"""The L1 sizing model behind the kernel's block-size choice."""

from compile.kernels import tuning
from compile.kernels.psi_stats import vmem_estimate_bytes


def test_pick_block_respects_vmem():
    best, rows = tuning.pick_block_n(m=64, q=2, d=3)
    assert best is not None
    bytes_needed = vmem_estimate_bytes(64, 2, 3, best)
    assert bytes_needed * tuning.STREAM_OVERLAP_FACTOR <= tuning.VMEM_BYTES
    # every larger candidate that was rejected really does not fit
    for bn, b, fits, _ in rows:
        if bn > best:
            assert not fits


def test_large_m_shrinks_block():
    small_m, _ = tuning.pick_block_n(m=32, q=2, d=3)
    big_m, _ = tuning.pick_block_n(m=256, q=2, d=3)
    assert big_m is None or big_m <= small_m


def test_mxu_fraction_grows_with_q():
    lo = tuning.mxu_fraction(m=64, q=1, d=3, bn=128)
    hi = tuning.mxu_fraction(m=64, q=8, d=3, bn=128)
    assert hi > lo  # contractions scale with q, elementwise does not


def test_flops_scale_linearly_in_block():
    f1 = tuning.flops_per_block(64, 2, 3, 128)
    f2 = tuning.flops_per_block(64, 2, 3, 256)
    assert abs(f2 / f1 - 2.0) < 1e-9
