"""Prediction-path semantics: the predict graph must implement the
standard sparse posterior (and its uncertain-input generalisation)
given the weight matrices W1 = beta Sigma^-1 C and Wv = Kmm^-1 - Sigma^-1.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import bound_ref, model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="module")
def fitted():
    """A small regression fit with everything precomputed."""
    rng = np.random.default_rng(0)
    n, m, q, d = 40, 9, 2, 3
    X = jnp.array(rng.normal(size=(n, q)))
    Z = jnp.array(rng.normal(size=(m, q)))
    log_ls = jnp.zeros(q)
    log_sf2 = jnp.array(0.0)
    log_beta = jnp.array(3.0)
    Y = jnp.array(rng.normal(size=(n, d)))
    a, p0, C, D, kl = ref.shard_stats_ref(
        Z, log_ls, log_sf2, X, jnp.zeros_like(X), Y, jnp.ones(n), 0.0)
    Kmm = ref.seard_kernel(Z, Z, log_ls, log_sf2) + 1e-8 * jnp.eye(m)
    beta = jnp.exp(log_beta)
    Sigma = Kmm + beta * D
    W1 = beta * jnp.linalg.solve(Sigma, C)
    Wv = jnp.linalg.inv(Kmm) - jnp.linalg.inv(Sigma)
    return dict(X=X, Z=Z, log_ls=log_ls, log_sf2=log_sf2, log_beta=log_beta,
                Y=Y, Kmm=Kmm, Sigma=Sigma, W1=W1, Wv=Wv, C=C, D=D)


def test_mean_matches_textbook_sparse_posterior(fitted):
    """mean = K*m (Kmm + beta Kmn Knm)^-1 beta Kmn Y (Titsias 2009)."""
    f = fitted
    rng = np.random.default_rng(1)
    Xt = jnp.array(rng.normal(size=(7, 2)))
    mean, _ = model.predict(f["Z"], f["log_ls"], jnp.array([f["log_sf2"]]),
                            Xt, jnp.zeros_like(Xt), f["W1"], f["Wv"])
    Ktm = ref.seard_kernel(Xt, f["Z"], f["log_ls"], f["log_sf2"])
    beta = jnp.exp(f["log_beta"])
    expect = Ktm @ jnp.linalg.solve(f["Sigma"], beta * f["C"])
    np.testing.assert_allclose(np.asarray(mean), np.asarray(expect), rtol=1e-10)


def test_variance_positive_and_reverts_to_prior(fitted):
    f = fitted
    near = f["X"][:5]
    far = near + 100.0
    _, v_near = model.predict(f["Z"], f["log_ls"], jnp.array([f["log_sf2"]]),
                              near, jnp.zeros_like(near), f["W1"], f["Wv"])
    _, v_far = model.predict(f["Z"], f["log_ls"], jnp.array([f["log_sf2"]]),
                             far, jnp.zeros_like(far), f["W1"], f["Wv"])
    assert np.all(np.asarray(v_near) > -1e-10)
    # far from data and inducing points, the posterior reverts to the prior
    np.testing.assert_allclose(np.asarray(v_far), np.exp(f["log_sf2"]),
                               rtol=1e-6)
    assert np.all(np.asarray(v_near) < np.asarray(v_far))


def test_uncertain_inputs_inflate_nothing_at_zero_variance(fitted):
    """Xt_var = 0 must agree exactly with the deterministic path."""
    f = fitted
    rng = np.random.default_rng(2)
    Xt = jnp.array(rng.normal(size=(6, 2)))
    m0, v0 = model.predict(f["Z"], f["log_ls"], jnp.array([f["log_sf2"]]),
                           Xt, jnp.zeros_like(Xt), f["W1"], f["Wv"])
    m1, v1 = model.predict(f["Z"], f["log_ls"], jnp.array([f["log_sf2"]]),
                           Xt, 1e-14 * jnp.ones_like(Xt), f["W1"], f["Wv"])
    np.testing.assert_allclose(np.asarray(m0), np.asarray(m1), atol=1e-9)
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1), atol=1e-9)


def test_uncertain_inputs_smooth_the_mean(fitted):
    """Increasing input variance shrinks Psi1, pulling the mean toward 0
    (the prior mean) — the qualitative behaviour reconstruction relies on."""
    f = fitted
    rng = np.random.default_rng(3)
    Xt = jnp.array(rng.normal(size=(10, 2)))
    m0, _ = model.predict(f["Z"], f["log_ls"], jnp.array([f["log_sf2"]]),
                          Xt, jnp.zeros_like(Xt), f["W1"], f["Wv"])
    m2, _ = model.predict(f["Z"], f["log_ls"], jnp.array([f["log_sf2"]]),
                          Xt, 4.0 * jnp.ones_like(Xt), f["W1"], f["Wv"])
    assert np.mean(np.abs(np.asarray(m2))) < np.mean(np.abs(np.asarray(m0)))


def test_optimal_qu_predictions_interpolate(fitted):
    """With enough inducing points and low noise, predictions at training
    inputs track the targets."""
    rng = np.random.default_rng(4)
    n, m = 60, 20
    X = jnp.array(np.sort(rng.uniform(-2, 2, size=(n, 1)), axis=0))
    Y = jnp.sin(2.0 * X)
    Z = jnp.array(np.linspace(-2, 2, m)[:, None])
    log_ls, log_sf2, log_beta = jnp.zeros(1) - 0.5, jnp.array(0.0), jnp.array(6.0)
    a, p0, C, D, kl = ref.shard_stats_ref(
        Z, log_ls, log_sf2, X, jnp.zeros_like(X), Y, jnp.ones(n), 0.0)
    Kmm = ref.seard_kernel(Z, Z, log_ls, log_sf2) + 1e-8 * jnp.eye(m)
    beta = jnp.exp(log_beta)
    Sigma = Kmm + beta * D
    W1 = beta * jnp.linalg.solve(Sigma, C)
    Wv = jnp.linalg.inv(Kmm) - jnp.linalg.inv(Sigma)
    mean, var = model.predict(Z, log_ls, jnp.array([log_sf2]), X,
                              jnp.zeros_like(X), W1, Wv)
    rmse = float(jnp.sqrt(jnp.mean((mean - Y) ** 2)))
    assert rmse < 0.01, rmse
    assert np.all(np.asarray(var) < 0.05)
