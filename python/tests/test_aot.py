"""AOT artifact generation: lowerability, manifest integrity, and the
runtime-compatibility constraint (no typed-FFI custom-calls, which
xla_extension 0.5.1 rejects at compile time).
"""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build(out, ["test"])
    return out


def test_all_entries_emitted(built):
    for entry in aot.ENTRIES:
        path = os.path.join(built, f"{entry}_test.hlo.txt")
        assert os.path.exists(path), entry
        assert os.path.getsize(path) > 200


def test_manifest_schema(built):
    with open(os.path.join(built, "manifest.json")) as f:
        man = json.load(f)
    assert man["dtype"] == "f64"
    cfg = man["configs"]["test"]
    for key in ("m", "q", "d", "B", "block_n", "entries"):
        assert key in cfg
    assert set(cfg["entries"]) == set(aot.ENTRIES)
    # every referenced file exists
    for fname in cfg["entries"].values():
        assert os.path.exists(os.path.join(built, fname))


def test_no_unsupported_custom_calls(built):
    """The deployment constraint that shaped the whole design (DESIGN.md §2):
    artifacts must be free of typed-FFI custom-calls (lapack_*_ffi etc.)."""
    for entry in aot.ENTRIES:
        with open(os.path.join(built, f"{entry}_test.hlo.txt")) as f:
            text = f.read()
        assert "API_VERSION_TYPED_FFI" not in text, entry
        assert "lapack" not in text, entry


def test_hlo_is_f64(built):
    with open(os.path.join(built, "shard_stats_test.hlo.txt")) as f:
        text = f.read()
    assert "f64[" in text


def test_entry_shapes_in_hlo(built):
    """Parameter shapes in the HLO must match the manifest config."""
    with open(os.path.join(built, "manifest.json")) as f:
        cfg = json.load(f)["configs"]["test"]
    m, q, B, d = cfg["m"], cfg["q"], cfg["B"], cfg["d"]
    with open(os.path.join(built, "shard_stats_test.hlo.txt")) as f:
        text = f.read()
    assert f"f64[{m},{q}]" in text   # Z
    assert f"f64[{B},{q}]" in text   # Xmu / Xvar
    assert f"f64[{B},{d}]" in text   # Y
