"""Model-level properties of the collapsed bound (eq. 3.3).

These pin the *statistical* correctness: the bound is a true lower bound
on the exact log marginal likelihood, is tight when Z = X, and the
optimal q(u) reproduces the exact sparse posterior.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import bound_ref
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def make_regression(seed, n=20, m=6, q=2, d=2):
    rng = np.random.default_rng(seed)
    X = jnp.array(rng.normal(size=(n, q)))
    Z = jnp.array(rng.normal(size=(m, q)))
    log_ls = jnp.array(rng.normal(size=q) * 0.1)
    log_sf2 = jnp.array(0.1)
    log_beta = jnp.array(1.5)
    Y = jnp.array(rng.normal(size=(n, d)))
    mask = jnp.ones(n)
    return X, Z, log_ls, log_sf2, log_beta, Y, mask


def exact_log_marginal(X, log_ls, log_sf2, log_beta, Y):
    """log N(Y; 0, Knn + beta^-1 I), summed over output dims."""
    n, d = Y.shape
    K = ref.seard_kernel(X, X, log_ls, log_sf2)
    Ky = K + jnp.exp(-log_beta) * jnp.eye(n)
    L = jnp.linalg.cholesky(Ky)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(L)))
    alpha = jax.scipy.linalg.cho_solve((L, True), Y)
    return (-0.5 * n * d * jnp.log(2 * jnp.pi) - 0.5 * d * logdet
            - 0.5 * jnp.sum(Y * alpha))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_bound_is_lower_bound(seed):
    X, Z, log_ls, log_sf2, log_beta, Y, mask = make_regression(seed)
    F = bound_ref.full_bound(Z, log_ls, log_sf2, log_beta,
                             X, jnp.zeros_like(X), Y, mask, 0.0, jitter=1e-10)
    exact = exact_log_marginal(X, log_ls, log_sf2, log_beta, Y)
    assert float(F) <= float(exact) + 1e-8


def test_bound_tight_when_z_equals_x():
    """Titsias (2009): with Z = X the collapsed bound is exact."""
    X, Z, log_ls, log_sf2, log_beta, Y, mask = make_regression(5, n=15, m=15)
    F = bound_ref.full_bound(X, log_ls, log_sf2, log_beta,
                             X, jnp.zeros_like(X), Y, mask, 0.0, jitter=1e-10)
    exact = exact_log_marginal(X, log_ls, log_sf2, log_beta, Y)
    np.testing.assert_allclose(float(F), float(exact), rtol=1e-7)


def test_more_inducing_points_tighten_bound():
    """Adding inducing points (superset) can only improve the optimum.

    We check the weaker monotone-in-practice form: Z = first k points of X,
    bound increases with k.
    """
    X, _, log_ls, log_sf2, log_beta, Y, mask = make_regression(6, n=24, q=2)
    vals = []
    for k in (2, 6, 12, 24):
        F = bound_ref.full_bound(X[:k], log_ls, log_sf2, log_beta,
                                 X, jnp.zeros_like(X), Y, mask, 0.0,
                                 jitter=1e-10)
        vals.append(float(F))
    assert all(b >= a - 1e-9 for a, b in zip(vals, vals[1:])), vals


def test_optimal_qu_matches_titsias_posterior():
    """mu_u = beta Kmm Sigma^-1 C must equal the standard sparse posterior
    mean at the inducing points (cross-checked via predictive equations)."""
    X, Z, log_ls, log_sf2, log_beta, Y, mask = make_regression(7)
    a, p0, C, D, kl = ref.shard_stats_ref(
        Z, log_ls, log_sf2, X, jnp.zeros_like(X), Y, mask, 0.0)
    m = Z.shape[0]
    Kmm = ref.seard_kernel(Z, Z, log_ls, log_sf2) + 1e-10 * jnp.eye(m)
    mu_u, S_u = bound_ref.optimal_qu(C, D, Kmm, log_beta)
    # Titsias eq: q(u) mean = beta Kmm (Kmm + beta Kmn Knm)^-1 Kmn Y
    beta = jnp.exp(log_beta)
    Knm = ref.seard_kernel(X, Z, log_ls, log_sf2)
    Sigma = Kmm + beta * Knm.T @ Knm
    expect = beta * Kmm @ jnp.linalg.solve(Sigma, Knm.T @ Y)
    np.testing.assert_allclose(np.asarray(mu_u), np.asarray(expect),
                               rtol=1e-8, atol=1e-10)
    # S_u is a valid covariance: symmetric positive definite
    S = np.asarray(S_u)
    np.testing.assert_allclose(S, S.T, atol=1e-10)
    assert np.all(np.linalg.eigvalsh((S + S.T) / 2) > 0)


def test_kl_zero_iff_prior():
    """KL(q||p) = 0 exactly at mu=0, s=1, positive elsewhere."""
    mu = jnp.zeros((4, 3))
    s = jnp.ones((4, 3))
    mask = jnp.ones(4)
    assert float(ref.kl_term(mu, s, mask, 1.0)) == pytest.approx(0.0, abs=1e-12)
    rng = np.random.default_rng(0)
    mu2 = jnp.array(rng.normal(size=(4, 3)))
    s2 = jnp.array(rng.uniform(0.1, 3.0, size=(4, 3)))
    assert float(ref.kl_term(mu2, s2, mask, 1.0)) > 0.0


def test_lvm_bound_below_regression_bound_at_true_inputs():
    """Adding input uncertainty (s > 0) plus KL can only lower the bound
    when the regression inputs are the truth."""
    X, Z, log_ls, log_sf2, log_beta, Y, mask = make_regression(9)
    F_reg = bound_ref.full_bound(Z, log_ls, log_sf2, log_beta,
                                 X, jnp.zeros_like(X), Y, mask, 0.0)
    F_lvm = bound_ref.full_bound(Z, log_ls, log_sf2, log_beta,
                                 X, 0.5 * jnp.ones_like(X), Y, mask, 1.0)
    assert float(F_lvm) < float(F_reg)
