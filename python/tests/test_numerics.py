"""Numerical robustness of the kernel/bound stack: extreme
hyperparameters, clustered inducing points, dtype sensitivity, and
hypothesis sweeps over the statistics' structural invariants.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import bound_ref
from compile.kernels import ref
from compile.kernels.psi_stats import shard_stats_pallas

jax.config.update("jax_enable_x64", True)


def case(seed, B=24, m=6, q=2, d=3, ls_scale=1.0, var_hi=1.0):
    rng = np.random.default_rng(seed)
    return dict(
        Z=jnp.array(rng.normal(size=(m, q))),
        log_ls=jnp.array(np.log(ls_scale) + 0.1 * rng.normal(size=q)),
        log_sf2=jnp.array(0.1 * rng.normal()),
        Xmu=jnp.array(rng.normal(size=(B, q))),
        Xvar=jnp.array(rng.uniform(0.01, var_hi, size=(B, q))),
        Y=jnp.array(rng.normal(size=(B, d))),
        mask=jnp.ones(B),
    )


@pytest.mark.parametrize("ls_scale", [1e-2, 1e2])
def test_extreme_lengthscales_stay_finite(ls_scale):
    c = case(0, ls_scale=ls_scale)
    a, p0, C, D, kl = ref.shard_stats_ref(
        c["Z"], c["log_ls"], c["log_sf2"], c["Xmu"], c["Xvar"], c["Y"],
        c["mask"], 1.0)
    for name, v in [("a", a), ("p0", p0), ("C", C), ("D", D), ("kl", kl)]:
        assert np.all(np.isfinite(np.asarray(v))), name


def test_huge_input_variance_kills_psi1():
    """s -> inf: <k(x, z)> -> 0 (the latent point knows nothing)."""
    c = case(1)
    P1 = ref.psi1(c["Z"], c["log_ls"], c["log_sf2"], c["Xmu"],
                  1e8 * jnp.ones_like(c["Xvar"]))
    assert float(jnp.max(jnp.abs(P1))) < 1e-3


def test_coincident_inducing_points_bound_recoverable():
    """Duplicated rows of Z make Kmm singular; the jittered bound must
    still evaluate (the paper's implementation faces this constantly
    during optimisation)."""
    c = case(2, m=5)
    Z = c["Z"].at[1].set(c["Z"][0])  # exact duplicate
    F = bound_ref.full_bound(Z, c["log_ls"], c["log_sf2"], jnp.array(1.0),
                             c["Xmu"], c["Xvar"], c["Y"], c["mask"], 1.0,
                             jitter=1e-6)
    assert np.isfinite(float(F))


def test_f32_vs_f64_statistics_error():
    """The f32 kernel path agrees to ~1e-5 relative — documents why the
    artifact path is f64 (log-det assembly amplifies stat errors)."""
    c = case(3, B=32)
    klw = jnp.array([1.0])
    out64 = shard_stats_pallas(
        c["Z"], c["log_ls"], jnp.array([c["log_sf2"]]), c["Xmu"], c["Xvar"],
        c["Y"], c["mask"], klw, block_n=16)
    to32 = lambda x: jnp.asarray(x, jnp.float32)
    out32 = shard_stats_pallas(
        to32(c["Z"]), to32(c["log_ls"]), to32(jnp.array([c["log_sf2"]])),
        to32(c["Xmu"]), to32(c["Xvar"]), to32(c["Y"]), to32(c["mask"]),
        to32(klw), block_n=16)
    for v64, v32 in zip(out64, out32):
        rel = np.max(np.abs(np.asarray(v64) - np.asarray(v32, np.float64))) / (
            1.0 + np.max(np.abs(np.asarray(v64))))
        assert rel < 1e-4


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), q=st.integers(1, 4))
def test_psi2_psd_property(seed, q):
    """Psi2 = sum_i E[k k^T] must be PSD for any inputs."""
    rng = np.random.default_rng(seed)
    B, m = 12, 5
    Z = jnp.array(rng.normal(size=(m, q)))
    log_ls = jnp.array(0.3 * rng.normal(size=q))
    log_sf2 = jnp.array(0.2 * rng.normal())
    Xmu = jnp.array(rng.normal(size=(B, q)))
    Xvar = jnp.array(rng.uniform(0.01, 2.0, size=(B, q)))
    D = ref.psi2(Z, log_ls, log_sf2, Xmu, Xvar, jnp.ones(B))
    eig = np.linalg.eigvalsh(np.asarray(D))
    assert eig.min() > -1e-9 * max(1.0, eig.max())


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_psi1_bounded_by_sf2(seed):
    """|Psi1| <= sigma^2: an expectation of a bounded kernel."""
    rng = np.random.default_rng(seed)
    q = 3
    Z = jnp.array(rng.normal(size=(6, q)))
    log_ls = jnp.array(0.3 * rng.normal(size=q))
    log_sf2 = jnp.array(rng.normal())
    Xmu = jnp.array(rng.normal(size=(10, q)))
    Xvar = jnp.array(rng.uniform(0.0, 3.0, size=(10, q)))
    P1 = ref.psi1(Z, log_ls, log_sf2, Xmu, Xvar)
    assert float(jnp.max(P1)) <= float(jnp.exp(log_sf2)) + 1e-12
    assert float(jnp.min(P1)) >= 0.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_bound_monotone_in_noise_mismatch(seed):
    """With data generated at noise 1/beta*, the bound at beta = beta* is
    at least the bound at a wildly wrong beta (model selection works)."""
    rng = np.random.default_rng(seed)
    n, q, d = 30, 1, 2
    X = jnp.array(rng.normal(size=(n, q)))
    F_true = jnp.sin(2.0 * X)
    Y = jnp.tile(F_true, (1, d)) + 0.1 * jnp.array(rng.normal(size=(n, d)))
    Z = jnp.array(rng.normal(size=(8, q)))
    log_ls = jnp.array([np.log(0.7)])
    args = (X, jnp.zeros_like(X), Y, jnp.ones(n), 0.0)
    f_good = bound_ref.full_bound(Z, log_ls, jnp.array(0.0),
                                  jnp.array(np.log(1 / 0.1**2)), *args)
    f_bad = bound_ref.full_bound(Z, log_ls, jnp.array(0.0),
                                 jnp.array(np.log(1e6)), *args)
    assert float(f_good) > float(f_bad)
