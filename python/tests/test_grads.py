"""L2 correctness: the adjoint chain rule and gradient artifacts.

The key identity behind the paper's step-3/step-4 message protocol:

    dF/dtheta = shard_grads(theta; adjoints)          (through statistics)
              + kmm_grads(theta; dF/dKmm)             (direct Kmm term)

i.e. the distributed two-round gradient must equal jax.grad of the
monolithic collapsed bound. These tests pin that identity to ~1e-9.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import bound_ref, model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def make_case(seed, B=24, m=6, q=2, d=3, lvm=True):
    rng = np.random.default_rng(seed)
    Z = jnp.array(rng.normal(size=(m, q)))
    log_ls = jnp.array(rng.normal(size=q) * 0.2)
    log_sf2 = jnp.array(rng.normal() * 0.2)
    log_beta = jnp.array(1.0 + 0.2 * rng.normal())
    Xmu = jnp.array(rng.normal(size=(B, q)))
    Xvar = (jnp.array(rng.uniform(0.05, 1.0, size=(B, q)))
            if lvm else jnp.zeros((B, q)))
    Y = jnp.array(rng.normal(size=(B, d)))
    mask = jnp.array((rng.uniform(size=B) > 0.1).astype(np.float64))
    klw = 1.0 if lvm else 0.0
    return Z, log_ls, log_sf2, log_beta, Xmu, Xvar, Y, mask, klw


@pytest.mark.parametrize("lvm", [True, False])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_distributed_gradient_equals_monolithic(seed, lvm):
    Z, log_ls, log_sf2, log_beta, Xmu, Xvar, Y, mask, klw = make_case(
        seed, lvm=lvm)
    m, d = Z.shape[0], Y.shape[1]
    jitter = 1e-6

    # --- monolithic oracle ------------------------------------------------
    g_Z, g_ls, g_sf2, g_beta, g_Xmu, g_Xvar = bound_ref.full_bound_grads(
        Z, log_ls, log_sf2, log_beta, Xmu, Xvar, Y, mask, klw, jitter)

    # --- the protocol path ------------------------------------------------
    a, p0, C, D, kl = ref.shard_stats_ref(
        Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, klw)
    Kmm = ref.seard_kernel(Z, Z, log_ls, log_sf2) + jitter * jnp.eye(m)
    n = jnp.sum(mask)
    adj_p0, adj_C, adj_D, adj_kl, adj_Kmm, adj_lb = bound_ref.bound_adjoints(
        a, p0, C, D, kl, Kmm, log_beta, n, d)

    # map step 2 on the (single) shard
    dZ_s, dls_s, dsf2_s, dXmu_s, dXvar_s = model.shard_grads(
        Z, log_ls, jnp.array([log_sf2]), Xmu, Xvar, Y, mask,
        jnp.array([klw]),
        jnp.array([adj_p0]), adj_C, adj_D, jnp.array([adj_kl]))

    # central direct term. note: jitter*I has zero kernel-param gradient,
    # so pulling adj_Kmm back through the un-jittered Kmm is exact.
    Kmm_art, dZ_k, dls_k, dsf2_k = model.kmm_grads(
        Z, log_ls, jnp.array([log_sf2]), adj_Kmm)

    np.testing.assert_allclose(np.asarray(Kmm_art) + jitter * np.eye(m),
                               np.asarray(Kmm), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(dZ_s + dZ_k), np.asarray(g_Z),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(dls_s + dls_k), np.asarray(g_ls),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(float(dsf2_s[0] + dsf2_k[0]), float(g_sf2),
                               rtol=1e-8)
    # beta only enters the bound directly (stats are beta-free)
    np.testing.assert_allclose(float(adj_lb), float(g_beta), rtol=1e-9)
    np.testing.assert_allclose(np.asarray(dXmu_s), np.asarray(g_Xmu),
                               rtol=1e-8, atol=1e-10)
    if lvm:
        np.testing.assert_allclose(np.asarray(dXvar_s), np.asarray(g_Xvar),
                                   rtol=1e-8, atol=1e-10)


def test_shard_grads_additive_over_shards():
    """Gradient partial terms must sum across shards like the stats do."""
    Z, log_ls, log_sf2, log_beta, Xmu, Xvar, Y, mask, klw = make_case(4)
    m, d = Z.shape[0], Y.shape[1]
    adj_p0 = jnp.array([0.3])
    adj_C = jnp.array(np.random.default_rng(0).normal(size=(m, d)))
    adj_D = jnp.array(np.random.default_rng(1).normal(size=(m, m)))
    adj_kl = jnp.array([-1.0])
    args = (Z, log_ls, jnp.array([log_sf2]))
    whole = model.shard_grads(*args, Xmu, Xvar, Y, mask, jnp.array([klw]),
                              adj_p0, adj_C, adj_D, adj_kl)
    h = Xmu.shape[0] // 2
    p1 = model.shard_grads(*args, Xmu[:h], Xvar[:h], Y[:h], mask[:h],
                           jnp.array([klw]), adj_p0, adj_C, adj_D, adj_kl)
    p2 = model.shard_grads(*args, Xmu[h:], Xvar[h:], Y[h:], mask[h:],
                           jnp.array([klw]), adj_p0, adj_C, adj_D, adj_kl)
    for w, g1, g2 in zip(whole[:3], p1[:3], p2[:3]):  # global params add
        np.testing.assert_allclose(np.asarray(g1) + np.asarray(g2),
                                   np.asarray(w), rtol=1e-9, atol=1e-12)
    # local params concatenate
    np.testing.assert_allclose(
        np.concatenate([np.asarray(p1[3]), np.asarray(p2[3])]),
        np.asarray(whole[3]), rtol=1e-9, atol=1e-12)


def test_finite_difference_spotcheck():
    """Independent-of-autodiff check of the full bound gradient."""
    case = make_case(6, B=12, m=4, q=2, d=2)
    Z, log_ls, log_sf2, log_beta, Xmu, Xvar, Y, mask, klw = case

    def f(z00):
        Z2 = Z.at[0, 0].set(z00)
        return bound_ref.full_bound(Z2, log_ls, log_sf2, log_beta,
                                    Xmu, Xvar, Y, mask, klw)

    eps = 1e-5
    fd = (f(Z[0, 0] + eps) - f(Z[0, 0] - eps)) / (2 * eps)
    g = bound_ref.full_bound_grads(*case)[0][0, 0]
    np.testing.assert_allclose(float(g), float(fd), rtol=1e-5)


def test_masked_rows_have_zero_local_gradient():
    Z, log_ls, log_sf2, log_beta, Xmu, Xvar, Y, mask, klw = make_case(7)
    mask = mask.at[:5].set(0.0)
    m, d = Z.shape[0], Y.shape[1]
    rng = np.random.default_rng(2)
    out = model.shard_grads(
        Z, log_ls, jnp.array([log_sf2]), Xmu, Xvar, Y, mask,
        jnp.array([klw]), jnp.array([0.5]),
        jnp.array(rng.normal(size=(m, d))), jnp.array(rng.normal(size=(m, m))),
        jnp.array([1.0]))
    np.testing.assert_allclose(np.asarray(out[3][:5]), 0.0, atol=1e-14)
    np.testing.assert_allclose(np.asarray(out[4][:5]), 0.0, atol=1e-14)
