"""L1 correctness: the Pallas psi-statistics kernel vs the pure-jnp oracle.

This is the core correctness signal for the kernel layer: the fused
Pallas kernel must agree with kernels/ref.py to near machine precision
across shapes, dtypes, block sizes, masks and the regression (s = 0)
special case. Shape/dtype sweeps use hypothesis.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.psi_stats import shard_stats_pallas, vmem_estimate_bytes

jax.config.update("jax_enable_x64", True)


def random_case(rng, B, m, q, d, lvm=True, dtype=jnp.float64):
    Z = jnp.array(rng.normal(size=(m, q)), dtype)
    log_ls = jnp.array(rng.normal(size=q) * 0.3, dtype)
    log_sf2 = jnp.array([rng.normal() * 0.3], dtype)
    Xmu = jnp.array(rng.normal(size=(B, q)), dtype)
    Xvar = (
        jnp.array(rng.uniform(0.01, 1.5, size=(B, q)), dtype)
        if lvm else jnp.zeros((B, q), dtype)
    )
    Y = jnp.array(rng.normal(size=(B, d)), dtype)
    mask = jnp.array((rng.uniform(size=B) > 0.2).astype(float), dtype)
    klw = jnp.array([1.0 if lvm else 0.0], dtype)
    return Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, klw


def assert_stats_match(out, expected, rtol):
    names = ("a", "psi0", "C", "D", "kl")
    for name, o, r in zip(names, out, expected):
        r = np.asarray(r)
        np.testing.assert_allclose(
            np.asarray(o).reshape(r.shape), r, rtol=rtol, atol=rtol,
            err_msg=f"statistic {name} mismatch",
        )


def ref_stats(Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, klw):
    return ref.shard_stats_ref(Z, log_ls, log_sf2[0], Xmu, Xvar, Y, mask, klw[0])


class TestPallasVsRef:
    @pytest.mark.parametrize("lvm", [True, False])
    @pytest.mark.parametrize("block_n", [8, 16, 64])
    def test_matches_reference(self, lvm, block_n):
        rng = np.random.default_rng(0)
        case = random_case(rng, B=64, m=8, q=3, d=5, lvm=lvm)
        out = shard_stats_pallas(*case, block_n=block_n)
        assert_stats_match(out, ref_stats(*case), rtol=1e-12)

    def test_block_size_invariance(self):
        """Accumulation across grid steps must not depend on the tiling."""
        rng = np.random.default_rng(1)
        case = random_case(rng, B=96, m=6, q=2, d=4)
        outs = [shard_stats_pallas(*case, block_n=bn) for bn in (8, 24, 96)]
        for o in outs[1:]:
            assert_stats_match(o, outs[0], rtol=1e-12)

    @settings(max_examples=20, deadline=None)
    @given(
        B=st.sampled_from([16, 32, 48]),
        m=st.integers(2, 12),
        q=st.integers(1, 5),
        d=st.integers(1, 8),
        seed=st.integers(0, 10_000),
        lvm=st.booleans(),
    )
    def test_shape_sweep(self, B, m, q, d, seed, lvm):
        rng = np.random.default_rng(seed)
        case = random_case(rng, B=B, m=m, q=q, d=d, lvm=lvm)
        out = shard_stats_pallas(*case, block_n=16)
        assert_stats_match(out, ref_stats(*case), rtol=1e-11)

    def test_f32_dtype(self):
        rng = np.random.default_rng(3)
        case = random_case(rng, B=32, m=6, q=2, d=3, dtype=jnp.float32)
        out = shard_stats_pallas(*case, block_n=16)
        exp = ref_stats(*case)
        assert out[2].dtype == jnp.float32
        assert_stats_match(out, exp, rtol=2e-5)


class TestRegressionSpecialCase:
    """s = 0 must reduce to the exact Titsias (2009) quantities."""

    def setup_method(self):
        rng = np.random.default_rng(5)
        self.case = random_case(rng, B=48, m=7, q=3, d=2, lvm=False)

    def test_psi1_is_knm(self):
        Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, klw = self.case
        P1 = ref.psi1(Z, log_ls, log_sf2[0], Xmu, Xvar)
        Knm = ref.seard_kernel(Xmu, Z, log_ls, log_sf2[0])
        np.testing.assert_allclose(np.asarray(P1), np.asarray(Knm), rtol=1e-13)

    def test_psi2_is_kmn_knm(self):
        Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, klw = self.case
        out = shard_stats_pallas(*self.case, block_n=16)
        Knm = ref.seard_kernel(Xmu, Z, log_ls, log_sf2[0])
        D_exact = (np.asarray(Knm) * np.asarray(mask)[:, None]).T @ np.asarray(Knm)
        np.testing.assert_allclose(np.asarray(out[3]), D_exact, rtol=1e-11, atol=1e-12)

    def test_kl_is_zero(self):
        out = shard_stats_pallas(*self.case, block_n=16)
        assert float(out[4][0]) == 0.0

    def test_psi0_counts_live_points(self):
        Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, klw = self.case
        out = shard_stats_pallas(*self.case, block_n=16)
        expected = float(jnp.exp(log_sf2[0]) * jnp.sum(mask))
        np.testing.assert_allclose(float(out[1][0]), expected, rtol=1e-13)


class TestMaskSemantics:
    def test_masked_points_do_not_contribute(self):
        """Padding rows with garbage must not change any statistic."""
        rng = np.random.default_rng(8)
        Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, klw = random_case(
            rng, B=32, m=5, q=2, d=3
        )
        mask = jnp.concatenate([jnp.ones(24), jnp.zeros(8)])
        out1 = shard_stats_pallas(Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, klw,
                                  block_n=16)
        # poison the dead rows
        Xmu2 = Xmu.at[24:].set(1e3)
        Y2 = Y.at[24:].set(-1e3)
        Xvar2 = Xvar.at[24:].set(42.0)
        out2 = shard_stats_pallas(Z, log_ls, log_sf2, Xmu2, Xvar2, Y2, mask, klw,
                                  block_n=16)
        assert_stats_match(out2, [np.asarray(o).squeeze() for o in out1],
                           rtol=1e-12)

    def test_shard_additivity(self):
        """stats(shard1) + stats(shard2) == stats(shard1 ++ shard2).

        This is the invariant the whole distributed reduce relies on.
        """
        rng = np.random.default_rng(9)
        Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, klw = random_case(
            rng, B=64, m=6, q=2, d=3
        )
        whole = shard_stats_pallas(Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, klw,
                                   block_n=16)
        h = 32
        p1 = shard_stats_pallas(Z, log_ls, log_sf2, Xmu[:h], Xvar[:h], Y[:h],
                                mask[:h], klw, block_n=16)
        p2 = shard_stats_pallas(Z, log_ls, log_sf2, Xmu[h:], Xvar[h:], Y[h:],
                                mask[h:], klw, block_n=16)
        for w, a_, b_ in zip(whole, p1, p2):
            np.testing.assert_allclose(
                np.asarray(a_) + np.asarray(b_), np.asarray(w), rtol=1e-12
            )


def test_vmem_estimate_monotone():
    """Sizing aid sanity: footprint grows with every dimension."""
    base = vmem_estimate_bytes(m=32, q=4, d=8, bn=64)
    assert vmem_estimate_bytes(64, 4, 8, 64) > base
    assert vmem_estimate_bytes(32, 8, 8, 64) > base
    assert vmem_estimate_bytes(32, 4, 16, 64) > base
    assert vmem_estimate_bytes(32, 4, 8, 128) > base
