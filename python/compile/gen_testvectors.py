"""Dump cross-layer test vectors to artifacts/testvectors.json.

A small random sparse-GP/GPLVM instance is pushed through the full JAX
oracle (statistics -> collapsed bound -> adjoints -> parameter
gradients -> optimal q(u) -> predictions). The Rust crate's unit tests
parse this file and assert that the hand-derived native global step
(rust/src/gp/) reproduces every number to ~1e-9 — the strongest
cross-language correctness signal in the repo.

Usage: python -m compile.gen_testvectors [--out ../artifacts/testvectors.json]
"""

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from . import bound_ref, model
from .kernels import ref

jax.config.update("jax_enable_x64", True)


def _tolist(x):
    return np.asarray(x).tolist()


def make_case(seed, B, m, q, d, kl_weight, name):
    rng = np.random.default_rng(seed)
    Z = jnp.array(rng.normal(size=(m, q)))
    log_ls = jnp.array(rng.normal(size=q) * 0.2)
    log_sf2 = jnp.array(rng.normal() * 0.2)
    log_beta = jnp.array(rng.normal() * 0.2 + 1.0)
    Xmu = jnp.array(rng.normal(size=(B, q)))
    if kl_weight > 0.0:
        Xvar = jnp.array(rng.uniform(0.05, 1.0, size=(B, q)))
    else:
        Xvar = jnp.zeros((B, q))
    Y = jnp.array(rng.normal(size=(B, d)))
    mask = jnp.array((rng.uniform(size=B) > 0.15).astype(np.float64))
    jitter = 1e-6

    a, p0, C, D, kl = ref.shard_stats_ref(
        Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, kl_weight
    )
    Kmm = ref.seard_kernel(Z, Z, log_ls, log_sf2) + jitter * jnp.eye(m)
    n = jnp.sum(mask)
    F = bound_ref.bound_from_stats(a, p0, C, D, kl, Kmm, log_beta, n, d)
    adj = bound_ref.bound_adjoints(a, p0, C, D, kl, Kmm, log_beta, n, d)
    adj_p0, adj_C, adj_D, adj_kl, adj_Kmm, dlog_beta = adj
    grads = bound_ref.full_bound_grads(
        Z, log_ls, log_sf2, log_beta, Xmu, Xvar, Y, mask, kl_weight, jitter
    )
    dZ, dlog_ls, dlog_sf2, dlog_beta_full, dXmu, dXvar = grads
    mu_u, S_u = bound_ref.optimal_qu(C, D, Kmm, log_beta)

    # prediction weights the Rust side must reproduce
    beta = jnp.exp(log_beta)
    Sigma = Kmm + beta * D
    W1 = beta * jnp.linalg.solve(Sigma, C)
    Wv = jnp.linalg.inv(Kmm) - jnp.linalg.inv(Sigma)
    Xt_mu = jnp.array(rng.normal(size=(5, q)))
    Xt_var = jnp.zeros((5, q)) if kl_weight == 0.0 else jnp.array(
        rng.uniform(0.05, 0.5, size=(5, q)))
    mean, var = model.predict(
        Z, log_ls, jnp.array([log_sf2]), Xt_mu, Xt_var, W1, Wv
    )

    return {
        "name": name,
        "B": B, "m": m, "q": q, "d": d,
        "kl_weight": kl_weight, "jitter": jitter,
        "inputs": {
            "Z": _tolist(Z), "log_ls": _tolist(log_ls),
            "log_sf2": float(log_sf2), "log_beta": float(log_beta),
            "Xmu": _tolist(Xmu), "Xvar": _tolist(Xvar),
            "Y": _tolist(Y), "mask": _tolist(mask),
        },
        "stats": {
            "a": float(a), "psi0": float(p0),
            "C": _tolist(C), "D": _tolist(D), "kl": float(kl),
            "Kmm": _tolist(Kmm), "n": float(n),
        },
        "bound": float(F),
        "adjoints": {
            "psi0": float(adj_p0), "C": _tolist(adj_C), "D": _tolist(adj_D),
            "kl": float(adj_kl), "Kmm": _tolist(adj_Kmm),
            "log_beta": float(dlog_beta),
        },
        "grads": {
            "Z": _tolist(dZ), "log_ls": _tolist(dlog_ls),
            "log_sf2": float(dlog_sf2), "log_beta": float(dlog_beta_full),
            "Xmu": _tolist(dXmu), "Xvar": _tolist(dXvar),
        },
        "qu": {"mu": _tolist(mu_u), "S": _tolist(S_u)},
        "predict": {
            "Xt_mu": _tolist(Xt_mu), "Xt_var": _tolist(Xt_var),
            "W1": _tolist(W1), "Wv": _tolist(Wv),
            "mean": _tolist(mean), "var": _tolist(var),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/testvectors.json")
    args = ap.parse_args()
    # the first two cases match the "test" artifact config (m=8, q=2, d=3,
    # B<=32) so the PJRT integration tests can replay them through the
    # compiled artifacts; lvm_wide exercises the native path at odd shapes.
    cases = [
        make_case(seed=7,  B=24, m=8, q=2, d=3, kl_weight=1.0, name="lvm_small"),
        make_case(seed=11, B=24, m=8, q=2, d=3, kl_weight=0.0, name="reg_small"),
        make_case(seed=13, B=40, m=9, q=4, d=7, kl_weight=1.0, name="lvm_wide"),
    ]
    with open(args.out, "w") as f:
        json.dump({"cases": cases}, f)
    print(f"wrote {args.out} ({len(cases)} cases)")


if __name__ == "__main__":
    main()
