"""AOT compilation: lower the Layer-2 graphs to HLO text artifacts.

Run once at build time (`make artifacts`); the Rust coordinator then
loads and executes the artifacts via PJRT with no Python anywhere on the
inference path.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 rejects (proto.id() <= INT_MAX); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/load_hlo.

Each named config fixes the static shapes (m inducing points, q latent
dims, d output dims, B shard capacity). Shards smaller than B are padded
and masked, so one compiled executable serves any fill level.

Usage:  python -m compile.aot [--out DIR] [--config NAME ...]
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)

DTYPE = jnp.float64

# name -> (m, q, d, B, block_n)
CONFIGS = {
    "test":   dict(m=8,  q=2, d=3,   B=32,   block_n=16),
    "small":  dict(m=16, q=2, d=3,   B=256,  block_n=64),
    # B sized for ~10-node shards of the n<=1000 oilflow runs (fig4/fig7):
    # oversized caps just burn padded FLOPs on every chunk.
    "oil":    dict(m=32, q=6, d=12,  B=64,   block_n=32),
    "digits": dict(m=48, q=8, d=256, B=128,  block_n=32),
    "perf":   dict(m=64, q=2, d=3,   B=2048, block_n=256),
    # the flight-delay regression scenario (gparml experiment flights):
    # 8 observed covariates, scalar delay output
    "flights": dict(m=32, q=8, d=1,  B=128,  block_n=32),
}

ENTRIES = ("shard_stats", "shard_grads", "kmm_grads", "predict")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


def lower_entry(entry, cfg):
    m, q, d, B = cfg["m"], cfg["q"], cfg["d"], cfg["B"]
    Z, ls, sf2 = _spec(m, q), _spec(q), _spec(1)
    Xmu, Xvar, Y, mask, klw = _spec(B, q), _spec(B, q), _spec(B, d), _spec(B), _spec(1)
    if entry == "shard_stats":
        fn = functools.partial(model.shard_stats, block_n=cfg["block_n"])
        args = (Z, ls, sf2, Xmu, Xvar, Y, mask, klw)
    elif entry == "shard_grads":
        adj = (_spec(1), _spec(m, d), _spec(m, m), _spec(1))
        fn = model.shard_grads
        args = (Z, ls, sf2, Xmu, Xvar, Y, mask, klw) + adj
    elif entry == "kmm_grads":
        fn = model.kmm_grads
        args = (Z, ls, sf2, _spec(m, m))
    elif entry == "predict":
        fn = model.predict
        args = (Z, ls, sf2, Xmu, Xvar, _spec(m, d), _spec(m, m))
    else:
        raise ValueError(entry)
    return to_hlo_text(jax.jit(fn).lower(*args))


def build(out_dir, config_names):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "dtype": "f64", "configs": {}}
    for name in config_names:
        cfg = CONFIGS[name]
        entries = {}
        for entry in ENTRIES:
            text = lower_entry(entry, cfg)
            fname = f"{entry}_{name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entries[entry] = fname
            print(f"  {fname}: {len(text)} chars")
        manifest["configs"][name] = {**cfg, "entries": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json ({len(config_names)} configs)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--config", action="append", default=None,
                    help="config name(s); default: all")
    args = ap.parse_args()
    names = args.config or list(CONFIGS)
    build(args.out, names)


if __name__ == "__main__":
    main()
