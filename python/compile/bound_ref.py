"""Collapsed-bound oracle (build/test-time only; uses jnp.linalg).

Implements eq. 3.3 of the paper — the unifying lower bound with the
optimal q(u) substituted analytically:

  F = -nd/2 log 2pi + nd/2 log beta + d/2 log|Kmm| - d/2 log|Sigma|
      - beta/2 a - beta d/2 psi0 + beta d/2 tr(Kmm^-1 D)
      + beta^2/2 tr(C^T Sigma^-1 C) - KL,        Sigma = Kmm + beta D

This module is the single source of truth the Rust global step
(rust/src/gp/bound.rs) is validated against: gen_testvectors.py dumps
F, the adjoints dF/d{psi0, C, D, KL, Kmm, log_beta} and the end-to-end
parameter gradients (all via jax autodiff, cholesky included) to JSON,
and cargo tests assert the hand-derived Rust algebra matches to ~1e-9.

It never becomes an artifact: jax >= 0.8 lowers cholesky to typed-FFI
lapack custom-calls that xla_extension 0.5.1 cannot compile.
"""

import jax
import jax.numpy as jnp

from .kernels import ref

jax.config.update("jax_enable_x64", True)


def bound_from_stats(a, p0, C, D, kl, Kmm, log_beta, n, d):
    """Eq. 3.3 given accumulated statistics and Kmm (jitter pre-added).

    D and Kmm are symmetrized first: the bound is treated as an explicitly
    symmetric function of both, which fixes the adjoint convention to the
    symmetric "full-matrix" gradient that the hand-derived Rust global
    step (rust/src/gp/bound.rs) produces.
    """
    D = 0.5 * (D + D.T)
    Kmm = 0.5 * (Kmm + Kmm.T)
    beta = jnp.exp(log_beta)
    Sigma = Kmm + beta * D
    Lk = jnp.linalg.cholesky(Kmm)
    Ls = jnp.linalg.cholesky(Sigma)
    logdet_K = 2.0 * jnp.sum(jnp.log(jnp.diagonal(Lk)))
    logdet_S = 2.0 * jnp.sum(jnp.log(jnp.diagonal(Ls)))
    Kinv_D = jax.scipy.linalg.cho_solve((Lk, True), D)
    Sinv_C = jax.scipy.linalg.cho_solve((Ls, True), C)
    return (
        -0.5 * n * d * jnp.log(2.0 * jnp.pi)
        + 0.5 * n * d * log_beta
        + 0.5 * d * logdet_K
        - 0.5 * d * logdet_S
        - 0.5 * beta * a
        - 0.5 * beta * d * p0
        + 0.5 * beta * d * jnp.trace(Kinv_D)
        + 0.5 * beta * beta * jnp.sum(C * Sinv_C)
        - kl
    )


def full_bound(Z, log_ls, log_sf2, log_beta, Xmu, Xvar, Y, mask, kl_weight,
               jitter=1e-6):
    """End-to-end collapsed bound from raw parameters (oracle path)."""
    a, p0, C, D, kl = ref.shard_stats_ref(
        Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, kl_weight
    )
    m = Z.shape[0]
    Kmm = ref.seard_kernel(Z, Z, log_ls, log_sf2) + jitter * jnp.eye(m)
    n = jnp.sum(mask)
    d = Y.shape[1]
    return bound_from_stats(a, p0, C, D, kl, Kmm, log_beta, n, d)


def bound_adjoints(a, p0, C, D, kl, Kmm, log_beta, n, d):
    """dF/d{p0, C, D, kl, Kmm, log_beta} — the constant-size message the
    central node broadcasts in map step 2 (oracle for rust gp::adjoints)."""
    g = jax.grad(bound_from_stats, argnums=(1, 2, 3, 4, 5, 6))(
        a, p0, C, D, kl, Kmm, log_beta, n, d
    )
    return g


def full_bound_grads(Z, log_ls, log_sf2, log_beta, Xmu, Xvar, Y, mask,
                     kl_weight, jitter=1e-6):
    """End-to-end gradient oracle w.r.t. all parameters."""
    return jax.grad(full_bound, argnums=(0, 1, 2, 3, 4, 5))(
        Z, log_ls, log_sf2, log_beta, Xmu, Xvar, Y, mask, kl_weight, jitter
    )


def optimal_qu(C, D, Kmm, log_beta):
    """Optimal variational q(u) = N(mu_u, S_u) (paper §3; supp. §3):

    mu_u = beta Kmm Sigma^-1 C,   S_u = Kmm Sigma^-1 Kmm.
    """
    beta = jnp.exp(log_beta)
    Sigma = Kmm + beta * D
    Ls = jnp.linalg.cholesky(Sigma)
    Sinv_C = jax.scipy.linalg.cho_solve((Ls, True), C)
    Sinv_K = jax.scipy.linalg.cho_solve((Ls, True), Kmm)
    return beta * Kmm @ Sinv_C, Kmm @ Sinv_K
