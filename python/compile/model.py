"""Layer-2 JAX model: the artifact entry points.

Four computation graphs get AOT-lowered to HLO text (aot.py) and executed
from the Rust coordinator via PJRT. Together with the native-Rust global
step (rust/src/gp/) they implement the paper's two Map-Reduce rounds:

  shard_stats  — map step 1: partial statistics (a, psi0, C, D, KL) for one
                 shard. Hot path: the Pallas kernel (kernels/psi_stats.py).
  shard_grads  — map step 2: given the adjoints dF/d{psi0, C, D, KL}
                 computed by the central node, chain-rule to the partial
                 gradients w.r.t. the global parameters (Z, log_ls,
                 log_sf2) and this shard's local parameters (Xmu, Xvar).
                 Implemented as jax.grad through the jnp reference
                 statistics — the same math as the Pallas kernel (pytest
                 asserts equality), kept differentiable.
  kmm_grads    — central direct term: Kmm and the pullback of an adjoint
                 dF/dKmm onto (Z, log_ls, log_sf2).
  predict      — sparse posterior predictions with (optionally) uncertain
                 inputs, given the solved weight matrices W1 = beta
                 Sigma^-1 C and Wv = Kmm^-1 - Sigma^-1 from the Rust side.

All graphs are decomposition-free (no cholesky/solve custom-calls): the
O(m^3) algebra lives in native Rust. See DESIGN.md §2 for why.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.psi_stats import shard_stats_pallas

jax.config.update("jax_enable_x64", True)


# --------------------------------------------------------------------------
# map step 1: partial statistics
# --------------------------------------------------------------------------

def shard_stats(Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, kl_weight,
                block_n=None):
    """Partial statistics for one shard — Pallas kernel under the hood.

    Returns (a [1], psi0 [1], C [m,d], D [m,m], kl [1]).
    """
    return shard_stats_pallas(
        Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, kl_weight, block_n=block_n
    )


# --------------------------------------------------------------------------
# map step 2: partial gradients via the adjoint chain rule
# --------------------------------------------------------------------------

def _weighted_stats(Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, kl_weight,
                    adj_p0, adj_C, adj_D, adj_kl):
    """Scalar <adjoints, statistics> whose gradient is the shard gradient."""
    _, p0, C, D, kl = ref.shard_stats_ref(
        Z, log_ls, log_sf2[0], Xmu, Xvar, Y, mask, kl_weight[0]
    )
    return (
        adj_p0[0] * p0
        + jnp.sum(adj_C * C)
        + jnp.sum(adj_D * D)
        + adj_kl[0] * kl
    )


def shard_grads(Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, kl_weight,
                adj_p0, adj_C, adj_D, adj_kl):
    """Partial gradients for one shard (paper §3.2 step 4 inputs).

    Returns (dZ [m,q], dlog_ls [q], dlog_sf2 [1], dXmu [B,q], dXvar [B,q]).
    dXvar is w.r.t. the raw variance s (the coordinator applies the
    log-reparameterisation chain rule: d/dlog s = s * d/ds).
    """
    g = jax.grad(_weighted_stats, argnums=(0, 1, 2, 3, 4))(
        Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, kl_weight,
        adj_p0, adj_C, adj_D, adj_kl,
    )
    return g


# --------------------------------------------------------------------------
# central direct term: Kmm and its pullback
# --------------------------------------------------------------------------

def kmm_grads(Z, log_ls, log_sf2, adj_Kmm):
    """Kmm plus the pullback of dF/dKmm onto the kernel parameters.

    Returns (Kmm [m,m], dZ [m,q], dlog_ls [q], dlog_sf2 [1]).
    """
    def inner(Z_, log_ls_, log_sf2_):
        K = ref.seard_kernel(Z_, Z_, log_ls_, log_sf2_[0])
        return jnp.sum(adj_Kmm * K), K

    (_, Kmm), grads = jax.value_and_grad(inner, argnums=(0, 1, 2),
                                         has_aux=True)(Z, log_ls, log_sf2)
    return (Kmm,) + grads


# --------------------------------------------------------------------------
# prediction
# --------------------------------------------------------------------------

def _psi2_per_point(Z, log_ls, log_sf2, Xmu, Xvar):
    """Psi2_i[j,k] for each test point — [B, m, m] (no data-sum)."""
    ls2 = jnp.exp(2.0 * log_ls)
    sf2 = jnp.exp(log_sf2)
    zbar = 0.5 * (Z[:, None, :] + Z[None, :, :])
    dz = Z[:, None, :] - Z[None, :, :]
    log_dist = -jnp.sum(dz * dz / (4.0 * ls2), axis=-1)
    denom = ls2[None, :] + 2.0 * Xvar
    log_scale = -0.5 * jnp.sum(jnp.log1p(2.0 * Xvar / ls2[None, :]), axis=1)
    diff = Xmu[:, None, None, :] - zbar[None, :, :, :]
    quad = jnp.sum(diff * diff / denom[:, None, None, :], axis=-1)
    return sf2 * sf2 * jnp.exp(log_scale[:, None, None] + log_dist[None] - quad)


def predict(Z, log_ls, log_sf2, Xt_mu, Xt_var, W1, Wv):
    """Sparse GP posterior at (possibly uncertain) test inputs.

    mean = Psi1* W1                      with W1 = beta Sigma^-1 C  [m, d]
    var  = psi0* - tr(Wv Psi2*_i)        with Wv = Kmm^-1 - Sigma^-1 [m, m]

    (observation noise 1/beta is added by the caller when wanted).
    Returns (mean [B, d], var [B]).
    """
    P1 = ref.psi1(Z, log_ls, log_sf2[0], Xt_mu, Xt_var)
    mean = P1 @ W1
    P2 = _psi2_per_point(Z, log_ls, log_sf2[0], Xt_mu, Xt_var)
    var = jnp.exp(log_sf2[0]) - jnp.einsum("bjk,jk->b", P2, Wv)
    return mean, var
