"""Pure-jnp oracle for the SE-ARD psi-statistics.

These are the expectations of kernel quantities under the variational
posterior q(X_i) = N(mu_i, diag(s_i)) that the paper's re-parametrised
bound is built from (supplementary material sections 3-4; see DESIGN.md §1):

    psi0      = sum_i <k(x_i, x_i)>_{q(X_i)}                  (scalar)
    Psi1[i,j] = <k(x_i, z_j)>_{q(X_i)}                        (n x m)
    Psi2      = sum_i <k(Z, x_i) k(x_i, Z)>_{q(X_i)}          (m x m)
    KL        = sum_i KL(q(X_i) || N(0, I))                   (scalar)

At s_i = 0 these reduce exactly to the Titsias (2009) regression
quantities: Psi1 = Knm, Psi2 = Kmn Knm, psi0 = n * sigma^2 — the
unification between sparse GP regression and the GPLVM the paper uses.

Everything here is the CORRECTNESS ORACLE: the Pallas kernel in
psi_stats.py must match these to ~1e-12 (f64), and the gradient artifact
is jax.grad through these expressions.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def seard_kernel(X1, X2, log_ls, log_sf2):
    """Plain SE-ARD kernel matrix k(X1, X2): sf2 * exp(-0.5 sum_q d_q^2/ls_q^2)."""
    ls2 = jnp.exp(2.0 * log_ls)  # [q]
    sf2 = jnp.exp(log_sf2)
    d = X1[:, None, :] - X2[None, :, :]  # [n1, n2, q]
    return sf2 * jnp.exp(-0.5 * jnp.sum(d * d / ls2, axis=-1))


def psi0(log_sf2, mask):
    """sum_i <k(x_i,x_i)> = sigma^2 per (live) point, for SE kernels."""
    return jnp.exp(log_sf2) * jnp.sum(mask)


def psi1(Z, log_ls, log_sf2, Xmu, Xvar):
    """Psi1[i,j] = <k(x_i, z_j)>_{N(x_i; mu_i, diag(s_i))}   [B, m].

    Psi1[i,j] = sf2 * prod_q (1 + s_iq/ls_q^2)^(-1/2)
                    * exp(-(mu_iq - z_jq)^2 / (2 (ls_q^2 + s_iq)))
    """
    ls2 = jnp.exp(2.0 * log_ls)  # [q]
    sf2 = jnp.exp(log_sf2)
    denom = ls2[None, :] + Xvar  # [B, q]
    # prod_q sqrt(ls2 / (ls2 + s)) == exp(-0.5 sum_q log(1 + s/ls2))
    scale = jnp.exp(-0.5 * jnp.sum(jnp.log1p(Xvar / ls2[None, :]), axis=1))  # [B]
    diff = Xmu[:, None, :] - Z[None, :, :]  # [B, m, q]
    quad = jnp.sum(diff * diff / denom[:, None, :], axis=-1)  # [B, m]
    return sf2 * scale[:, None] * jnp.exp(-0.5 * quad)


def psi2(Z, log_ls, log_sf2, Xmu, Xvar, mask):
    """Psi2 = sum_i mask_i <k(Z, x_i) k(x_i, Z)>   [m, m].

    Psi2_i[j,k] = sf2^2 * prod_q (1 + 2 s_iq/ls_q^2)^(-1/2)
                  * exp(-(z_jq - z_kq)^2/(4 ls_q^2)
                        - (mu_iq - zbar_q)^2/(ls_q^2 + 2 s_iq)),
    with zbar = (z_j + z_k)/2.
    """
    ls2 = jnp.exp(2.0 * log_ls)
    sf2 = jnp.exp(log_sf2)
    zbar = 0.5 * (Z[:, None, :] + Z[None, :, :])  # [m, m, q]
    dz = Z[:, None, :] - Z[None, :, :]
    log_dist = -jnp.sum(dz * dz / (4.0 * ls2), axis=-1)  # [m, m]
    denom = ls2[None, :] + 2.0 * Xvar  # [B, q]
    log_scale = -0.5 * jnp.sum(jnp.log1p(2.0 * Xvar / ls2[None, :]), axis=1)  # [B]
    diff = Xmu[:, None, None, :] - zbar[None, :, :, :]  # [B, m, m, q]
    quad = jnp.sum(diff * diff / denom[:, None, None, :], axis=-1)  # [B, m, m]
    contrib = sf2 * sf2 * jnp.exp(
        log_scale[:, None, None] + log_dist[None, :, :] - quad
    )
    return jnp.sum(mask[:, None, None] * contrib, axis=0)


def kl_term(Xmu, Xvar, mask, kl_weight):
    """sum_i mask_i KL(N(mu_i, diag(s_i)) || N(0, I)), gated by kl_weight.

    kl_weight = 0.0 selects the regression model (observed inputs, no KL);
    kl_weight = 1.0 selects the LVM. The safe-log guards s = 0 in the
    regression case (where the whole term is multiplied away anyway).
    """
    safe = jnp.where(Xvar > 0.0, Xvar, 1.0)
    per_point = 0.5 * jnp.sum(Xmu * Xmu + Xvar - jnp.log(safe) - 1.0, axis=1)
    return kl_weight * jnp.sum(mask * per_point)


def shard_stats_ref(Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, kl_weight):
    """Reference partial statistics for one shard (paper §3.2 map step 1).

    Returns (a, p0, C, D, kl):
      a  = sum_i mask_i |Y_i|^2        (scalar)
      p0 = psi0                        (scalar)
      C  = Psi1^T (mask * Y)           [m, d]
      D  = Psi2 (masked sum)           [m, m]
      kl = KL term                     (scalar)
    """
    Ym = Y * mask[:, None]
    a = jnp.sum(Ym * Y)
    p0 = psi0(log_sf2, mask)
    P1 = psi1(Z, log_ls, log_sf2, Xmu, Xvar)
    C = P1.T @ Ym
    D = psi2(Z, log_ls, log_sf2, Xmu, Xvar, mask)
    kl = kl_term(Xmu, Xvar, mask, kl_weight)
    return a, p0, C, D, kl
