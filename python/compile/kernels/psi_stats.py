"""Layer-1 Pallas kernel: fused psi-statistics for one data shard.

This is the hot spot of the paper's map step: O(B * m^2 * q) work per
shard, producing the constant-size partial statistics
(a, psi0, C = Psi1^T Y, D = Psi2, KL) that the coordinator reduces.

Hardware adaptation (DESIGN.md §2): the original GParML computed these
with NumPy broadcasting on CPU cores. For a TPU-shaped memory hierarchy we

  * stream data points HBM->VMEM in blocks of `block_n` rows via the
    BlockSpec grid (the inducing-point tensors Z, and the m x m / m x d
    accumulators stay resident in VMEM across the whole grid);
  * expand the Gaussian quadratic forms
        (mu - z)^2 / denom = mu^2/denom - 2 (mu/denom) z + (1/denom) z^2
    so the cross terms become [bn, q] @ [q, m] / [q, m^2] contractions —
    MXU-shaped matmuls instead of [bn, m, m, q] broadcast subtractions.
    This drops the per-block intermediate from O(bn m^2 q) to O(bn m^2)
    and puts ~all FLOPs on the systolic array;
  * accumulate all five statistics in-place across grid steps
    (initialised at program_id == 0), so the kernel emits exactly the
    constant-size message the paper's reduce step transmits.

interpret=True everywhere: the CPU PJRT runtime cannot execute Mosaic
custom-calls; numerics are validated against kernels/ref.py and real-TPU
performance is estimated analytically (EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _psi_stats_kernel(
    z_ref,        # [m, q]      resident
    log_ls_ref,   # [q]         resident
    log_sf2_ref,  # [1]         resident
    klw_ref,      # [1]         resident
    xmu_ref,      # [bn, q]     streamed
    xvar_ref,     # [bn, q]     streamed
    y_ref,        # [bn, d]     streamed
    mask_ref,     # [bn]        streamed
    a_ref,        # [1]         accumulator
    p0_ref,       # [1]         accumulator
    c_ref,        # [m, d]      accumulator
    d_ref,        # [m, m]      accumulator
    kl_ref,       # [1]         accumulator
):
    Z = z_ref[...]
    ls2 = jnp.exp(2.0 * log_ls_ref[...])          # [q]
    sf2 = jnp.exp(log_sf2_ref[0])
    klw = klw_ref[0]
    Xmu = xmu_ref[...]                            # [bn, q]
    Xvar = xvar_ref[...]                          # [bn, q]
    Y = y_ref[...]                                # [bn, d]
    mask = mask_ref[...]                          # [bn]
    m = Z.shape[0]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        a_ref[...] = jnp.zeros_like(a_ref)
        p0_ref[...] = jnp.zeros_like(p0_ref)
        c_ref[...] = jnp.zeros_like(c_ref)
        d_ref[...] = jnp.zeros_like(d_ref)
        kl_ref[...] = jnp.zeros_like(kl_ref)

    Ym = Y * mask[:, None]

    # --- a = sum_i mask_i |Y_i|^2 and psi0 = sf2 * sum_i mask_i ----------
    a_ref[...] += jnp.sum(Ym * Y)[None]
    p0_ref[...] += (sf2 * jnp.sum(mask))[None]

    # --- Psi1 block [bn, m], expanded quadratic => MXU contraction ------
    denom1 = ls2[None, :] + Xvar                  # [bn, q]
    w1 = 1.0 / denom1                             # [bn, q]
    scale1 = jnp.exp(-0.5 * jnp.sum(jnp.log1p(Xvar / ls2[None, :]), axis=1))
    r1 = jnp.sum(Xmu * Xmu * w1, axis=1)          # [bn]
    cross1 = (Xmu * w1) @ Z.T                     # [bn, m]  (MXU)
    zsq1 = w1 @ (Z * Z).T                         # [bn, m]  (MXU)
    quad1 = r1[:, None] - 2.0 * cross1 + zsq1
    psi1 = sf2 * scale1[:, None] * jnp.exp(-0.5 * quad1)

    # C += Psi1^T (mask * Y)   [m, d]  (MXU)
    c_ref[...] += psi1.T @ Ym

    # --- Psi2 block: sum_i mask_i Psi2_i [m, m] --------------------------
    zbar = 0.5 * (Z[:, None, :] + Z[None, :, :])  # [m, m, q]
    zb = zbar.reshape(m * m, Z.shape[1])          # [m^2, q]
    dz = Z[:, None, :] - Z[None, :, :]
    log_dist = -jnp.sum(dz * dz / (4.0 * ls2), axis=-1).reshape(m * m)
    denom2 = ls2[None, :] + 2.0 * Xvar            # [bn, q]
    w2 = 1.0 / denom2
    log_scale2 = -jnp.sum(jnp.log1p(2.0 * Xvar / ls2[None, :]), axis=1)  # [bn]
    r2 = jnp.sum(Xmu * Xmu * w2, axis=1)          # [bn]
    cross2 = (Xmu * w2) @ zb.T                    # [bn, m^2]  (MXU)
    zsq2 = w2 @ (zb * zb).T                       # [bn, m^2]  (MXU)
    quad2 = r2[:, None] - 2.0 * cross2 + zsq2
    contrib = jnp.exp(
        0.5 * log_scale2[:, None] + log_dist[None, :] - quad2
    )  # exp(log_scale2/... ) see note below
    # note: prod_q (1+2s/ls2)^(-1/2) = exp(-0.5 sum log1p(2s/ls2)); we folded
    # the -0.5 into log_scale2 by summing with weight -1 then halving here.
    d_ref[...] += (sf2 * sf2) * (mask @ contrib).reshape(m, m)

    # --- KL (gated; 0 in the regression case) ---------------------------
    safe = jnp.where(Xvar > 0.0, Xvar, 1.0)
    per_point = 0.5 * jnp.sum(Xmu * Xmu + Xvar - jnp.log(safe) - 1.0, axis=1)
    kl_ref[...] += (klw * jnp.sum(mask * per_point))[None]


@functools.partial(jax.jit, static_argnames=("block_n",))
def shard_stats_pallas(Z, log_ls, log_sf2, Xmu, Xvar, Y, mask, kl_weight,
                       block_n=None):
    """Fused shard statistics via the Pallas kernel.

    Shapes: Z [m,q], log_ls [q], log_sf2 [1], Xmu/Xvar [B,q], Y [B,d],
    mask [B], kl_weight [1].  B must be divisible by block_n.
    Returns (a [1], psi0 [1], C [m,d], D [m,m], kl [1]).
    """
    B, q = Xmu.shape
    m = Z.shape[0]
    d = Y.shape[1]
    bn = block_n or min(B, 128)
    assert B % bn == 0, f"B={B} not divisible by block_n={bn}"
    grid = (B // bn,)
    dt = Xmu.dtype

    resident = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    out_shapes = (
        jax.ShapeDtypeStruct((1,), dt),
        jax.ShapeDtypeStruct((1,), dt),
        jax.ShapeDtypeStruct((m, d), dt),
        jax.ShapeDtypeStruct((m, m), dt),
        jax.ShapeDtypeStruct((1,), dt),
    )
    return pl.pallas_call(
        _psi_stats_kernel,
        grid=grid,
        in_specs=[
            resident((m, q)),
            resident((q,)),
            resident((1,)),
            resident((1,)),
            pl.BlockSpec((bn, q), lambda i: (i, 0)),
            pl.BlockSpec((bn, q), lambda i: (i, 0)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            resident((1,)),
            resident((1,)),
            resident((m, d)),
            resident((m, m)),
            resident((1,)),
        ],
        out_shape=out_shapes,
        interpret=True,
    )(Z, log_ls, log_sf2, kl_weight, Xmu, Xvar, Y, mask)


def vmem_estimate_bytes(m, q, d, bn, itemsize=4):
    """Analytic VMEM footprint of one grid step (TPU sizing aid, f32).

    Resident: Z, accumulators C/D, zbar-derived [m^2, q] tables.
    Streamed per block: Xmu, Xvar, Y, mask, and the [bn, m^2] quad tile.
    """
    resident = m * q + m * d + m * m + 2 * (m * m * q) + m * m
    streamed = bn * (2 * q + d + 1) + 2 * bn * m + 2 * bn * m * m
    return (resident + streamed) * itemsize
