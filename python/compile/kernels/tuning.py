"""L1 performance model: block-size selection for the psi-statistics
Pallas kernel on a TPU-like memory hierarchy.

`interpret=True` wall-clock is CPU-numpy time, NOT a TPU proxy, so the
kernel is tuned structurally (DESIGN.md §6): this module models the VMEM
footprint and FLOP mix per grid step and picks the largest block size
that fits the VMEM budget with double buffering — larger blocks amortise
the resident Z/zbar tables and keep the [bn, q] x [q, m^2] contraction
MXU-shaped.

Usage: python -m compile.kernels.tuning [--m 64] [--q 2] [--d 3]
"""

import argparse

from .psi_stats import vmem_estimate_bytes

# per-core VMEM on TPU v4-class hardware
VMEM_BYTES = 16 * 1024 * 1024
# double buffering of streamed inputs halves the usable budget headroom
STREAM_OVERLAP_FACTOR = 2.0


def flops_per_block(m, q, d, bn):
    """Approximate FLOP count of one grid step (fused kernel)."""
    psi1_mm = 2 * bn * q * m * 2          # cross + zsq contractions
    psi1_ew = 8 * bn * m                   # exp/scale/mask
    c_acc = 2 * bn * m * d                 # Psi1^T Y
    psi2_mm = 2 * bn * q * m * m * 2       # cross2 + zsq2 contractions
    psi2_ew = 10 * bn * m * m              # exp + accumulation
    kl = 8 * bn * q
    return psi1_mm + psi1_ew + c_acc + psi2_mm + psi2_ew + kl


def mxu_fraction(m, q, d, bn):
    """Fraction of FLOPs landing on the systolic array (matmul-shaped)."""
    total = flops_per_block(m, q, d, bn)
    mm = 2 * bn * q * m * 2 + 2 * bn * m * d + 2 * bn * q * m * m * 2
    return mm / total


def pick_block_n(m, q, d, candidates=(32, 64, 128, 256, 512, 1024),
                 vmem=VMEM_BYTES, itemsize=4):
    """Largest candidate whose double-buffered footprint fits VMEM."""
    best = None
    rows = []
    for bn in candidates:
        bytes_needed = vmem_estimate_bytes(m, q, d, bn, itemsize)
        fits = bytes_needed * STREAM_OVERLAP_FACTOR <= vmem
        rows.append((bn, bytes_needed, fits, mxu_fraction(m, q, d, bn)))
        if fits:
            best = bn
    return best, rows


def report(m, q, d):
    best, rows = pick_block_n(m, q, d)
    print(f"psi-stats kernel sizing: m={m}, q={q}, d={d} (f32, 16MiB VMEM)")
    print(f"{'bn':>6} {'VMEM/step':>12} {'2x fits':>8} {'MXU frac':>9}")
    for bn, b, fits, frac in rows:
        print(f"{bn:>6} {b/2**20:>10.2f}Mi {str(fits):>8} {frac:>9.3f}")
    print(f"selected block_n = {best}")
    return best


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=64)
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument("--d", type=int, default=3)
    args = ap.parse_args()
    report(args.m, args.q, args.d)


if __name__ == "__main__":
    main()
