//! END-TO-END DRIVER: the full system on a real workload.
//!
//! Trains the distributed GPLVM/sparse-GP stack on the paper's synthetic
//! benchmark at a configurable scale (default 20K points — pass
//! `--n 100000` for the paper's headline size), with the full two-round
//! Map-Reduce protocol and distributed SCG, over either cluster
//! backend:
//!
//! * `--cluster threads` (default): worker nodes as OS threads;
//! * `--cluster tcp`: worker nodes as REAL spawned processes — this
//!   example re-executes itself in worker mode and drives the
//!   processes over the localhost wire protocol, reporting the
//!   constant-size network traffic per iteration.
//!
//! Logs the bound ("loss curve"), per-iteration load distribution,
//! modeled-parallel and measured times; writes results/e2e_run.csv
//! (recorded in EXPERIMENTS.md).
//!
//! ```sh
//! cargo run --release --example e2e_distributed -- \
//!     [--n 20000] [--workers 8] [--iters 20] [--model lvm|reg] [--cluster tcp]
//!     [--fill-threads N]
//! ```

use std::net::TcpListener;
use std::process::{Child, Command, Stdio};

use anyhow::{Context, Result};
use gparml::cluster::Backend;
use gparml::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use gparml::data::synthetic;
use gparml::gp::GlobalParams;
use gparml::linalg::Matrix;
use gparml::util::cli::Args;
use gparml::util::csv::CsvWriter;
use gparml::util::rng::Rng;
use gparml::util::stats;

fn main() -> Result<()> {
    let args = Args::from_env();

    // hidden worker mode: `--worker-connect ADDR` turns this very
    // binary into a cluster node (used by `--cluster tcp` below)
    if let Some(addr) = args.get("worker-connect") {
        let artifacts = gparml::runtime::default_artifacts_dir();
        gparml::cluster::node::run_worker_connect(addr, &artifacts, None, None, None)?;
        return Ok(());
    }

    // `--trace-out FILE`: record structured training spans (DESIGN.md §10)
    if let Some(path) = args.get("trace-out") {
        gparml::obs::trace::init(std::path::Path::new(path))?;
    }

    let n = args.get_usize("n", 20_000)?;
    let workers = args.get_usize("workers", 8)?;
    let iters = args.get_usize("iters", 20)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let lvm = args.get_str("model", "reg") == "lvm";
    let tcp = args.get_str("cluster", "threads") == "tcp";
    // `--fill-threads N`: intra-worker psi-fill parallelism (DESIGN.md
    // §11) — bit-identical at any value, negotiated in the Init frame
    let fill_threads = args.get_usize("fill-threads", 1)?.max(1);

    println!("=== gparml end-to-end driver ===");
    println!("dataset : {n} points, 1D latent -> 3D observations (paper §4.2)");
    println!(
        "cluster : {workers} worker nodes ({})",
        if tcp {
            "spawned processes over TCP"
        } else {
            "threads in-process"
        }
    );
    println!(
        "model   : {}",
        if lvm { "Bayesian GPLVM" } else { "sparse GP regression" }
    );

    let data = synthetic::generate(n, 0.05, seed);
    let mut rng = Rng::new(seed ^ 21);
    let (xmu, xvar, klw) = if lvm {
        // latent init: noisy observation of the truth (PCA-equivalent for
        // this linear+sine map, avoids an O(n d^2) PCA at 100K scale)
        (
            Matrix::from_fn(n, 2, |i, j| {
                if j == 0 {
                    data.latent[i] / 1.8 + 0.1 * rng.normal()
                } else {
                    0.3 * rng.normal()
                }
            }),
            Matrix::from_fn(n, 2, |_, _| 0.5),
            1.0,
        )
    } else {
        (
            Matrix::from_fn(n, 2, |i, j| {
                if j == 0 {
                    data.latent[i]
                } else {
                    0.1 * rng.normal()
                }
            }),
            Matrix::zeros(n, 2),
            0.0,
        )
    };

    let mut prng = Rng::new(seed ^ 4);
    let params = GlobalParams {
        z: Matrix::from_fn(64, 2, |_, _| prng.range(-3.0, 3.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let shards = partition(&xmu, &xvar, &data.y, klw, workers);
    let cfg = TrainConfig {
        artifact: "perf".into(),
        workers,
        model: if lvm { ModelKind::Lvm } else { ModelKind::Regression },
        global_opt: GlobalOpt::Scg,
        fill_threads,
        seed,
        ..Default::default()
    };

    if tcp {
        let listener = TcpListener::bind("127.0.0.1:0").context("binding leader port")?;
        let addr = listener.local_addr()?.to_string();
        println!("leader  : listening on {addr}, spawning {workers} worker processes");
        let me = std::env::current_exe().context("locating own binary")?;
        let procs: Vec<Child> = (0..workers)
            .map(|_| {
                Command::new(&me)
                    .args(["--worker-connect", &addr])
                    .stdout(Stdio::null())
                    .spawn()
                    .context("spawning worker process")
            })
            .collect::<Result<_>>()?;
        let t = Trainer::accept_tcp(cfg, params, shards, &listener)?;
        let result = run(t, n, iters, lvm, seed);
        for mut p in procs {
            let _ = p.wait();
        }
        gparml::obs::trace::flush();
        return result;
    }

    let t = Trainer::new(cfg, params, shards)?;
    let result = run(t, n, iters, lvm, seed);
    gparml::obs::trace::flush();
    result
}

fn run<B: Backend>(mut t: Trainer<B>, n: usize, iters: usize, lvm: bool, seed: u64) -> Result<()> {
    println!(
        "startup (node state + executor construction): {:.2}s\n",
        t.log.startup_secs
    );

    println!(
        "{:>5} {:>16} {:>12} {:>12} {:>12} {:>8} {:>12}",
        "iter", "bound F", "modeled(s)", "compute(s)", "wall(s)", "gap%", "net KiB"
    );
    let mut csv = CsvWriter::new(&[
        "iter",
        "bound",
        "modeled_parallel_s",
        "total_compute_s",
        "measured_wall_s",
        "load_gap_pct",
        "net_bytes",
    ]);
    for i in 0..iters {
        let f = t.step()?;
        let it = t.log.iterations.last().unwrap();
        let (_, mean, max) = it.load_min_mean_max();
        let gap = if mean > 0.0 { (max - mean) / mean * 100.0 } else { 0.0 };
        let (tx, rx) = it.network_bytes();
        println!(
            "{:>5} {:>16.2} {:>12.4} {:>12.4} {:>12.4} {:>8.2} {:>12.1}",
            i,
            f,
            it.modeled_parallel_secs(),
            it.total_compute_secs(),
            it.measured_wall_secs(),
            gap,
            (tx + rx) as f64 / 1024.0
        );
        csv.row(&[
            i as f64,
            f,
            it.modeled_parallel_secs(),
            it.total_compute_secs(),
            it.measured_wall_secs(),
            gap,
            (tx + rx) as f64,
        ]);
    }

    let f0 = t.log.iterations.first().unwrap().f;
    let f1 = t.log.final_bound();
    let per_iter = t.log.mean_iteration_modeled_secs();
    let throughput = n as f64 / per_iter;
    println!("\nsummary:");
    println!("  bound: {f0:.2} -> {f1:.2} over {iters} iterations");
    println!("  mean modeled-parallel iteration: {per_iter:.4}s");
    println!(
        "  point-throughput (modeled): {:.0} points/s through the full two-round protocol",
        throughput
    );
    println!(
        "  mean load gap (max vs mean worker): {:.2}%",
        t.log.mean_load_gap() * 100.0
    );
    let (tx, rx) = t.log.total_network_bytes();
    if tx + rx > 0 {
        println!(
            "  network total: {:.1} KiB out, {:.1} KiB in — constant per iteration, \
             independent of n (paper requirement 3)",
            tx as f64 / 1024.0,
            rx as f64 / 1024.0
        );
    }

    // fit quality on a held-out slice
    let nt = 500.min(n / 10);
    if !lvm {
        let test = synthetic::generate(nt, 0.0, seed ^ 0x7E57);
        let xt_true = Matrix::from_fn(nt, 2, |i, j| {
            if j == 0 {
                test.latent[i]
            } else {
                0.0
            }
        });
        let (mean, _) = t.predict(&xt_true, &Matrix::zeros(nt, 2))?;
        let mut se = Vec::new();
        for i in 0..nt {
            for j in 0..3 {
                se.push((mean[(i, j)] - test.y[(i, j)]).powi(2));
            }
        }
        println!("  held-out RMSE: {:.4}", stats::mean(&se).sqrt());
    }

    let path = std::path::Path::new("results/e2e_run.csv");
    csv.save(path)?;
    println!("  loss curve -> {}", path.display());
    assert!(f1 > f0, "end-to-end training must improve the bound");
    println!("e2e_distributed OK");
    Ok(())
}
