//! Quickstart: distributed sparse GP regression in ~40 lines, plus the
//! train → export → predict story.
//!
//! Fits y = sin(1.5 x) + noise with 4 worker nodes, prints the bound as
//! it improves, evaluates test RMSE with calibrated error bars, then
//! exports the trained model to a file and serves the same predictions
//! from a standalone `Predictor` — no cluster, bit-identical results.
//! Finally the same dataset is packed into an on-disk sharded store and
//! the whole training run is reproduced bit-for-bit from a streamed
//! bring-up (DESIGN.md §13) — the out-of-core path for datasets bigger
//! than leader RAM. The CLI equivalent:
//!
//! ```sh
//! gparml data pack --gen synthetic --n 800 --out store/   # write shards
//! gparml data inspect --store store/ --verify             # checksums
//! gparml train --store store/ --chunk-rows 4096 ...       # stream it
//! ```
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Hacking on the repo? `gparml analyze` lints the sources against the
//! standing contracts (determinism, panic-freedom, wire totality —
//! DESIGN.md §14) and is a blocking CI job; run it before pushing.

use anyhow::Result;
use gparml::coordinator::{partition, GlobalOpt, ModelKind, StreamConfig, TrainConfig, Trainer};
use gparml::gp::GlobalParams;
use gparml::linalg::Matrix;
use gparml::model::{Predictor, TrainedModel};
use gparml::store::{ShardedDiskSource, SplitColumns, StoreWriter};
use gparml::util::rng::Rng;

fn main() -> Result<()> {
    let n = 800;
    let mut rng = Rng::new(0);

    // toy data: q = 2 inputs (second dim irrelevant), d = 3 outputs
    let x = Matrix::from_fn(n, 2, |_, _| rng.range(-3.0, 3.0));
    let y = Matrix::from_fn(n, 3, |i, j| {
        (1.5 * x[(i, 0)] + j as f64).sin() + 0.1 * rng.normal()
    });

    // 16 inducing points (the "small" artifact config: m=16, q=2, d=3)
    let params = GlobalParams {
        z: Matrix::from_fn(16, 2, |_, _| rng.range(-3.0, 3.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    };

    // shard the data over 4 worker nodes and train with distributed SCG
    let shards = partition(&x, &Matrix::zeros(n, 2), &y, 0.0, 4);
    let cfg = TrainConfig {
        artifact: "small".into(),
        workers: 4,
        model: ModelKind::Regression,
        global_opt: GlobalOpt::Scg,
        ..Default::default()
    };
    let mut trainer = Trainer::new(cfg.clone(), params.clone(), shards)?;
    let mut trace = Vec::with_capacity(25);
    for it in 0..25 {
        let f = trainer.step()?;
        trace.push(f);
        if it % 5 == 0 || it == 24 {
            println!("iter {it:>3}: bound F = {f:.2}");
        }
    }

    // held-out predictions
    let nt = 200;
    let xt = Matrix::from_fn(nt, 2, |_, _| rng.range(-3.0, 3.0));
    let yt = Matrix::from_fn(nt, 3, |i, j| (1.5 * xt[(i, 0)] + j as f64).sin());
    let (mean, var) = trainer.predict(&xt, &Matrix::zeros(nt, 2))?;
    let mut se = 0.0;
    let mut calibrated = 0usize;
    let noise = (-trainer.params.log_beta).exp();
    for i in 0..nt {
        for j in 0..3 {
            let r: f64 = mean[(i, j)] - yt[(i, j)];
            se += r * r;
            if r.abs() < 3.0 * (var[i] + noise).sqrt() {
                calibrated += 1;
            }
        }
    }
    let rmse = (se / (nt * 3) as f64).sqrt();
    println!("test RMSE: {rmse:.4} (noise level 0.1)");
    println!(
        "|error| < 3 sigma for {:.1}% of test points",
        100.0 * calibrated as f64 / (nt * 3) as f64
    );
    println!(
        "learned: lengthscales {:?}, noise std {:.3}",
        trainer
            .params
            .lengthscales()
            .iter()
            .map(|l| (l * 100.0).round() / 100.0)
            .collect::<Vec<_>>(),
        noise.sqrt()
    );
    assert!(rmse < 0.2, "quickstart should fit this function");

    // ---- train/serve split: export the artifact, predict without a
    // cluster (DESIGN.md §9). The file holds the global parameters and
    // the posterior weights over the 16 inducing points — a few KB,
    // independent of the 800 training points.
    let model_path = std::env::temp_dir().join("quickstart_model.gpm");
    trainer.export_model()?.save(&model_path)?;
    let final_params = trainer.params.flatten();
    drop(trainer); // the training cluster is gone from here on

    let model = TrainedModel::load(&model_path)?;
    let predictor = Predictor::new(&model)?;
    let (mean2, var2) = predictor.predict(&xt, &Matrix::zeros(nt, 2))?;
    for i in 0..nt {
        for j in 0..3 {
            assert_eq!(
                mean[(i, j)].to_bits(),
                mean2[(i, j)].to_bits(),
                "standalone predictor diverged from the cluster"
            );
        }
        assert_eq!(var[i].to_bits(), var2[i].to_bits());
    }
    println!(
        "exported {} ({} bytes) and re-served {nt} predictions bit-identically without a cluster",
        model_path.display(),
        std::fs::metadata(&model_path)?.len()
    );
    std::fs::remove_file(&model_path).ok();

    // ---- out-of-core bring-up (DESIGN.md §13): pack the same dataset
    // into a checksummed sharded store on disk, then rebuild the WHOLE
    // training run by streaming it back chunk-by-chunk — the leader
    // holds at most chunk_rows rows at once, yet the trace is
    // bit-identical to the in-memory run above.
    let store_dir = std::env::temp_dir().join("quickstart_store");
    std::fs::remove_dir_all(&store_dir).ok();
    let full = Matrix::from_fn(n, 5, |i, j| if j < 2 { x[(i, j)] } else { y[(i, j - 2)] });
    let mut w = StoreWriter::create(&store_dir, 2, 256, None)?;
    w.append(&full)?;
    let man = w.finish()?;
    let src = ShardedDiskSource::open(&store_dir)?;
    let verified = src.verify()?;
    println!(
        "packed {} rows into {} shard(s), {verified} bytes checksum-verified",
        man.n,
        man.shards.len()
    );
    let mapper = SplitColumns { x_cols: 2 };
    let stream = StreamConfig {
        source: &src,
        mapper: &mapper,
        chunk_rows: 128,
        kl_weight: 0.0,
        shard_refs: None,
    };
    let mut streamed = Trainer::new_streaming(cfg, params, &stream)?;
    for (it, f) in trace.iter().enumerate() {
        let g = streamed.step()?;
        assert_eq!(
            f.to_bits(),
            g.to_bits(),
            "streamed iteration {it} diverged from the in-memory run"
        );
    }
    for (a, b) in final_params.iter().zip(streamed.params.flatten()) {
        assert_eq!(a.to_bits(), b.to_bits(), "streamed final params diverged");
    }
    println!("re-trained {} iterations from the on-disk store bit-identically", trace.len());
    std::fs::remove_dir_all(&store_dir).ok();
    println!("quickstart OK");
    Ok(())
}
