//! Digit reconstruction (paper §4.5 / Fig. 6): train a GPLVM density
//! model over (synthetic) 16x16 digits, then reconstruct test digits
//! with 34% of their pixels missing and render the results as ASCII art.
//!
//! ```sh
//! make artifacts && cargo run --release --example usps_reconstruct
//! ```

use anyhow::Result;
use gparml::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use gparml::data::{digits, kmeans, pca};
use gparml::experiments::fig6_digits::reconstruct;
use gparml::gp::GlobalParams;
use gparml::linalg::Matrix;
use gparml::util::rng::Rng;

fn render(tag: &str, img: &[f64]) {
    println!("  {tag}:");
    for row in 0..digits::SIDE {
        let line: String = (0..digits::SIDE)
            .map(|c| {
                let v = img[row * digits::SIDE + c];
                match v {
                    v if v > 0.66 => '#',
                    v if v > 0.33 => '+',
                    v if v > 0.12 => '.',
                    _ => ' ',
                }
            })
            .collect();
        println!("    {line}");
    }
}

fn main() -> Result<()> {
    let n = 300;
    let (m, q, workers) = (48, 8, 3);
    let data = digits::generate(n, 0.02, 0);
    println!("training GPLVM on {n} synthetic digits (16x16)...");

    let p = pca::pca(&data.y, q, 40, 1);
    let xmu = pca::whitened_scores(&p);
    let xvar = Matrix::from_fn(n, q, |_, _| 0.5);
    let mut rng = Rng::new(2);
    let z = kmeans::inducing_init(&xmu, m, 0.05, &mut rng);
    let params = GlobalParams {
        z,
        log_ls: vec![0.0; q],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let shards = partition(&xmu, &xvar, &data.y, 1.0, workers);
    let cfg = TrainConfig {
        artifact: "digits".into(),
        workers,
        model: ModelKind::Lvm,
        global_opt: GlobalOpt::Scg,
        local_lr: 0.05,
        ..Default::default()
    };
    let mut trainer = Trainer::new(cfg, params, shards)?;
    for it in 0..20 {
        let f = trainer.step()?;
        if it % 5 == 0 || it == 19 {
            println!("iter {it:>3}: bound F = {f:.0}");
        }
    }

    // gather training latents for reconstruction inits (scattered back
    // to dataset order via the gathered row indices)
    let locals = trainer.gather_locals()?;
    let mut latents = Matrix::zeros(n, q);
    for (ids, mu, _) in &locals {
        for (i, &orig) in ids.iter().enumerate() {
            latents.row_mut(orig).copy_from_slice(mu.row(i));
        }
    }
    let weights = trainer.posterior()?;

    // reconstruct unseen digits with 34% of pixels dropped
    let test = digits::generate(12, 0.02, 99);
    let mut rng = Rng::new(5);
    let mut total_err = 0.0;
    for i in 0..3 {
        let image: Vec<f64> = test.y.row(i).to_vec();
        let (obs, kept) = digits::drop_pixels(&image, 0.34, &mut rng);
        let rec = reconstruct(
            &trainer.params,
            &weights,
            &latents,
            &data.y,
            &obs,
            &kept,
            60,
        );
        let mut err = 0.0;
        let mut cnt = 0;
        for (p, k) in kept.iter().enumerate() {
            if !*k {
                err += (rec[p] - image[p]).abs();
                cnt += 1;
            }
        }
        total_err += err / cnt as f64;
        println!("\ndigit {} with 34% pixels dropped:", test.labels[i]);
        render("input (dropped pixels blank)", &obs);
        render("reconstruction", &rec);
        render("ground truth", &image);
    }
    println!(
        "\nmean reconstruction error on dropped pixels: {:.4}",
        total_err / 3.0
    );
    println!("usps_reconstruct OK");
    Ok(())
}
