//! GPLVM on the oil-flow-like dataset: non-linear dimensionality
//! reduction with automatic relevance determination, distributed over
//! worker nodes (paper §4.4).
//!
//! ```sh
//! make artifacts && cargo run --release --example gplvm_oilflow
//! ```

use anyhow::Result;
use gparml::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use gparml::data::{kmeans, oilflow, pca};
use gparml::gp::GlobalParams;
use gparml::linalg::Matrix;
use gparml::util::rng::Rng;

fn main() -> Result<()> {
    let n = 450;
    let (m, q, workers) = (32, 6, 3);
    let data = oilflow::generate(n, 0);
    println!("oil-flow-like data: {n} x 12, 3 flow regimes");

    // paper §4.1 initialisation: PCA latents, k-means inducing points
    let p = pca::pca(&data.y, q, 50, 1);
    let xmu = pca::whitened_scores(&p);
    let xvar = Matrix::from_fn(n, q, |_, _| 0.5);
    let mut rng = Rng::new(2);
    let z = kmeans::inducing_init(&xmu, m, 0.05, &mut rng);
    let params = GlobalParams {
        z,
        log_ls: vec![0.0; q],
        log_sf2: 0.0,
        log_beta: 1.0,
    };

    let shards = partition(&xmu, &xvar, &data.y, 1.0, workers);
    let cfg = TrainConfig {
        artifact: "oil".into(),
        workers,
        model: ModelKind::Lvm,
        global_opt: GlobalOpt::Scg,
        local_lr: 0.05,
        ..Default::default()
    };
    let mut trainer = Trainer::new(cfg, params, shards)?;
    for it in 0..30 {
        let f = trainer.step()?;
        if it % 5 == 0 || it == 29 {
            println!("iter {it:>3}: bound F = {f:.1}");
        }
    }

    // inspect the ARD profile: which latent dimensions survived?
    let inv_ls2: Vec<f64> = trainer
        .params
        .log_ls
        .iter()
        .map(|l| (-2.0 * l).exp())
        .collect();
    let max = inv_ls2.iter().cloned().fold(f64::MIN, f64::max);
    println!("ARD relevances (1/l^2, normalised):");
    for (d, v) in inv_ls2.iter().enumerate() {
        let rel = v / max;
        let bar = "#".repeat((rel * 40.0) as usize);
        println!("  dim {d}: {rel:>6.3} {bar}");
    }

    // embedding quality: class separation in the learned latent space
    // (rows scattered back to dataset order via the gathered indices)
    let locals = trainer.gather_locals()?;
    let mut emb = Matrix::zeros(n, q);
    for (ids, mu, _) in &locals {
        for (i, &orig) in ids.iter().enumerate() {
            emb.row_mut(orig).copy_from_slice(mu.row(i));
        }
    }
    let sep = gparml::experiments::common::class_separation(&emb, &data.labels);
    let sep_pca = gparml::experiments::common::class_separation(&xmu, &data.labels);
    println!("class separation (between/within scatter): GPLVM {sep:.3} vs PCA-init {sep_pca:.3}");
    println!("gplvm_oilflow OK");
    Ok(())
}
