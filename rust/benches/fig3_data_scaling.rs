//! Bench: paper Fig. 3 — time per iteration with data scaled
//! proportionally to workers (weak scaling), plus the sequential path.

use gparml::experiments::fig2_core_scaling::measure;
use gparml::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let base_n = args.get_usize("base-n", 1_000).unwrap();
    let iters = args.get_usize("iters", 2).unwrap();
    println!("fig3 bench: weak scaling, n = {base_n} x workers");
    println!(
        "{:>8} {:>9} {:>18} {:>18}",
        "workers", "n", "modeled par (s)", "per-worker map (s)"
    );
    let mut first: Option<f64> = None;
    for workers in [1usize, 2, 4, 8, 16] {
        let n = base_n * workers;
        let (p, _) = measure(&args, n, workers, iters, 0).expect("measure");
        println!(
            "{:>8} {:>9} {:>18.4} {:>18.4}",
            workers,
            n,
            p.modeled_parallel,
            p.total_compute / workers as f64
        );
        let f = *first.get_or_insert(p.modeled_parallel);
        if workers > 1 {
            println!(
                "{:>8}   growth vs ideal-constant: {:+.1}%",
                "",
                (p.modeled_parallel / f - 1.0) * 100.0
            );
        }
    }
}
