//! Hot-path microbenchmarks (the §Perf inventory in EXPERIMENTS.md):
//! artifact execution latency per entry and per config, literal
//! marshalling, the native O(m^3) global step, and the pure-native
//! statistics for comparison.

use std::path::PathBuf;

use gparml::gp::{self, kernel, GlobalParams};
use gparml::linalg::{Cholesky, Matrix};
use gparml::runtime::{Manifest, ShardData, ShardExecutor};
use gparml::util::bench::bench;
use gparml::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    std::env::var_os("GPARML_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn random_params(m: usize, q: usize, seed: u64) -> GlobalParams {
    let mut rng = Rng::new(seed);
    GlobalParams {
        z: Matrix::from_fn(m, q, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0; q],
        log_sf2: 0.0,
        log_beta: 1.0,
    }
}

fn random_shard(b: usize, q: usize, d: usize, lvm: bool, seed: u64) -> ShardData {
    let mut rng = Rng::new(seed);
    ShardData {
        xmu: Matrix::from_fn(b, q, |_, _| rng.normal()),
        xvar: if lvm {
            Matrix::from_fn(b, q, |_, _| 0.1 + rng.uniform())
        } else {
            Matrix::zeros(b, q)
        },
        y: Matrix::from_fn(b, d, |_, _| rng.normal()),
        kl_weight: if lvm { 1.0 } else { 0.0 },
    }
}

fn main() {
    let manifest = Manifest::load(&artifacts_dir()).expect("run `make artifacts`");
    println!("== artifact execution latency (per shard pass) ==");
    for cfg_name in ["small", "perf", "oil"] {
        let exec = ShardExecutor::new(&manifest, cfg_name).expect("compile");
        let c = exec.config().clone();
        let params = random_params(c.m, c.q, 1);
        let shard = random_shard(c.cap, c.q, c.d, true, 2);
        let kmm = kernel::kmm(&params, 1e-6);

        let stats = exec.shard_stats(&params, &shard).unwrap();
        let (_, adj) = gp::assemble_bound(&stats, &kmm, params.log_beta, c.d).unwrap();
        bench(
            &format!("{cfg_name}: shard_stats (B={}, m={})", c.cap, c.m),
            2,
            10,
            || exec.shard_stats(&params, &shard).unwrap(),
        );
        bench(&format!("{cfg_name}: shard_grads"), 2, 10, || {
            exec.shard_grads(&params, &shard, &adj).unwrap()
        });
        // the workspace pipeline: round 1 fills the executor scratch,
        // round 2 consumes it — one psi pass per evaluation
        let mut version = 0u64;
        bench(&format!("{cfg_name}: eval cached (stats+grads)"), 2, 10, || {
            version += 1;
            let tok = exec.begin_eval(version);
            let st = exec.shard_stats_cached(&tok, &params, &shard).unwrap();
            let g = exec.shard_grads_cached(&tok, &params, &shard, &adj).unwrap();
            (st, g)
        });
        bench(&format!("{cfg_name}: eval nocache (stats+grads)"), 2, 10, || {
            (
                exec.shard_stats(&params, &shard).unwrap(),
                exec.shard_grads(&params, &shard, &adj).unwrap(),
            )
        });
        bench(&format!("{cfg_name}: kmm_grads"), 2, 10, || {
            exec.kmm_grads(&params, &adj.d_kmm).unwrap()
        });

        // native mirror for the same shard (what the pre-AOT world costs)
        bench(&format!("{cfg_name}: native shard_stats"), 1, 3, || {
            kernel::shard_stats(
                &params,
                &shard.xmu,
                &shard.xvar,
                &shard.y,
                &vec![1.0; shard.len()],
                1.0,
            )
        });
    }

    println!("\n== central global step (O(m^3), constant in n) ==");
    for m in [16usize, 32, 64, 128] {
        let params = random_params(m, 2, 3);
        let shard = random_shard(256, 2, 3, true, 4);
        let stats = kernel::shard_stats(
            &params,
            &shard.xmu,
            &shard.xvar,
            &shard.y,
            &vec![1.0; 256],
            1.0,
        );
        let kmm = kernel::kmm(&params, 1e-6);
        bench(&format!("assemble_bound m={m}"), 2, 20, || {
            gp::assemble_bound(&stats, &kmm, params.log_beta, 3).unwrap()
        });
        bench(&format!("cholesky m={m}"), 2, 20, || {
            Cholesky::new(&kmm).unwrap()
        });
        bench(&format!("kmm_vjp m={m}"), 2, 20, || {
            kernel::kmm_vjp(&params, &kmm)
        });
    }

    println!("\n== linalg primitives ==");
    let mut rng = Rng::new(7);
    for m in [64usize, 128, 256] {
        let a = Matrix::from_fn(m, m, |_, _| rng.normal());
        let b = Matrix::from_fn(m, m, |_, _| rng.normal());
        bench(&format!("matmul {m}x{m}"), 2, 10, || a.matmul(&b));
    }
}
