//! Bench: paper Fig. 5 — min/mean/max worker execution time per
//! iteration (the reduce barrier waits for the max).

use gparml::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use gparml::data::synthetic;
use gparml::gp::GlobalParams;
use gparml::linalg::Matrix;
use gparml::util::cli::Args;
use gparml::util::rng::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let n = args.get_usize("n", 8_000).unwrap();
    let iters = args.get_usize("iters", 4).unwrap();
    for workers in [5usize, 10] {
        let data = synthetic::generate(n, 0.05, 0);
        let mut rng = Rng::new(9);
        let xmu = Matrix::from_fn(n, 2, |i, j| {
            if j == 0 {
                data.latent[i]
            } else {
                0.1 * rng.normal()
            }
        });
        let shards = partition(&xmu, &Matrix::zeros(n, 2), &data.y, 0.0, workers);
        let params = GlobalParams {
            z: Matrix::from_fn(64, 2, |_, _| rng.range(-3.0, 3.0)),
            log_ls: vec![0.0, 0.0],
            log_sf2: 0.0,
            log_beta: 1.0,
        };
        let cfg = TrainConfig {
            artifact: "perf".into(),
            workers,
            model: ModelKind::Regression,
            global_opt: GlobalOpt::Scg,
            ..Default::default()
        };
        let mut t = Trainer::new(cfg, params, shards).expect("trainer");
        t.train(1).unwrap();
        t.log.iterations.clear();
        t.train(iters).unwrap();
        println!("fig5 bench: n={n}, workers={workers}");
        for it in &t.log.iterations {
            let (mn, mean, mx) = it.load_min_mean_max();
            println!(
                "  iter {:>3}: min {:.5}s mean {:.5}s max {:.5}s",
                it.iter, mn, mean, mx
            );
        }
        println!(
            "  mean (max-mean)/mean gap: {:.2}%  (paper: 3.7%)",
            t.log.mean_load_gap() * 100.0
        );
    }
}
