//! Bench: paper Fig. 2 — per-iteration time vs worker count at a fixed
//! dataset size (scaled down from 100K for bench time; run
//! `gparml experiment fig2 --n 100000` for the full version).

use gparml::experiments::fig2_core_scaling::measure;
use gparml::util::cli::Args;

fn main() {
    // cargo bench passes --bench; ignore unknown flags
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let n = args.get_usize("n", 8_000).unwrap();
    let iters = args.get_usize("iters", 2).unwrap();
    println!("fig2 bench: n={n}, iters={iters} (per-iteration means)");
    println!(
        "{:>8} {:>18} {:>18} {:>14}",
        "workers", "modeled par (s)", "map compute (s)", "wall (s)"
    );
    let mut baseline = None;
    for workers in [1usize, 2, 5, 10, 20] {
        let (p, _) = measure(&args, n, workers, iters, 0).expect("measure");
        println!(
            "{:>8} {:>18.4} {:>18.4} {:>14.4}",
            workers, p.modeled_parallel, p.total_compute, p.measured_wall
        );
        let base = *baseline.get_or_insert(p.modeled_parallel);
        if workers > 1 {
            println!(
                "{:>8}   speedup vs 1 worker: {:.2}x (ideal {:.0}x)",
                "", base / p.modeled_parallel, workers as f64
            );
        }
    }
}
