//! # GParML-RS
//!
//! Distributed variational inference for sparse Gaussian process regression
//! and the Bayesian GP latent variable model (GPLVM), reproducing
//! *Gal, van der Wilk & Rasmussen (2014)* as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3** (this crate): the paper's contribution — a leader/worker
//!   Map-Reduce coordinator with distributed scaled-conjugate-gradient
//!   optimisation, constant-size global messages, load accounting and
//!   node-failure tolerance ([`coordinator`], [`cluster`], [`mapreduce`],
//!   [`optim`]). The cluster layer runs the same protocol over OS
//!   threads or real worker processes on TCP (`gparml worker`).
//! * **Layer 2**: per-shard statistic/gradient graphs authored in JAX,
//!   AOT-lowered to HLO text at build time (`python/compile/`), executed
//!   here via PJRT ([`runtime`]).
//! * **Layer 1**: the fused psi-statistics Pallas kernel inside the
//!   Layer-2 graphs (`python/compile/kernels/psi_stats.py`).
//!
//! The native [`gp`] module owns the constant-size global step (the
//! collapsed bound of eq. 3.3 and its hand-derived adjoints) plus a full
//! native fallback used by the [`baselines`]. The [`model`] module is
//! the train/serve split: a serializable [`model::TrainedModel`]
//! artifact exported by the trainer, a cluster-free `Send + Sync`
//! [`model::Predictor`], and the `gparml export/predict/serve` CLI
//! story built on them (DESIGN.md §9). See `DESIGN.md` for the system
//! inventory and the experiment index.

pub mod analyze;
pub mod baselines;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod fleet;
pub mod gp;
pub mod linalg;
pub mod mapreduce;
pub mod model;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod store;
pub mod telemetry;
pub mod testing;
pub mod util;
