//! Adam — used for the workers' local q(X) parameter updates (the paper
//! allows "parallelising SCG or using local gradient descent"; adaptive
//! steps are the modern equivalent) and as an ablation optimiser for the
//! global step.

/// Adam optimiser state over a flat parameter vector.
#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(dim: usize, lr: f64) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t: 0,
        }
    }

    /// In-place descent step: params -= lr * mhat / (sqrt(vhat) + eps).
    pub fn step(&mut self, params: &mut [f64], grad: &[f64]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        let mut x = vec![5.0, -4.0];
        let mut adam = Adam::new(2, 0.1);
        for _ in 0..500 {
            let g = vec![2.0 * (x[0] - 1.0), 2.0 * (x[1] + 2.0)];
            adam.step(&mut x, &g);
        }
        assert!((x[0] - 1.0).abs() < 1e-3);
        assert!((x[1] + 2.0).abs() < 1e-3);
    }

    #[test]
    fn step_size_bounded_by_lr() {
        let mut x = vec![0.0];
        let mut adam = Adam::new(1, 0.01);
        adam.step(&mut x, &[1e9]);
        // Adam normalises the step to ~lr regardless of gradient scale
        assert!(x[0].abs() < 0.011);
    }
}
