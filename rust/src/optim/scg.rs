//! Scaled Conjugate Gradients (Møller, 1993) — the optimiser the paper
//! uses for the global parameters, with the finite-difference curvature
//! probe the paper's Fig. 7 discussion refers to (it is this probe that
//! makes SCG sensitive to noisy gradients under node failure).
//!
//! The implementation keeps its state across [`Scg::step`] calls so the
//! trainer can interleave distributed function evaluations with local
//! worker updates; each `step` performs one SCG iteration and calls the
//! objective 1-2 times (curvature probe + candidate evaluation).

use super::{dot, norm_sq, Objective};

/// Outcome of one SCG iteration.
#[derive(Debug, Clone)]
pub struct ScgStep {
    /// Objective value at the (possibly unchanged) current point.
    pub f: f64,
    /// Whether the candidate step was accepted.
    pub accepted: bool,
    /// |gradient|^2 at the current point.
    pub grad_norm_sq: f64,
}

/// Møller's SCG state.
pub struct Scg {
    w: Vec<f64>,
    f: f64,
    r: Vec<f64>, // -grad at w
    p: Vec<f64>, // search direction
    lambda: f64,
    lambda_bar: f64,
    success: bool,
    k: usize,
    sigma0: f64,
    fresh: bool,
    /// curvature from the last probe (reused while success == false)
    last_delta: f64,
}

impl Scg {
    /// Initialise at `w0`; evaluates the objective once.
    pub fn new(w0: Vec<f64>, obj: &mut impl Objective) -> Scg {
        let (f, g) = obj.value_grad(&w0);
        let r: Vec<f64> = g.iter().map(|x| -x).collect();
        Scg {
            p: r.clone(),
            r,
            w: w0,
            f,
            lambda: 1e-6,
            lambda_bar: 0.0,
            success: true,
            k: 0,
            sigma0: 1e-5,
            fresh: true,
            last_delta: 1.0,
        }
    }

    pub fn x(&self) -> &[f64] {
        &self.w
    }

    pub fn f(&self) -> f64 {
        self.f
    }

    /// Re-evaluate f and the gradient at the current point (needed when
    /// the objective itself changed between steps, e.g. the workers
    /// updated their local parameters or a node failed).
    pub fn refresh(&mut self, obj: &mut impl Objective) {
        let (f, g) = obj.value_grad(&self.w);
        self.f = f;
        self.r = g.iter().map(|x| -x).collect();
        if !self.success || self.fresh {
            self.p = self.r.clone();
        }
        self.fresh = false;
    }

    /// One SCG iteration (Møller 1993, steps 2-9).
    pub fn step(&mut self, obj: &mut impl Objective) -> ScgStep {
        self.fresh = false;
        let n = self.w.len();
        let p_norm_sq = norm_sq(&self.p);
        if p_norm_sq == 0.0 {
            return ScgStep {
                f: self.f,
                accepted: false,
                grad_norm_sq: norm_sq(&self.r),
            };
        }
        let p_norm = p_norm_sq.sqrt();

        // 2. curvature probe via finite differences along p
        let mut delta = if self.success {
            let sigma = self.sigma0 / p_norm;
            let w_probe: Vec<f64> = self
                .w
                .iter()
                .zip(&self.p)
                .map(|(w, p)| w + sigma * p)
                .collect();
            let g_probe = obj.grad(&w_probe);
            // s = (f'(w+sigma p) - f'(w)) / sigma ; note r = -f'(w)
            let mut d = 0.0;
            for i in 0..n {
                d += self.p[i] * (g_probe[i] + self.r[i]);
            }
            d / sigma
        } else {
            self.last_delta
        };

        // 3. scale
        delta += (self.lambda - self.lambda_bar) * p_norm_sq;

        // 4. make positive definite
        if delta <= 0.0 {
            self.lambda_bar = 2.0 * (self.lambda - delta / p_norm_sq);
            delta = -delta + self.lambda * p_norm_sq;
            self.lambda = self.lambda_bar;
        }
        self.last_delta = delta;

        // 5. step size
        let mu = dot(&self.p, &self.r);
        let alpha = mu / delta;

        // 6. comparison parameter
        let w_new: Vec<f64> = self
            .w
            .iter()
            .zip(&self.p)
            .map(|(w, p)| w + alpha * p)
            .collect();
        let (f_new, g_new) = obj.value_grad(&w_new);
        let big_delta = 2.0 * delta * (self.f - f_new) / (mu * mu);

        let accepted = big_delta >= 0.0 && f_new.is_finite();
        if accepted {
            // 7. successful reduction
            self.w = w_new;
            self.f = f_new;
            let r_new: Vec<f64> = g_new.iter().map(|x| -x).collect();
            self.lambda_bar = 0.0;
            self.success = true;
            self.k += 1;
            if self.k % n == 0 {
                // restart
                self.p = r_new.clone();
            } else {
                let beta = (norm_sq(&r_new) - dot(&r_new, &self.r)) / mu;
                for i in 0..n {
                    self.p[i] = r_new[i] + beta * self.p[i];
                }
            }
            self.r = r_new;
            if big_delta >= 0.75 {
                self.lambda = (self.lambda * 0.25).max(1e-15);
            }
        } else {
            self.lambda_bar = self.lambda;
            self.success = false;
        }

        // 8. increase scale on poor agreement
        if big_delta < 0.25 {
            self.lambda += delta * (1.0 - big_delta) / p_norm_sq;
            self.lambda = self.lambda.min(1e15);
        }

        ScgStep {
            f: self.f,
            accepted,
            grad_norm_sq: norm_sq(&self.r),
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(scg: &mut Scg, obj: &mut impl Objective, iters: usize) -> f64 {
        for _ in 0..iters {
            scg.step(obj);
        }
        scg.f()
    }

    #[test]
    fn minimises_quadratic() {
        // f(x) = 0.5 x^T A x - b^T x with SPD A
        let a = [[4.0, 1.0], [1.0, 3.0]];
        let b = [1.0, 2.0];
        let mut obj = |x: &[f64]| {
            let ax = [
                a[0][0] * x[0] + a[0][1] * x[1],
                a[1][0] * x[0] + a[1][1] * x[1],
            ];
            let f = 0.5 * (x[0] * ax[0] + x[1] * ax[1]) - b[0] * x[0] - b[1] * x[1];
            (f, vec![ax[0] - b[0], ax[1] - b[1]])
        };
        let mut scg = Scg::new(vec![5.0, -3.0], &mut obj);
        run(&mut scg, &mut obj, 30);
        // solution: A x = b -> x = [1/11, 7/11]
        assert!((scg.x()[0] - 1.0 / 11.0).abs() < 1e-6, "{:?}", scg.x());
        assert!((scg.x()[1] - 7.0 / 11.0).abs() < 1e-6);
    }

    #[test]
    fn minimises_rosenbrock() {
        let mut obj = |x: &[f64]| {
            let (a, b) = (1.0, 100.0);
            let f = (a - x[0]).powi(2) + b * (x[1] - x[0] * x[0]).powi(2);
            let g = vec![
                -2.0 * (a - x[0]) - 4.0 * b * x[0] * (x[1] - x[0] * x[0]),
                2.0 * b * (x[1] - x[0] * x[0]),
            ];
            (f, g)
        };
        let mut scg = Scg::new(vec![-1.2, 1.0], &mut obj);
        let f = run(&mut scg, &mut obj, 400);
        assert!(f < 1e-5, "f={f}, x={:?}", scg.x());
    }

    #[test]
    fn monotone_nonincreasing_objective() {
        let mut obj = |x: &[f64]| {
            let f: f64 = x.iter().map(|v| v.cosh()).sum();
            (f, x.iter().map(|v| v.sinh()).collect())
        };
        let mut scg = Scg::new(vec![2.0, -1.5, 0.7], &mut obj);
        let mut prev = scg.f();
        for _ in 0..50 {
            let s = scg.step(&mut obj);
            assert!(s.f <= prev + 1e-12, "objective increased");
            prev = s.f;
        }
        assert!(prev < 3.0 + 1e-6); // min is 3 at x = 0
    }

    #[test]
    fn refresh_handles_changed_objective() {
        // minimise (x - c)^2 where c jumps between refreshes
        let mut c = 0.0;
        {
            let mut obj = |x: &[f64]| ((x[0] - c).powi(2), vec![2.0 * (x[0] - c)]);
            let mut scg = Scg::new(vec![4.0], &mut obj);
            for _ in 0..20 {
                scg.step(&mut obj);
            }
            assert!((scg.x()[0] - c).abs() < 1e-5);
        }
        c = 3.0;
        let mut obj2 = |x: &[f64]| ((x[0] - c).powi(2), vec![2.0 * (x[0] - c)]);
        let mut scg = Scg::new(vec![0.0], &mut obj2);
        scg.refresh(&mut obj2);
        for _ in 0..20 {
            scg.step(&mut obj2);
        }
        assert!((scg.x()[0] - 3.0).abs() < 1e-5);
    }
}
