//! Optimisers: scaled conjugate gradients (the paper's choice) plus
//! Adam and plain gradient descent for local steps and ablations.

mod adam;
mod scg;

pub use adam::Adam;
pub use scg::{Scg, ScgStep};

/// Objective interface: value and gradient at a parameter vector.
/// All optimisers MINIMISE; the trainer negates the bound.
pub trait Objective {
    fn value_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>);

    /// Gradient only (SCG's curvature probe). Default: discard the value.
    fn grad(&mut self, x: &[f64]) -> Vec<f64> {
        self.value_grad(x).1
    }
}

impl<F> Objective for F
where
    F: FnMut(&[f64]) -> (f64, Vec<f64>),
{
    fn value_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        self(x)
    }
}

pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub(crate) fn norm_sq(a: &[f64]) -> f64 {
    dot(a, a)
}
