//! Rule `determinism` — the hot paths that produce or move numbers
//! must be bit-reproducible (DESIGN.md §11).
//!
//! Scope: `gp/`, `linalg/`, `cluster/wire.rs`, `store/codec.rs` — the
//! psi/kernel math, the wire encoders and the shard codec. In these
//! files non-test code may not use `HashMap`/`HashSet` (iteration
//! order is randomized per-process), wall-clock reads
//! (`Instant::now`/`SystemTime::now`), or RNG (`Rng`, `thread_rng`,
//! `rand::`). Ordered containers (`BTreeMap`/`Vec`) and seeds passed
//! in from the caller are the sanctioned alternatives.

use crate::analyze::source::{find_ident, SourceFile};
use crate::analyze::Finding;

pub const RULE: &str = "determinism";

/// Files the rule applies to (path prefixes / exact paths, repo-relative).
fn in_scope(path: &str) -> bool {
    path.starts_with("rust/src/gp/")
        || path.starts_with("rust/src/linalg/")
        || path == "rust/src/cluster/wire.rs"
        || path == "rust/src/store/codec.rs"
}

/// (needle, whole-ident?, what to say).
const BANNED: &[(&str, bool, &str)] = &[
    ("HashMap", true, "HashMap iteration order is nondeterministic; use BTreeMap or Vec"),
    ("HashSet", true, "HashSet iteration order is nondeterministic; use BTreeSet or a sorted Vec"),
    ("Instant::now", false, "wall-clock reads make hot-path output time-dependent"),
    ("SystemTime::now", false, "wall-clock reads make hot-path output time-dependent"),
    ("Rng", true, "RNG in a deterministic hot path; thread seeds through from the caller"),
    ("thread_rng", true, "thread_rng is seeded per-thread; hot paths must be reproducible"),
    ("rand", true, "RNG in a deterministic hot path; thread seeds through from the caller"),
];

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files.iter().filter(|f| in_scope(&f.path)) {
        for (idx, line) in f.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for &(needle, ident, why) in BANNED {
                let hit = if ident {
                    find_ident(&line.code, needle).is_some()
                } else {
                    line.code.contains(needle)
                };
                if hit {
                    out.push(Finding {
                        rule: RULE,
                        file: f.path.clone(),
                        line: idx + 1,
                        snippet: line.raw.trim().to_string(),
                        message: format!("{needle} in a determinism-scoped file: {why}"),
                    });
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::source::parse;

    fn run(path: &str, src: &str) -> Vec<Finding> {
        check(&[parse(path, src)])
    }

    #[test]
    fn flags_hashmap_clock_and_rng_in_scoped_files() {
        let src = "use std::collections::HashMap;\nfn f() {\n    let t = std::time::Instant::now();\n    let mut r = Rng::new(0);\n}\n";
        let hits = run("rust/src/gp/kernel.rs", src);
        assert_eq!(hits.len(), 3, "{hits:?}");
        assert_eq!(hits[0].line, 1);
        assert!(hits[1].message.contains("wall-clock"));
        assert!(hits[2].message.contains("RNG"));
    }

    #[test]
    fn ignores_test_code_and_out_of_scope_files() {
        let src = "#[cfg(test)]\nmod tests {\n    use crate::util::rng::Rng;\n    fn t() { let _ = Rng::new(7); }\n}\n";
        assert!(run("rust/src/linalg/matrix.rs", src).is_empty());
        let shipped = "fn f() { let m: HashMap<u32, u8> = HashMap::new(); }\n";
        assert!(run("rust/src/obs/trace.rs", shipped).is_empty(), "obs/ is out of scope");
        assert_eq!(run("rust/src/store/codec.rs", shipped).len(), 2);
    }

    #[test]
    fn strings_and_comments_do_not_trigger() {
        let src = "fn f() {\n    let msg = \"HashMap order\"; // Instant::now here is prose\n}\n";
        assert!(run("rust/src/cluster/wire.rs", src).is_empty());
    }
}
