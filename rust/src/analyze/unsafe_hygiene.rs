//! Rule `unsafe-hygiene` — every `unsafe` block carries a written-down
//! proof obligation (DESIGN.md §14).
//!
//! Scope: the whole crate. An `unsafe` token in non-test code must
//! have a `SAFETY:` comment either on the same line or in the
//! contiguous comment block immediately above it. The workspace also
//! denies `unsafe_code` via `[lints]`; a file that opts back in with
//! `#![allow(unsafe_code)]` still has to satisfy this rule for each
//! block it writes.

use crate::analyze::source::{find_ident, SourceFile};
use crate::analyze::Finding;

pub const RULE: &str = "unsafe-hygiene";

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        for (idx, line) in f.lines.iter().enumerate() {
            if line.in_test || find_ident(&line.code, "unsafe").is_none() {
                continue;
            }
            // `#![allow(unsafe_code)]` / `forbid(unsafe_code)` attribute
            // lines mention the lint, not an unsafe block
            if line.code.contains("unsafe_code") {
                continue;
            }
            if !has_safety_comment(f, idx) {
                out.push(Finding {
                    rule: RULE,
                    file: f.path.clone(),
                    line: idx + 1,
                    snippet: line.raw.trim().to_string(),
                    message: "unsafe without a `// SAFETY:` comment on the line or immediately \
                              above stating why the invariants hold"
                        .to_string(),
                });
            }
        }
    }
    out
}

/// SAFETY marker on the line itself or in the contiguous run of
/// comment/attribute lines directly above.
fn has_safety_comment(f: &SourceFile, idx: usize) -> bool {
    if f.lines[idx].raw.contains("SAFETY:") {
        return true;
    }
    for line in f.lines[..idx].iter().rev() {
        let t = line.raw.trim();
        let is_annotation = t.starts_with("//") || t.starts_with('#') || t.starts_with("*");
        if !is_annotation {
            return false;
        }
        if t.contains("SAFETY:") {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::source::parse;

    fn run(src: &str) -> Vec<Finding> {
        check(&[parse("rust/src/util/timer.rs", src)])
    }

    #[test]
    fn bare_unsafe_is_flagged() {
        let hits = run("fn f() {\n    let rc = unsafe { syscall() };\n}\n");
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].line, 2);
    }

    #[test]
    fn safety_comment_above_or_inline_passes() {
        let above = "fn f() {\n    // SAFETY: ts is a valid exclusive reference.\n    // The layout matches the C struct.\n    let rc = unsafe { syscall() };\n}\n";
        assert!(run(above).is_empty());
        let gap = "fn f() {\n    // SAFETY: stale — a blank code line breaks the run.\n    let x = 1;\n    let rc = unsafe { syscall() };\n}\n";
        assert_eq!(run(gap).len(), 1, "comment must be contiguous");
        let inline = "fn f() { unsafe { syscall() } } // SAFETY: inline proof\n";
        assert!(run(inline).is_empty());
    }

    #[test]
    fn lint_attributes_and_test_code_are_ignored() {
        assert!(run("#![allow(unsafe_code)]\n").is_empty());
        let test_src = "#[cfg(test)]\nmod tests {\n    fn t() { unsafe { x() } }\n}\n";
        assert!(run(test_src).is_empty());
    }
}
