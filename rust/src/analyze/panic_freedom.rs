//! Rule `panic-freedom` — the request-handling paths must degrade to
//! `Err`, never abort a connection thread (DESIGN.md §14).
//!
//! Scope: `model/serve.rs`, `fleet/`, and the cluster transport
//! (`cluster/wire.rs`, `cluster/node.rs`, `cluster/tcp.rs`) — every
//! thread that holds a socket or a registry entry for a remote peer.
//! Non-test code there may not call `.unwrap()`/`.expect(` or invoke
//! `panic!`/`todo!`/`unimplemented!`: a panic in a connection thread
//! poisons shared locks and silently drops the peer. Poisoned-lock
//! recovery is `unwrap_or_else(PoisonError::into_inner)` (which this
//! rule deliberately does not match), not `.expect("poisoned")`.

use crate::analyze::source::SourceFile;
use crate::analyze::Finding;

pub const RULE: &str = "panic-freedom";

fn in_scope(path: &str) -> bool {
    path == "rust/src/model/serve.rs"
        || path.starts_with("rust/src/fleet/")
        || path == "rust/src/cluster/wire.rs"
        || path == "rust/src/cluster/node.rs"
        || path == "rust/src/cluster/tcp.rs"
}

/// Exact-substring needles. `.unwrap()` with the parens, so
/// `unwrap_or_else`/`unwrap_or_default` do not match; `.expect(` with
/// the dot, so `expect_model_info(`/`expect_err(` do not match.
const BANNED: &[(&str, &str)] = &[
    (".unwrap()", "use ? / match / unwrap_or_else(PoisonError::into_inner)"),
    (".expect(", "use ? / match / unwrap_or_else(PoisonError::into_inner)"),
    ("panic!(", "return Err via bail! so the peer sees an error reply"),
    ("todo!(", "request paths must not ship placeholders"),
    ("unimplemented!(", "request paths must not ship placeholders"),
];

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files.iter().filter(|f| in_scope(&f.path)) {
        for (idx, line) in f.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for &(needle, fix) in BANNED {
                if line.code.contains(needle) {
                    out.push(Finding {
                        rule: RULE,
                        file: f.path.clone(),
                        line: idx + 1,
                        snippet: line.raw.trim().to_string(),
                        message: format!("{needle} in a request path: {fix}"),
                    });
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::source::parse;

    fn run(path: &str, src: &str) -> Vec<Finding> {
        check(&[parse(path, src)])
    }

    #[test]
    fn flags_every_banned_form_in_request_paths() {
        let src = "fn handler() {\n    let g = m.lock().unwrap();\n    let v = o.expect(\"present\");\n    panic!(\"boom\");\n    todo!()\n}\n";
        let hits = run("rust/src/fleet/lb.rs", src);
        assert_eq!(hits.len(), 4, "{hits:?}");
        let src2 = "fn h() { todo!(); unimplemented!(); }\n";
        assert_eq!(run("rust/src/model/serve.rs", src2).len(), 2);
    }

    #[test]
    fn sanctioned_recovery_forms_do_not_match() {
        let src = "fn h() {\n    let g = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);\n    let v = o.unwrap_or_default();\n    let i = c.expect_model_info()?;\n    let e = r.expect_err; // field, not a call\n}\n";
        assert!(run("rust/src/cluster/wire.rs", src).is_empty());
    }

    #[test]
    fn test_code_and_out_of_scope_files_are_ignored() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        assert!(run("rust/src/fleet/control.rs", src).is_empty());
        let shipped = "fn f() { x.unwrap(); }\n";
        assert!(run("rust/src/util/cli.rs", shipped).is_empty(), "util/ is out of scope");
    }
}
