//! `analyze-allowlist.toml` — the committed escape hatch for findings
//! that are deliberate (DESIGN.md §14).
//!
//! Format: a sequence of `[[allow]]` tables, each with string keys
//!
//! ```toml
//! [[allow]]
//! rule = "lock-hygiene"
//! file = "rust/src/model/serve.rs"
//! contains = "conn.shutdown"   # or: line = 478
//! reason = "why this is safe — required, shown in reports"
//! ```
//!
//! `contains` matches a substring of the flagged line (stable across
//! unrelated edits); `line` pins an exact line number. Exactly one of
//! the two must be given. The parser is a deliberate TOML subset —
//! tables of string/integer pairs and `#` comments — so the engine
//! stays dependency-free.

use crate::analyze::Finding;
use anyhow::{bail, Context, Result};

/// One `[[allow]]` entry.
#[derive(Debug, Clone)]
pub struct Allow {
    pub rule: String,
    pub file: String,
    /// Substring of the flagged line (preferred: survives line drift).
    pub contains: Option<String>,
    /// Exact 1-based line number (for lines with no stable substring).
    pub line: Option<usize>,
    pub reason: String,
}

/// The parsed allowlist.
#[derive(Debug, Default)]
pub struct Allowlist {
    pub allows: Vec<Allow>,
}

impl Allowlist {
    /// Parse the TOML-subset format. Errors name the offending line.
    pub fn parse(text: &str) -> Result<Allowlist> {
        let mut allows = Vec::new();
        let mut current: Option<Allow> = None;
        for (idx, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line == "[[allow]]" {
                if let Some(done) = current.take() {
                    allows.push(validate(done, idx)?);
                }
                current = Some(Allow {
                    rule: String::new(),
                    file: String::new(),
                    contains: None,
                    line: None,
                    reason: String::new(),
                });
                continue;
            }
            let entry = match current.as_mut() {
                Some(e) => e,
                None => bail!("allowlist line {}: key outside [[allow]] table", idx + 1),
            };
            let (key, value) = split_kv(&line)
                .with_context(|| format!("allowlist line {}: expected key = value", idx + 1))?;
            match key {
                "rule" => entry.rule = parse_str(value, idx)?,
                "file" => entry.file = parse_str(value, idx)?,
                "contains" => entry.contains = Some(parse_str(value, idx)?),
                "reason" => entry.reason = parse_str(value, idx)?,
                "line" => {
                    entry.line = Some(value.parse().with_context(|| {
                        format!("allowlist line {}: line must be an integer", idx + 1)
                    })?)
                }
                other => bail!("allowlist line {}: unknown key {other:?}", idx + 1),
            }
        }
        if let Some(done) = current.take() {
            allows.push(validate(done, 0)?);
        }
        Ok(Allowlist { allows })
    }

    /// Load from disk.
    pub fn load(path: &std::path::Path) -> Result<Allowlist> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading allowlist {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing allowlist {}", path.display()))
    }

    /// Index of the first entry matching `f`, if any.
    pub fn matches(&self, f: &Finding) -> Option<usize> {
        self.allows.iter().position(|a| {
            a.rule == f.rule
                && a.file == f.file
                && match (&a.contains, a.line) {
                    (Some(sub), _) => f.snippet.contains(sub.as_str()),
                    (None, Some(n)) => n == f.line,
                    (None, None) => false,
                }
        })
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted value would break this, so allowlist reasons
    // must not contain '#'; validate() enforces the quoting either way
    match line.find('#') {
        Some(pos) if !line[..pos].contains('"') || line[..pos].matches('"').count() % 2 == 0 => {
            &line[..pos]
        }
        _ => line,
    }
}

fn split_kv(line: &str) -> Option<(&str, &str)> {
    let eq = line.find('=')?;
    Some((line[..eq].trim(), line[eq + 1..].trim()))
}

fn parse_str(value: &str, idx: usize) -> Result<String> {
    let inner = value
        .strip_prefix('"')
        .and_then(|v| v.strip_suffix('"'))
        .with_context(|| format!("allowlist line {}: expected a quoted string", idx + 1))?;
    Ok(inner.to_string())
}

fn validate(a: Allow, idx: usize) -> Result<Allow> {
    if a.rule.is_empty() || a.file.is_empty() {
        bail!("allowlist entry ending at line {}: rule and file are required", idx + 1);
    }
    if a.reason.is_empty() {
        bail!("allowlist entry for {} in {}: a reason is required", a.rule, a.file);
    }
    if a.contains.is_none() && a.line.is_none() {
        bail!("allowlist entry for {} in {}: give contains or line", a.rule, a.file);
    }
    Ok(a)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, file: &str, line: usize, snippet: &str) -> Finding {
        Finding {
            rule,
            file: file.to_string(),
            line,
            snippet: snippet.to_string(),
            message: String::new(),
        }
    }

    #[test]
    fn parses_and_matches_contains_and_line_entries() {
        let text = "\n# comment\n[[allow]]\nrule = \"lock-hygiene\"\nfile = \"rust/src/model/serve.rs\"\ncontains = \"conn.shutdown\"\nreason = \"shutdown is non-blocking\"\n\n[[allow]]\nrule = \"panic-freedom\"\nfile = \"rust/src/fleet/lb.rs\"\nline = 12\nreason = \"startup only\"\n";
        let al = Allowlist::parse(text).unwrap();
        assert_eq!(al.allows.len(), 2);
        let hit = finding(
            "lock-hygiene",
            "rust/src/model/serve.rs",
            99,
            "conn.shutdown(std::net::Shutdown::Both).ok();",
        );
        assert_eq!(al.matches(&hit), Some(0));
        let by_line = finding("panic-freedom", "rust/src/fleet/lb.rs", 12, "x.unwrap()");
        assert_eq!(al.matches(&by_line), Some(1));
        let wrong_line = finding("panic-freedom", "rust/src/fleet/lb.rs", 13, "x.unwrap()");
        assert_eq!(al.matches(&wrong_line), None);
        let wrong_rule = finding(
            "determinism",
            "rust/src/model/serve.rs",
            99,
            "conn.shutdown()",
        );
        assert_eq!(al.matches(&wrong_rule), None);
    }

    #[test]
    fn rejects_entries_missing_reason_or_selector() {
        let no_reason = "[[allow]]\nrule = \"determinism\"\nfile = \"a.rs\"\nline = 1\n";
        assert!(Allowlist::parse(no_reason).is_err());
        let no_selector = "[[allow]]\nrule = \"determinism\"\nfile = \"a.rs\"\nreason = \"x\"\n";
        assert!(Allowlist::parse(no_selector).is_err());
        let stray_key = "rule = \"determinism\"\n";
        assert!(Allowlist::parse(stray_key).is_err());
    }
}
