//! Rule `lock-hygiene` — no lock guard held across blocking socket I/O
//! (DESIGN.md §14).
//!
//! Scope: `model/serve.rs`, `fleet/`, `cluster/` — the code that holds
//! both registries and sockets. A `MutexGuard`/`RwLockGuard` bound
//! while the thread performs `read_frame`/`write_frame` or raw socket
//! calls serializes every peer behind the slowest one and turns a
//! stalled client into a fleet-wide stall. The rule is a
//! statement-level heuristic: a `let` statement (joined across rustfmt
//! chain breaks, up to its `;`) whose initializer contains `.lock()` /
//! `.read()` / `.write()` (empty parens — the io traits always take a
//! buffer argument) binds a guard; if a socket call appears before the
//! guard's enclosing block closes or the guard is `drop`ped, flag it.
//! Deliberate holds (e.g. a drain sweep calling non-blocking
//! `shutdown()`) go in analyze-allowlist.toml with a reason.

use crate::analyze::source::SourceFile;
use crate::analyze::Finding;

pub const RULE: &str = "lock-hygiene";

fn in_scope(path: &str) -> bool {
    path == "rust/src/model/serve.rs"
        || path.starts_with("rust/src/fleet/")
        || path.starts_with("rust/src/cluster/")
}

const GUARD_CALLS: &[&str] = &[".lock()", ".read()", ".write()"];

const SOCKET_CALLS: &[&str] = &[
    "read_frame(",
    "write_frame(",
    ".write_all(",
    ".read_exact(",
    ".flush(",
    ".shutdown(",
    "TcpStream::connect",
];

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files.iter().filter(|f| in_scope(&f.path)) {
        for (idx, line) in f.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            let name = match let_binding(&line.code) {
                Some(n) => n,
                None => continue,
            };
            // join the whole statement: rustfmt breaks guard chains
            // like `let g = self\n.inner\n.lock()\n.unwrap_or_else(…);`
            // across lines, so the guard call is rarely on the `let`
            // line itself
            let mut stmt = String::new();
            let mut end = idx;
            for (j, l) in f.lines.iter().enumerate().skip(idx) {
                stmt.push_str(&l.code);
                stmt.push('\n');
                end = j;
                if l.code.contains(';') {
                    break;
                }
            }
            if !GUARD_CALLS.iter().any(|g| stmt.contains(g)) {
                continue;
            }
            let let_depth = line.depth;
            let drop_call = format!("drop({name})");
            for later in &f.lines[end + 1..] {
                if later.depth < let_depth || later.code.contains(&drop_call) {
                    break; // guard scope ended
                }
                if let Some(call) = SOCKET_CALLS.iter().find(|c| later.code.contains(**c)) {
                    out.push(Finding {
                        rule: RULE,
                        file: f.path.clone(),
                        line: idx + 1,
                        snippet: later.raw.trim().to_string(),
                        message: format!(
                            "guard `{name}` is live across `{}` — drop it (or scope it) before \
                             blocking I/O, or justify the hold in analyze-allowlist.toml",
                            call.trim_start_matches('.').trim_end_matches('(')
                        ),
                    });
                    break; // one finding per guard
                }
            }
        }
    }
    out
}

/// If the line starts a `let` statement, return the binding's name.
/// Whether the statement binds a *guard* is decided by the caller on
/// the joined statement text.
fn let_binding(code: &str) -> Option<String> {
    let after_let = code.trim_start().strip_prefix("let ")?;
    let pat = after_let.strip_prefix("mut ").unwrap_or(after_let);
    let name: String = pat
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::source::parse;

    fn run(src: &str) -> Vec<Finding> {
        check(&[parse("rust/src/model/serve.rs", src)])
    }

    #[test]
    fn guard_across_write_frame_is_flagged() {
        let src = "fn h() {\n    let g = reg.lock().unwrap_or_else(std::sync::PoisonError::into_inner);\n    g.insert(id);\n    write_frame(&mut sock, &frame)?;\n}\n";
        let hits = run(src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].line, 2, "span anchors on the guard binding");
        assert!(hits[0].message.contains("write_frame"));
    }

    #[test]
    fn multiline_chain_bindings_are_guards_too() {
        // rustfmt breaks long guard chains — the repo's canonical form
        let src = "fn h() {\n    let conns = registry\n        .lock()\n        .unwrap_or_else(std::sync::PoisonError::into_inner);\n    for conn in conns.values() {\n        let _ = conn.shutdown(std::net::Shutdown::Both);\n    }\n}\n";
        let hits = run(src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].line, 2, "span anchors on the `let` line");
        assert!(hits[0].message.contains("shutdown"));
        assert!(hits[0].snippet.contains("conn.shutdown"));
    }

    #[test]
    fn dropped_or_scoped_guards_pass() {
        let dropped = "fn h() {\n    let g = reg.lock().unwrap_or_else(std::sync::PoisonError::into_inner);\n    g.insert(id);\n    drop(g);\n    write_frame(&mut sock, &frame)?;\n}\n";
        assert!(run(dropped).is_empty());
        let scoped = "fn h() {\n    {\n        let g = reg.lock().unwrap_or_else(std::sync::PoisonError::into_inner);\n        g.insert(id);\n    }\n    sock.write_all(&bytes)?;\n}\n";
        assert!(run(scoped).is_empty());
    }

    #[test]
    fn io_trait_reads_are_not_guards() {
        // .read(&mut buf) has an argument, so it is io::Read, not RwLock
        let src = "fn h() {\n    let n = sock.read(&mut buf)?;\n    sock.write_all(&buf[..n])?;\n}\n";
        assert!(run(src).is_empty());
        let shipped = "fn h() {\n    let g = slot.read();\n    sock.flush()?;\n}\n";
        assert_eq!(run(shipped).len(), 1, "empty-paren .read() is a guard");
    }

    #[test]
    fn out_of_scope_files_are_ignored() {
        let src = "fn h() {\n    let g = reg.lock().unwrap_or_else(std::sync::PoisonError::into_inner);\n    write_frame(&mut sock, &frame)?;\n}\n";
        assert!(check(&[parse("rust/src/util/timer.rs", src)]).is_empty());
    }
}
