//! `gparml analyze` — the repo-invariant lint engine (DESIGN.md §14).
//!
//! A dependency-free, token/line-level static-analysis pass over the
//! repo's own Rust sources. It enforces the contracts the runtime
//! tests can only sample: determinism of the hot paths (DESIGN.md
//! §11), panic-freedom of the serve/fleet/cluster request paths, wire
//! encode/decode totality and version agreement with DESIGN.md §6,
//! `// SAFETY:` discipline around unsafe blocks, and no lock guard
//! held across socket I/O. Violations fail the run (and the blocking
//! CI job) unless justified in the committed `analyze-allowlist.toml`.
//!
//! ```sh
//! gparml analyze                 # human-readable report, exit 1 on findings
//! gparml analyze --json          # machine-readable report (CI artifact)
//! gparml analyze --allowlist F   # explicit allowlist path
//! gparml analyze --root DIR      # explicit repo root (default: auto-detect)
//! ```

pub mod allowlist;
pub mod determinism;
pub mod lock_hygiene;
pub mod panic_freedom;
pub mod source;
pub mod unsafe_hygiene;
pub mod wire_conformance;

use crate::util::cli::Args;
use crate::util::json::{obj, Json};
use allowlist::Allowlist;
use anyhow::{bail, Context, Result};
use source::SourceFile;
use std::path::{Path, PathBuf};

/// All rule ids, in report order.
pub const RULE_IDS: &[&str] = &[
    determinism::RULE,
    panic_freedom::RULE,
    wire_conformance::RULE,
    unsafe_hygiene::RULE,
    lock_hygiene::RULE,
];

/// One violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Stable rule id (kebab-case, one per rule module).
    pub rule: &'static str,
    /// Repo-relative path with forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// The offending line, trimmed (allowlist `contains` matches this).
    pub snippet: String,
    pub message: String,
}

/// The result of a full repo pass.
#[derive(Debug)]
pub struct Report {
    /// Unallowed findings — any entry here fails the run.
    pub findings: Vec<Finding>,
    /// Findings matched by an allowlist entry, with the entry's reason.
    pub allowed: Vec<(Finding, String)>,
    /// Allowlist entries that matched nothing (stale debt — reported
    /// so the allowlist shrinks instead of accreting).
    pub unused_allows: Vec<String>,
    /// Number of source files analysed.
    pub files: usize,
}

/// Run every rule over the repo rooted at `root` and partition the
/// findings against `allowlist`.
pub fn analyze_repo(root: &Path, allowlist: &Allowlist) -> Result<Report> {
    let src_root = root.join("rust").join("src");
    let mut paths = Vec::new();
    walk(&src_root, &mut paths)
        .with_context(|| format!("walking {}", src_root.display()))?;
    paths.sort();

    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading {}", p.display()))?;
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push(source::parse(&rel, &text));
    }

    let design_path = root.join("DESIGN.md");
    let design = std::fs::read_to_string(&design_path).ok();

    let mut all = Vec::new();
    all.extend(determinism::check(&files));
    all.extend(panic_freedom::check(&files));
    all.extend(wire_conformance::check(&files, design.as_deref()));
    all.extend(unsafe_hygiene::check(&files));
    all.extend(lock_hygiene::check(&files));
    all.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));

    let mut used = vec![false; allowlist.allows.len()];
    let mut findings = Vec::new();
    let mut allowed = Vec::new();
    for f in all {
        match allowlist.matches(&f) {
            Some(i) => {
                used[i] = true;
                allowed.push((f, allowlist.allows[i].reason.clone()));
            }
            None => findings.push(f),
        }
    }
    let unused_allows = allowlist
        .allows
        .iter()
        .zip(&used)
        .filter(|(_, u)| !**u)
        .map(|(a, _)| format!("{} in {}", a.rule, a.file))
        .collect();

    Ok(Report {
        findings,
        allowed,
        unused_allows,
        files: files.len(),
    })
}

/// Recursively collect `.rs` files under `dir` (sorted by the caller;
/// `read_dir` order is platform-dependent).
fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Locate the repo root: `--root`, else cwd or its parents (the root
/// is the directory containing `rust/src`).
fn find_root(args: &Args) -> Result<PathBuf> {
    if let Some(r) = args.get("root") {
        return Ok(PathBuf::from(r));
    }
    let mut dir = std::env::current_dir().context("reading current dir")?;
    loop {
        if dir.join("rust").join("src").is_dir() {
            return Ok(dir);
        }
        if !dir.pop() {
            bail!("no repo root found (no rust/src above the current dir); pass --root DIR");
        }
    }
}

impl Report {
    pub fn to_json(&self) -> Json {
        let finding_json = |f: &Finding| {
            obj(vec![
                ("rule", Json::Str(f.rule.to_string())),
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("snippet", Json::Str(f.snippet.clone())),
                ("message", Json::Str(f.message.clone())),
            ])
        };
        obj(vec![
            ("files", Json::Num(self.files as f64)),
            (
                "rules",
                Json::Arr(
                    RULE_IDS
                        .iter()
                        .map(|r| Json::Str(r.to_string()))
                        .collect(),
                ),
            ),
            (
                "findings",
                Json::Arr(self.findings.iter().map(finding_json).collect()),
            ),
            (
                "allowed",
                Json::Arr(
                    self.allowed
                        .iter()
                        .map(|(f, reason)| {
                            let mut j = finding_json(f);
                            if let Json::Obj(m) = &mut j {
                                m.insert("reason".to_string(), Json::Str(reason.clone()));
                            }
                            j
                        })
                        .collect(),
                ),
            ),
            (
                "unused_allows",
                Json::Arr(
                    self.unused_allows
                        .iter()
                        .map(|s| Json::Str(s.clone()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// CLI entry point (`gparml analyze`).
pub fn run_cli(args: &Args) -> Result<()> {
    let root = find_root(args)?;
    let allowlist = match args.get("allowlist") {
        Some(p) => Allowlist::load(Path::new(p))?,
        None => {
            let default = root.join("analyze-allowlist.toml");
            if default.exists() {
                Allowlist::load(&default)?
            } else {
                Allowlist::default()
            }
        }
    };
    let report = analyze_repo(&root, &allowlist)?;

    if args.has("json") {
        println!("{}", report.to_json().to_string());
    } else {
        for (f, reason) in &report.allowed {
            println!("{}:{}: [{}] allowed: {}", f.file, f.line, f.rule, reason);
        }
        for u in &report.unused_allows {
            println!("note: unused allowlist entry: {u}");
        }
        for f in &report.findings {
            println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
            println!("    {}", f.snippet);
        }
        println!(
            "analyze: {} file(s), {} finding(s), {} allowed, {} unused allow(s)",
            report.files,
            report.findings.len(),
            report.allowed.len(),
            report.unused_allows.len()
        );
    }

    if !report.findings.is_empty() {
        bail!(
            "analyze found {} unallowed violation(s); fix them or justify each in \
             analyze-allowlist.toml",
            report.findings.len()
        );
    }
    Ok(())
}
