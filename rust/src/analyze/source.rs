//! Line-oriented Rust source model for the analyze rules
//! (DESIGN.md §14).
//!
//! The rules are token/line-level, not AST-level, so the only lexing
//! the engine needs is the part that prevents false positives: string
//! and char literal *contents* are blanked (a log message mentioning
//! `unwrap()` is not a violation), comments are stripped from the
//! `code` view (but kept in `raw`, where the unsafe-hygiene rule looks
//! for `// SAFETY:`), brace depth is tracked per line (scope tracking
//! for the lock-hygiene rule), and `#[cfg(test)]` regions are marked
//! so every rule can skip test code — the contracts cover shipped
//! paths, and tests are *supposed* to unwrap.

/// One analysed line of a source file.
#[derive(Debug, Clone)]
pub struct Line {
    /// The original line text, comments and all (the unsafe-hygiene
    /// rule reads `// SAFETY:` markers from here).
    pub raw: String,
    /// The code view: comments removed, string/char literal contents
    /// replaced with spaces (delimiters kept), everything else intact.
    pub code: String,
    /// True inside a `#[cfg(test)]` item (attribute line through the
    /// closing brace of the block it gates).
    pub in_test: bool,
    /// Brace depth at the START of the line.
    pub depth: usize,
}

/// A parsed source file: repo-relative path + analysed lines.
#[derive(Debug)]
pub struct SourceFile {
    /// Repo-relative path with forward slashes (span reporting).
    pub path: String,
    pub lines: Vec<Line>,
}

/// Lexer state carried across lines.
enum Mode {
    Code,
    /// Nested block comment depth (Rust block comments nest).
    BlockComment(usize),
    /// Inside a normal `"` string (they may span lines).
    Str,
    /// Inside a raw string with this many `#` marks.
    RawStr(usize),
}

/// Parse `text` into the line model. `path` should be repo-relative
/// with forward slashes; it is stored verbatim for span reporting.
pub fn parse(path: &str, text: &str) -> SourceFile {
    let mut mode = Mode::Code;
    let mut depth: usize = 0;
    // test-region tracking: a `#[cfg(test)]` attribute arms `pending`;
    // the next opening brace starts the region, which runs until depth
    // returns to the level the region opened at.
    let mut pending_test = false;
    let mut test_start_depth: Option<usize> = None;
    let mut lines = Vec::new();

    for raw in text.lines() {
        let start_depth = depth;
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::with_capacity(raw.len());
        let mut i = 0;
        while i < chars.len() {
            match mode {
                Mode::BlockComment(d) => {
                    if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        i += 2;
                        mode = if d == 1 {
                            Mode::Code
                        } else {
                            Mode::BlockComment(d - 1)
                        };
                    } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        i += 2;
                        mode = Mode::BlockComment(d + 1);
                    } else {
                        i += 1;
                    }
                }
                Mode::Str => {
                    match chars[i] {
                        '\\' => {
                            code.push(' ');
                            if i + 1 < chars.len() {
                                code.push(' ');
                            }
                            i += 2;
                        }
                        '"' => {
                            code.push('"');
                            i += 1;
                            mode = Mode::Code;
                        }
                        _ => {
                            code.push(' ');
                            i += 1;
                        }
                    }
                }
                Mode::RawStr(hashes) => {
                    if chars[i] == '"' && closes_raw(&chars[i + 1..], hashes) {
                        code.push('"');
                        for _ in 0..hashes {
                            code.push(' ');
                        }
                        i += 1 + hashes;
                        mode = Mode::Code;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                Mode::Code => {
                    let c = chars[i];
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        // line comment: the rest of the line is raw-only
                        break;
                    }
                    if c == '/' && chars.get(i + 1) == Some(&'*') {
                        i += 2;
                        mode = Mode::BlockComment(1);
                        continue;
                    }
                    if let Some((hashes, consumed)) = raw_string_open(&chars[i..]) {
                        for _ in 0..consumed - 1 {
                            code.push(' ');
                        }
                        code.push('"');
                        i += consumed;
                        mode = Mode::RawStr(hashes);
                        continue;
                    }
                    match c {
                        '"' => {
                            code.push('"');
                            i += 1;
                            mode = Mode::Str;
                        }
                        '\'' => {
                            // char literal vs lifetime: 'x' / '\n' are
                            // literals (blank them — '{' must not skew
                            // brace depth); anything else is a lifetime
                            if chars.get(i + 1) == Some(&'\\') {
                                let mut j = i + 2;
                                while j < chars.len() && chars[j] != '\'' {
                                    j += 1;
                                }
                                code.push_str("' '");
                                i = (j + 1).min(chars.len());
                            } else if chars.get(i + 2) == Some(&'\'') {
                                code.push_str("' '");
                                i += 3;
                            } else {
                                code.push('\'');
                                i += 1;
                            }
                        }
                        '{' => {
                            depth += 1;
                            code.push('{');
                            i += 1;
                        }
                        '}' => {
                            depth = depth.saturating_sub(1);
                            code.push('}');
                            i += 1;
                        }
                        c => {
                            code.push(c);
                            i += 1;
                        }
                    }
                }
            }
        }

        // test-region bookkeeping: the attribute line, the block it
        // gates and the closing brace are all `in_test`
        let mut in_test = test_start_depth.is_some();
        if test_start_depth.is_none() {
            if code.contains("#[cfg(test)]") {
                pending_test = true;
                in_test = true;
            }
            if pending_test {
                in_test = true;
                if depth > start_depth {
                    test_start_depth = Some(start_depth);
                    pending_test = false;
                }
            }
        } else if let Some(sd) = test_start_depth {
            if depth <= sd {
                // this line closed the region (its closing brace is
                // still test code); the next line is shipped code again
                test_start_depth = None;
            }
        }

        lines.push(Line {
            raw: raw.to_string(),
            code,
            in_test,
            depth: start_depth,
        });
    }

    SourceFile {
        path: path.to_string(),
        lines,
    }
}

/// Does `rest` (the chars after a `"` inside a raw string) close a raw
/// string with `hashes` marks?
fn closes_raw(rest: &[char], hashes: usize) -> bool {
    rest.len() >= hashes && rest[..hashes].iter().all(|&c| c == '#')
}

/// Detect a raw-string opening at the start of `s`: `r"`, `r#"`, `br"`,
/// `b"` etc. Returns (hash count, chars consumed incl. the quote).
fn raw_string_open(s: &[char]) -> Option<(usize, usize)> {
    let mut i = 0;
    if s.get(i) == Some(&'b') {
        i += 1;
    }
    let raw = s.get(i) == Some(&'r');
    if raw {
        i += 1;
    }
    if i == 0 {
        return None; // plain '"' is handled by the Str branch
    }
    let mut hashes = 0;
    while s.get(i + hashes) == Some(&'#') {
        hashes += 1;
    }
    if s.get(i + hashes) == Some(&'"') && (raw || hashes == 0) {
        // b"..." (hashes == 0, not raw) is a byte string; br#"/r#" raw
        Some((if raw { hashes } else { 0 }, i + hashes + 1))
    } else {
        None
    }
}

/// True if `code` contains `token` as a whole identifier (neither
/// neighbour is an identifier character).
pub fn has_ident(code: &str, token: &str) -> bool {
    find_ident(code, token).is_some()
}

/// Byte offset of the first whole-identifier occurrence of `token`.
pub fn find_ident(code: &str, token: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(token) {
        let at = from + pos;
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let end = at + token.len();
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return Some(at);
        }
        from = at + 1;
    }
    None
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let f = parse(
            "x.rs",
            "let a = \"unwrap() inside a string\"; // unwrap() in comment\nlet b = 1; /* unwrap()\nstill a comment */ let c = 2;",
        );
        assert!(!f.lines[0].code.contains("unwrap"));
        assert!(f.lines[0].raw.contains("unwrap() in comment"));
        assert!(!f.lines[1].code.contains("unwrap"));
        assert!(f.lines[2].code.contains("let c = 2;"));
        assert!(!f.lines[2].code.contains("still"));
    }

    #[test]
    fn raw_strings_and_char_literals_are_blanked() {
        let f = parse(
            "x.rs",
            "let a = r#\"panic!() { } \"#; let b = '{'; let c: &'static str = \"\";",
        );
        assert!(!f.lines[0].code.contains("panic"));
        // the blanked brace literals must not skew depth
        assert_eq!(f.lines[0].depth, 0);
        assert!(f.lines[0].code.contains("&'static str"));
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let f = parse("x.rs", "let a = \"he said \\\"hi\\\" loudly\"; let b = 1;");
        assert!(f.lines[0].code.contains("let b = 1;"));
        assert!(!f.lines[0].code.contains("loudly"));
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let src = "fn shipped() {\n    x.unwrap();\n}\n\n#[cfg(test)]\nmod tests {\n    fn t() {\n        y.unwrap();\n    }\n}\nfn shipped_again() {}\n";
        let f = parse("x.rs", src);
        assert!(!f.lines[1].in_test, "shipped code is not test code");
        assert!(f.lines[4].in_test, "the attribute line is test code");
        assert!(f.lines[7].in_test, "inside the test mod");
        assert!(f.lines[9].in_test, "closing brace is test code");
        assert!(!f.lines[10].in_test, "code after the region is shipped");
    }

    #[test]
    fn depth_tracks_braces() {
        let f = parse("x.rs", "fn a() {\n    if x {\n        y();\n    }\n}\n");
        assert_eq!(f.lines[0].depth, 0);
        assert_eq!(f.lines[1].depth, 1);
        assert_eq!(f.lines[2].depth, 2);
        assert_eq!(f.lines[4].depth, 1);
    }

    #[test]
    fn ident_matching_respects_boundaries() {
        assert!(has_ident("let m: HashMap<u32, u8>;", "HashMap"));
        assert!(!has_ident("let m = MyHashMapLike::new();", "HashMap"));
        assert!(has_ident("Rng::new(7)", "Rng"));
        assert!(!has_ident("rng_seed", "Rng"));
    }
}
