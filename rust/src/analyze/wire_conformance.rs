//! Rule `wire-conformance` — the wire protocol stays total and
//! versioned (DESIGN.md §6, §14).
//!
//! Three checks:
//! 1. every `Frame` enum variant appears in BOTH `encode_payload` and
//!    `decode_payload` (a variant with no decode arm ships frames the
//!    peer rejects as "unknown frame kind");
//! 2. no `match` whose arms dispatch on `Frame::` carries a `_ =>`
//!    wildcard — a wildcard silently swallows the next frame kind
//!    instead of forcing the author through every dispatch site;
//! 3. `wire::VERSION` equals the newest `**vN**` entry in DESIGN.md
//!    §6's version history, so the doc can't drift from the code.

use crate::analyze::source::{find_ident, SourceFile};
use crate::analyze::Finding;

pub const RULE: &str = "wire-conformance";

const WIRE: &str = "rust/src/cluster/wire.rs";

pub fn check(files: &[SourceFile], design_md: Option<&str>) -> Vec<Finding> {
    let mut out = Vec::new();
    if let Some(wire) = files.iter().find(|f| f.path == WIRE) {
        check_arms(wire, &mut out);
        check_version(wire, design_md, &mut out);
    }
    for f in files {
        check_wildcards(f, &mut out);
    }
    out
}

/// Every Frame variant must appear in both encode_payload and
/// decode_payload.
fn check_arms(wire: &SourceFile, out: &mut Vec<Finding>) {
    let variants = frame_variants(wire);
    let enc = fn_body(wire, "encode_payload");
    let dec = fn_body(wire, "decode_payload");
    for (name, line) in &variants {
        let needle = format!("Frame::{name}");
        let misses: &[(&str, &Option<String>)] =
            &[("encode_payload", &enc), ("decode_payload", &dec)];
        for (fn_name, body) in misses {
            let present = body.as_deref().is_some_and(|b| b.contains(&needle));
            if !present {
                out.push(Finding {
                    rule: RULE,
                    file: wire.path.clone(),
                    line: *line,
                    snippet: format!("Frame::{name}"),
                    message: format!("Frame variant {name} has no arm in {fn_name}"),
                });
            }
        }
    }
}

/// Variant names of `enum Frame` with their 1-based declaration lines.
fn frame_variants(wire: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let start = match wire
        .lines
        .iter()
        .position(|l| find_ident(&l.code, "enum").is_some() && find_ident(&l.code, "Frame").is_some())
    {
        Some(i) => i,
        None => return out,
    };
    let enum_depth = wire.lines[start].depth;
    for (idx, line) in wire.lines.iter().enumerate().skip(start + 1) {
        // a start-of-line depth back at the enum's own level means the
        // enum block closed on the previous line
        if line.depth <= enum_depth {
            break;
        }
        if line.depth != enum_depth + 1 {
            continue;
        }
        let t = line.code.trim();
        if t.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
            let name: String = t
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                out.push((name, idx + 1));
            }
        }
    }
    out
}

/// The concatenated `code` text of the named fn's block, if present.
fn fn_body(file: &SourceFile, name: &str) -> Option<String> {
    let start = file.lines.iter().position(|l| {
        find_ident(&l.code, "fn").is_some() && find_ident(&l.code, name).is_some()
    })?;
    let fn_depth = file.lines[start].depth;
    let mut body = String::new();
    for (i, line) in file.lines.iter().enumerate().skip(start) {
        if i > start && line.depth <= fn_depth {
            break; // the fn block closed on the previous line
        }
        body.push_str(&line.code);
        body.push('\n');
    }
    Some(body)
}

/// Flag `_ =>` arms inside matches that dispatch on `Frame::`.
fn check_wildcards(f: &SourceFile, out: &mut Vec<Finding>) {
    for (idx, line) in f.lines.iter().enumerate() {
        if line.in_test || !line.code.trim_start().starts_with("_ =>") {
            continue;
        }
        // nearest preceding line that opened this block
        let opener = match f.lines[..idx].iter().rposition(|l| l.depth < line.depth) {
            Some(j) => j,
            None => continue,
        };
        if find_ident(&f.lines[opener].code, "match").is_none() {
            continue;
        }
        // does any arm of that match dispatch on Frame::?
        let open_depth = f.lines[opener].depth;
        let mut frame_match = false;
        for l in &f.lines[opener + 1..] {
            if l.depth <= open_depth {
                break; // the match block closed on the previous line
            }
            if l.code.contains("Frame::") {
                frame_match = true;
                break;
            }
        }
        if frame_match {
            out.push(Finding {
                rule: RULE,
                file: f.path.clone(),
                line: idx + 1,
                snippet: line.raw.trim().to_string(),
                message: "wildcard `_ =>` in a Frame dispatch match swallows new frame kinds; \
                          name every variant"
                    .to_string(),
            });
        }
    }
}

/// wire::VERSION must equal the newest `**vN**` in DESIGN.md §6.
fn check_version(wire: &SourceFile, design_md: Option<&str>, out: &mut Vec<Finding>) {
    let (code_version, version_line) = match wire.lines.iter().enumerate().find_map(|(i, l)| {
        l.code
            .find("const VERSION")
            .and_then(|_| trailing_int(&l.code))
            .map(|v| (v, i + 1))
    }) {
        Some(v) => v,
        None => return,
    };
    let design = match design_md {
        Some(d) => d,
        None => return,
    };
    let doc_version = match newest_doc_version(design) {
        Some(v) => v,
        None => {
            out.push(Finding {
                rule: RULE,
                file: wire.path.clone(),
                line: version_line,
                snippet: format!("VERSION = {code_version}"),
                message: "DESIGN.md wire-format section has no **vN** version history entries"
                    .to_string(),
            });
            return;
        }
    };
    if doc_version != code_version {
        out.push(Finding {
            rule: RULE,
            file: wire.path.clone(),
            line: version_line,
            snippet: format!("VERSION = {code_version}"),
            message: format!(
                "wire::VERSION is {code_version} but DESIGN.md §6's newest history entry is \
                 **v{doc_version}** — update whichever lags"
            ),
        });
    }
}

/// Last integer literal on the line (e.g. `pub const VERSION: u16 = 9;`).
fn trailing_int(code: &str) -> Option<u64> {
    let digits: String = code
        .chars()
        .skip_while(|c| *c != '=')
        .filter(|c| c.is_ascii_digit())
        .collect();
    digits.parse().ok()
}

/// Max N over `**vN**` markers in the wire-format section of DESIGN.md.
fn newest_doc_version(design: &str) -> Option<u64> {
    let mut in_section = false;
    let mut max = None;
    for line in design.lines() {
        if line.starts_with('#') {
            in_section = line.contains("Wire format");
            continue;
        }
        if !in_section {
            continue;
        }
        let mut rest = line;
        while let Some(pos) = rest.find("**v") {
            let tail = &rest[pos + 3..];
            let digits: String = tail.chars().take_while(|c| c.is_ascii_digit()).collect();
            if !digits.is_empty() && tail[digits.len()..].starts_with("**") {
                let v: u64 = digits.parse().ok()?;
                max = Some(max.map_or(v, |m: u64| m.max(v)));
            }
            rest = &rest[pos + 3..];
        }
    }
    max
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::source::parse;

    /// A miniature wire.rs with an enum, encoder and decoder.
    fn mini_wire(encode_arms: &str, decode_arms: &str, version: u64) -> String {
        format!(
            "pub const VERSION: u16 = {version};\npub enum Frame {{\n    Hello {{ worker_id: u32 }},\n    Ping,\n    Pong,\n}}\nimpl Frame {{\n    fn encode_payload(&self) {{\n        match self {{\n{encode_arms}\n        }}\n    }}\n    fn decode_payload(kind: u8) {{\n        match kind {{\n{decode_arms}\n        }}\n    }}\n}}\n"
        )
    }

    const DESIGN: &str = "### Wire format\n\nhistory: **v1** first, **v2** newest.\n\n### Next section\n**v9** (not wire history)\n";

    #[test]
    fn complete_enum_and_matching_version_pass() {
        let src = mini_wire(
            "            Frame::Hello { .. } => {}\n            Frame::Ping | Frame::Pong => {}",
            "            1 => Frame::Hello { worker_id: 0 },\n            6 => Frame::Ping,\n            7 => Frame::Pong,\n            k => bail!(\"unknown {k}\"),",
            2,
        );
        let hits = check(&[parse("rust/src/cluster/wire.rs", &src)], Some(DESIGN));
        assert!(hits.is_empty(), "{hits:?}");
    }

    #[test]
    fn missing_decode_arm_is_flagged() {
        let src = mini_wire(
            "            Frame::Hello { .. } => {}\n            Frame::Ping | Frame::Pong => {}",
            "            1 => Frame::Hello { worker_id: 0 },\n            6 => Frame::Ping,",
            2,
        );
        let hits = check(&[parse("rust/src/cluster/wire.rs", &src)], Some(DESIGN));
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert!(hits[0].message.contains("Pong"));
        assert!(hits[0].message.contains("decode_payload"));
    }

    #[test]
    fn wildcard_in_frame_dispatch_is_flagged_anywhere() {
        let src = "fn dispatch(f: Frame) {\n    match f {\n        Frame::Ping => pong(),\n        _ => {}\n    }\n    match n {\n        1 => a(),\n        _ => b(),\n    }\n}\n";
        let hits = check(&[parse("rust/src/cluster/node.rs", src)], None);
        assert_eq!(hits.len(), 1, "only the Frame match is flagged: {hits:?}");
        assert_eq!(hits[0].line, 4);
    }

    #[test]
    fn version_drift_against_design_is_flagged() {
        let src = mini_wire(
            "            Frame::Hello { .. } => {}\n            Frame::Ping | Frame::Pong => {}",
            "            1 => Frame::Hello { worker_id: 0 },\n            6 => Frame::Ping,\n            7 => Frame::Pong,",
            3,
        );
        let hits = check(&[parse("rust/src/cluster/wire.rs", &src)], Some(DESIGN));
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert!(hits[0].message.contains("**v2**"));
    }
}
