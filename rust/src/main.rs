//! `gparml` — distributed variational inference for sparse GPs and the
//! GPLVM (Gal, van der Wilk & Rasmussen, 2014).
//!
//! ```text
//! gparml experiment <fig1..fig8|all> [--n N] [--iters I] [--workers W] ...
//! gparml train [--data synthetic|oilflow|digits] [--model reg|lvm] ...
//! gparml info                      # artifact manifest summary
//! ```

use anyhow::{bail, Context, Result};

use gparml::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use gparml::data::{digits, oilflow, synthetic};
use gparml::experiments::{self, common};
use gparml::linalg::Matrix;
use gparml::runtime::Manifest;
use gparml::util::cli::Args;
use gparml::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("experiment") => {
            let name = args
                .positional
                .get(1)
                .context("usage: gparml experiment <fig1..fig8|all>")?;
            experiments::run(name, &args)
        }
        Some("train") => train(&args),
        Some("info") => info(&args),
        _ => {
            eprintln!(
                "usage: gparml <experiment|train|info> [flags]\n\
                 experiments: fig1 fig2 fig3 fig4 fig5 fig6 fig7 fig8 all\n\
                 common flags: --n --iters --workers --seed --out DIR --artifacts DIR"
            );
            bail!("no command given")
        }
    }
}

fn info(args: &Args) -> Result<()> {
    let man = Manifest::load(&common::artifacts_dir(args))?;
    println!("artifacts in {} (dtype {}):", man.dir.display(), man.dtype);
    for (name, cfg) in &man.configs {
        println!(
            "  {name:>8}: m={:<4} q={:<3} d={:<4} B={:<5} block_n={:<4} entries={}",
            cfg.m,
            cfg.q,
            cfg.d,
            cfg.cap,
            cfg.block_n,
            cfg.entries.len()
        );
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let dataset = args.get_str("data", "synthetic");
    let iters = args.get_usize("iters", 30)?;
    let workers = args.get_usize("workers", 4)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let model = match args.get_str("model", "lvm") {
        "reg" | "regression" => ModelKind::Regression,
        _ => ModelKind::Lvm,
    };

    match dataset {
        "synthetic" => {
            let n = args.get_usize("n", 2000)?;
            let data = synthetic::generate(n, 0.05, seed);
            if model == ModelKind::Lvm {
                let (mut t, _) = common::lvm_trainer(args, "small", &data.y, 16, 2, workers, seed)?;
                run_loop(&mut t, iters)
            } else {
                let mut rng = Rng::new(seed);
                let xmu = Matrix::from_fn(n, 2, |i, j| {
                    if j == 0 {
                        data.latent[i]
                    } else {
                        0.1 * rng.normal()
                    }
                });
                let shards = partition(&xmu, &Matrix::zeros(n, 2), &data.y, 0.0, workers);
                let mut prng = Rng::new(seed ^ 1);
                let params = gparml::gp::GlobalParams {
                    z: Matrix::from_fn(16, 2, |_, _| prng.range(-3.0, 3.0)),
                    log_ls: vec![0.0, 0.0],
                    log_sf2: 0.0,
                    log_beta: 1.0,
                };
                let cfg = TrainConfig {
                    artifact: "small".into(),
                    artifacts_dir: common::artifacts_dir(args),
                    workers,
                    model,
                    global_opt: GlobalOpt::Scg,
                    seed,
                    ..Default::default()
                };
                let mut t = Trainer::new(cfg, params, shards)?;
                run_loop(&mut t, iters)
            }
        }
        "oilflow" => {
            let n = args.get_usize("n", 600)?;
            let data = oilflow::generate(n, seed);
            let (mut t, _) = common::lvm_trainer(args, "oil", &data.y, 32, 6, workers, seed)?;
            run_loop(&mut t, iters)
        }
        "digits" => {
            let n = args.get_usize("n", 300)?;
            let data = digits::generate(n, 0.02, seed);
            let (mut t, _) = common::lvm_trainer(args, "digits", &data.y, 48, 8, workers, seed)?;
            run_loop(&mut t, iters)
        }
        other => bail!("unknown dataset {other:?} (synthetic|oilflow|digits)"),
    }
}

fn run_loop(t: &mut Trainer, iters: usize) -> Result<()> {
    println!("training: {} workers, {} iterations", t.workers(), iters);
    for i in 0..iters {
        let f = t.step()?;
        if i % 5 == 0 || i == iters - 1 {
            let it = t.log.iterations.last().unwrap();
            println!(
                "iter {i:>4}: F = {f:>14.3}  modeled {:.4}s  compute {:.4}s  failed {:?}",
                it.modeled_parallel_secs(),
                it.total_compute_secs(),
                it.failed_workers
            );
        }
    }
    println!(
        "done. startup {:.2}s, mean iteration (modeled parallel) {:.4}s, load gap {:.2}%",
        t.log.startup_secs,
        t.log.mean_iteration_modeled_secs(),
        t.log.mean_load_gap() * 100.0
    );
    Ok(())
}
