//! `gparml` — distributed variational inference for sparse GPs and the
//! GPLVM (Gal, van der Wilk & Rasmussen, 2014).
//!
//! ```text
//! gparml experiment <fig1..fig8|flights|mnist-lvm|all> [--n N] [--iters I] ...
//! gparml train [--data synthetic|oilflow|digits] [--model reg|lvm] ...
//!              [--store DIR] [--chunk-rows R]    # stream a packed store
//!              [--shard-local]                   # workers read own shards
//!              [--math-mode strict|fast]          # execution policy
//!              [--fill-threads N]                # intra-worker psi fill
//!              [--connect HOST:PORT,HOST:PORT]   # drive TCP workers
//!              [--export MODEL] [--checkpoint F] [--resume F]
//! gparml data pack --out DIR (--csv F [--x-cols C] | --gen NAME)
//!                  [--n N] [--seed S] [--shard-rows R] [--artifact A]
//! gparml data inspect --store DIR [--verify]    # manifest + checksums
//! gparml export [train flags] --out model.gpm   # train, then save the
//!                                               # TrainedModel artifact
//! gparml predict (--model model.gpm | --connect ADDR) [--n N] [--seed S]
//!                [--points file.csv]            # real test points (q or 2q cols)
//!                [--project]                    # LVM latent projection (--points
//!                                               # rows are observed outputs)
//!                [--out preds.csv]              # cluster-free serving
//! gparml serve --model model.gpm --listen ADDR [--clients N]
//!              [--threads W] [--batch-rows R]   # worker pool + micro-batch cap
//!              [--fill-threads N]               # split batch rows over N threads
//!              [--trace-out FILE]               # span JSONL (DESIGN.md §10)
//!              [--control ADDR]                 # join a fleet (DESIGN.md §12)
//!              [--advertise ADDR] [--heartbeat-ms N]
//! gparml control --listen ADDR [--stale-ms N] [--sweep-ms N]
//!                                               # fleet membership registry
//! gparml lb --listen ADDR (--connect CONTROL | --backends A,B,...)
//!           [--clients N] [--interval-ms N] [--drain-timeout-ms N]
//!                                               # fleet front door
//! gparml reload --connect ADDR                  # hot-swap the served model
//!                                               # (via an lb: rolling fleet swap)
//! gparml stats --connect ADDR [--json] [--watch] [--interval-ms N] [--count K]
//!                                               # live metrics snapshot
//! gparml worker (--listen ADDR | --connect LEADER) [--artifacts DIR]
//!               [--math-mode strict|fast]         # pin; reject the other
//!               [--fill-threads N]                # pin; reject a mismatch
//!               [--heartbeat-ms N]                # leader-liveness window
//! gparml bench psi [--config perf] [--reps R]    # writes BENCH_psi.json
//! gparml bench predict [--points B] [--threads T] # BENCH_predict.json
//! gparml bench check [--baseline F] [--current F] # CI regression gate
//! gparml bench rebaseline [--headroom X]          # regenerate baseline
//! gparml analyze [--json] [--allowlist F]  # repo-invariant lint engine
//!                                          # (DESIGN.md §14); nonzero on
//!                                          # unallowed findings
//! gparml info                      # artifact manifest summary
//! ```
//!
//! `worker` turns this process into a cluster node: it either listens
//! for a leader (`--listen`) or dials one (`--connect`), then serves
//! map rounds over the binary wire protocol until shutdown. A leader
//! started with `train --connect a,b,c` drives those processes instead
//! of in-process threads.
//!
//! The train/serve split (DESIGN.md §9): `export` persists the tiny
//! product of training as a `TrainedModel` artifact; `predict` serves
//! batches from it with **zero** training workers, either locally
//! (`--model`) or against a running `serve` process (`--connect`).
//! Predictions are bit-identical across all three paths.

use anyhow::{bail, Context, Result};

use gparml::cluster::Backend;
use gparml::coordinator::{partition, GlobalOpt, ModelKind, StreamConfig, TrainConfig, Trainer};
use gparml::data::{digits, flights, oilflow, synthetic};
use gparml::experiments::{self, common};
use gparml::linalg::Matrix;
use gparml::model::{serve, Predictor, TrainedModel};
use gparml::runtime::Manifest;
use gparml::util::cli::Args;
use gparml::util::json::Json;
use gparml::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    // `--trace-out FILE` on any command: record structured spans/events
    // to JSONL (DESIGN.md §10); flushed before exit either way
    if let Some(path) = args.get("trace-out") {
        gparml::obs::trace::init(std::path::Path::new(path))?;
    }
    let result = run_command(&args);
    gparml::obs::trace::flush();
    result
}

fn run_command(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("experiment") => {
            let name = args
                .positional
                .get(1)
                .context("usage: gparml experiment <fig1..fig8|all>")?;
            experiments::run(name, args)
        }
        Some("train") => train(args),
        Some("export") => export_cmd(args),
        Some("predict") => predict_cmd(args),
        Some("serve") => serve_cmd(args),
        Some("control") => control_cmd(args),
        Some("lb") => lb_cmd(args),
        Some("reload") => reload_cmd(args),
        Some("stats") => stats_cmd(args),
        Some("worker") => worker(args),
        Some("bench") => bench(args),
        Some("data") => data_cmd(args),
        Some("analyze") => gparml::analyze::run_cli(args),
        Some("info") => info(args),
        _ => {
            eprintln!(
                "usage: gparml <experiment|train|export|predict|serve|control|lb|reload|stats|worker|bench|data|analyze|info> [flags]\n\
                 experiments: fig1 fig2 fig3 fig4 fig5 fig6 fig7 fig8 flights mnist-lvm all\n\
                 common flags: --n --iters --workers --seed --out DIR --artifacts DIR\n\
                 cluster: gparml worker --connect LEADER_ADDR (or --listen ADDR)\n\
                          [--heartbeat-ms N],\n\
                          gparml train --connect W1,W2,... (synthetic dataset or --store)\n\
                 store:   gparml data pack --out DIR (--csv F | --gen NAME),\n\
                          gparml data inspect --store DIR [--verify],\n\
                          gparml train --store DIR [--chunk-rows R] [--shard-local]\n\
                 serving: gparml export [train flags] --out model.gpm,\n\
                          gparml predict (--model F | --connect ADDR) [--points file.csv]\n\
                          [--project] [--out preds.csv],\n\
                          gparml serve --model F --listen ADDR [--clients N]\n\
                          [--threads W] [--batch-rows R]\n\
                          [--control ADDR --advertise ADDR --heartbeat-ms N],\n\
                          gparml reload --connect ADDR (hot-swap the served model)\n\
                 fleet:   gparml control --listen ADDR [--stale-ms N],\n\
                          gparml lb --listen ADDR (--connect CONTROL | --backends A,B)\n\
                          [--interval-ms N] [--drain-timeout-ms N],\n\
                          reload/stats/predict --connect work against an lb too\n\
                 obs:     gparml stats --connect ADDR [--json] [--watch]\n\
                          [--interval-ms N] [--count K],\n\
                          --trace-out FILE on any command (span JSONL, DESIGN.md §10)\n\
                 math:    --math-mode strict|fast on train/bench/worker (DESIGN.md §8),\n\
                          --fill-threads N on train/worker/predict/serve (DESIGN.md §11)\n\
                 bench:   gparml bench psi [--config perf] [--points B] [--reps R],\n\
                          gparml bench predict [--points B] [--threads T] [--clients C],\n\
                          gparml bench check [--baseline F] [--current F] [--max-regress X],\n\
                          gparml bench rebaseline [--headroom X] [--out F]\n\
                 lint:    gparml analyze [--json] [--allowlist F] (DESIGN.md §14)"
            );
            bail!("no command given")
        }
    }
}

/// Machine-readable hot-path benchmarks (`gparml bench psi|predict`),
/// the CI regression gate over their JSON (`gparml bench check`) and
/// in-place baseline regeneration (`gparml bench rebaseline`).
fn bench(args: &Args) -> Result<()> {
    match args.positional.get(1).map(|s| s.as_str()) {
        Some("psi") => gparml::runtime::psibench::run(args),
        Some("predict") => gparml::model::bench::run(args),
        Some("check") => gparml::runtime::psibench::check(args),
        Some("rebaseline") => gparml::runtime::psibench::rebaseline(args),
        other => bail!("usage: gparml bench <psi|predict|check|rebaseline> [flags] (got {other:?})"),
    }
}

/// `gparml export`: run the `train` flow, then persist the trained
/// model (`--out`, default `model.gpm`).
fn export_cmd(args: &Args) -> Result<()> {
    let mut args = args.clone();
    let out = args.get_str("out", "model.gpm").to_string();
    args.flags.insert("export".into(), out);
    args.flags.remove("out"); // `--out` is the artifact path here, not a results dir
    train(&args)
}

/// Deterministic test points for the predict CLI: both a local and a
/// remote client at the same `--n`/`--seed` generate identical batches,
/// so their outputs can be diffed byte-for-byte.
fn predict_points(n: usize, q: usize, seed: u64) -> (Matrix, Matrix) {
    let mut rng = Rng::new(seed ^ 0x9E3779B97F4A7C15);
    let xt_mu = Matrix::from_fn(n, q, |_, _| rng.range(-2.0, 2.0));
    (xt_mu, Matrix::zeros(n, q))
}

/// Real test points from `--points file.csv`: either q columns (input
/// means, zero input variance) or 2q columns (means then variances).
fn load_predict_points(path: &str, q: usize) -> Result<(Matrix, Matrix)> {
    let m = gparml::util::csv::read_matrix(std::path::Path::new(path))?;
    if m.cols() == q {
        let rows = m.rows();
        Ok((m, Matrix::zeros(rows, q)))
    } else if m.cols() == 2 * q {
        let xt_mu = Matrix::from_fn(m.rows(), q, |i, j| m[(i, j)]);
        let xt_var = Matrix::from_fn(m.rows(), q, |i, j| m[(i, q + j)]);
        Ok((xt_mu, xt_var))
    } else {
        bail!(
            "--points {path} has {} columns; the model expects q={q} (means) \
             or 2q={} (means,variances)",
            m.cols(),
            2 * q
        )
    }
}

/// Observed outputs for `--project`: d columns, one observation per row.
fn load_project_points(path: &str, d: usize) -> Result<Matrix> {
    let y = gparml::util::csv::read_matrix(std::path::Path::new(path))?;
    if y.cols() != d {
        bail!(
            "--points {path} has {} columns; projecting into latent space \
             needs d={d} observed output dimensions per row",
            y.cols()
        );
    }
    Ok(y)
}

/// Write predictions as CSV with round-trip-exact float formatting
/// (`{:.17e}`), so two bit-identical prediction paths produce
/// byte-identical files.
fn write_predictions(
    path: &str,
    xt_mu: &Matrix,
    mean: &Matrix,
    var: &[f64],
) -> Result<()> {
    use std::fmt::Write as _;
    let mut out = String::new();
    let (q, d) = (xt_mu.cols(), mean.cols());
    for j in 0..q {
        let _ = write!(out, "x{j},");
    }
    for j in 0..d {
        let _ = write!(out, "mean{j},");
    }
    out.push_str("var\n");
    for i in 0..xt_mu.rows() {
        for j in 0..q {
            let _ = write!(out, "{:.17e},", xt_mu[(i, j)]);
        }
        for j in 0..d {
            let _ = write!(out, "{:.17e},", mean[(i, j)]);
        }
        let _ = writeln!(out, "{:.17e}", var[i]);
    }
    std::fs::write(path, out).with_context(|| format!("writing predictions to {path}"))?;
    println!("wrote {path}");
    Ok(())
}

/// `gparml predict`: serve a batch from a model artifact — locally
/// (`--model PATH`, zero processes) or against a running predict
/// server (`--connect ADDR`, zero local model state). `--points` reads
/// real test points from CSV; `--project` maps observed outputs into
/// the LVM latent space instead of predicting outputs.
fn predict_cmd(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 64)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let project = args.has("project");
    let points = args.get("points");

    if let Some(addr) = args.get("connect") {
        let mut client = serve::ServeClient::with_opts(addr, serve::ConnectOpts::from_args(args)?)?;
        let info = client.model_info()?;
        println!(
            "predict server at {addr}: m={}, q={}, d={}, model version {}",
            info.m, info.q, info.d, info.version
        );
        if project {
            let path =
                points.context("--project needs --points file.csv (observed outputs, d columns)")?;
            let y = load_project_points(path, info.d)?;
            let (xmu, conf) = client.project(&y)?;
            client.hangup();
            report_projection(args, &y, &xmu, &conf, &format!("server {addr}"))
        } else {
            let (xt_mu, xt_var) = match points {
                Some(p) => load_predict_points(p, info.q)?,
                None => predict_points(n, info.q, seed),
            };
            let (mean, var, trace_id) = client.predict_traced(&xt_mu, &xt_var)?;
            client.hangup();
            println!("request id {trace_id:#018x} (grep it in the server's --trace-out JSONL)");
            report_prediction(args, &xt_mu, &mean, &var, &format!("server {addr}"))
        }
    } else {
        let path = args
            .get("model")
            .context("predict needs --model PATH or --connect ADDR")?;
        let model = TrainedModel::load(std::path::Path::new(path))?;
        let mut pred = Predictor::new(&model)?;
        pred.set_fill_threads(common::fill_threads(args)?);
        println!(
            "model {path}: m={}, q={}, d={} (artifact {:?}, {} iterations, final bound {:.3})",
            pred.m(),
            pred.q(),
            pred.dout(),
            model.meta.artifact,
            model.meta.iterations,
            model.meta.final_bound
        );
        if project {
            let csv =
                points.context("--project needs --points file.csv (observed outputs, d columns)")?;
            let y = load_project_points(csv, pred.dout())?;
            let (xmu, conf) = pred.project(&y)?;
            report_projection(args, &y, &xmu, &conf, &format!("model {path}"))
        } else {
            let (xt_mu, xt_var) = match points {
                Some(p) => load_predict_points(p, pred.q())?,
                None => predict_points(n, pred.q(), seed),
            };
            let (mean, var) = pred.predict(&xt_mu, &xt_var)?;
            report_prediction(args, &xt_mu, &mean, &var, &format!("model {path}"))
        }
    }
}

/// Print the prediction summary and write `--out` CSV if asked.
fn report_prediction(
    args: &Args,
    xt_mu: &Matrix,
    mean: &Matrix,
    var: &[f64],
    origin: &str,
) -> Result<()> {
    let mean_abs =
        mean.data().iter().map(|v| v.abs()).sum::<f64>() / mean.data().len().max(1) as f64;
    let var_mean = var.iter().sum::<f64>() / var.len().max(1) as f64;
    println!(
        "predicted {} points from {origin}: mean|mean| = {mean_abs:.6}, mean var = {var_mean:.6}",
        xt_mu.rows()
    );
    if let Some(path) = args.get("out") {
        write_predictions(path, xt_mu, mean, var)?;
    }
    Ok(())
}

/// Print the projection summary and write `--out` CSV if asked.
fn report_projection(
    args: &Args,
    y: &Matrix,
    xmu: &Matrix,
    conf: &[f64],
    origin: &str,
) -> Result<()> {
    let conf_mean = conf.iter().sum::<f64>() / conf.len().max(1) as f64;
    println!(
        "projected {} observations into the q={} latent space from {origin}: \
         mean confidence = {conf_mean:.6}",
        y.rows(),
        xmu.cols()
    );
    if let Some(path) = args.get("out") {
        write_projections(path, xmu, conf)?;
    }
    Ok(())
}

/// Write latent projections as CSV (same round-trip-exact formatting
/// as [`write_predictions`]).
fn write_projections(path: &str, xmu: &Matrix, conf: &[f64]) -> Result<()> {
    use std::fmt::Write as _;
    let mut out = String::new();
    for j in 0..xmu.cols() {
        let _ = write!(out, "x{j},");
    }
    out.push_str("conf\n");
    for i in 0..xmu.rows() {
        for j in 0..xmu.cols() {
            let _ = write!(out, "{:.17e},", xmu[(i, j)]);
        }
        let _ = writeln!(out, "{:.17e}", conf[i]);
    }
    std::fs::write(path, out).with_context(|| format!("writing projections to {path}"))?;
    println!("wrote {path}");
    Ok(())
}

/// `gparml serve`: the TCP serving subsystem — one hot-swappable
/// model, a reader thread per client, a worker pool micro-batching
/// compute across clients, zero training workers. `--control ADDR`
/// additionally joins a fleet (DESIGN.md §12): a scoped thread
/// registers with the control plane and heartbeats the live model
/// version until the accept loop exits.
fn serve_cmd(args: &Args) -> Result<()> {
    let path = args.get("model").context("serve needs --model PATH")?;
    let model = TrainedModel::load(std::path::Path::new(path))?;
    let mut pred = Predictor::new(&model)?;
    // `--fill-threads N`: split each coalesced batch's rows over N
    // threads (bit-identical at any value; survives hot reloads)
    pred.set_fill_threads(common::fill_threads(args)?);
    let listen = common::listen_addr(args, "127.0.0.1:0")?;
    let opts = gparml::model::ServeOptions::from_args(args)?;
    let listener =
        std::net::TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
    let local = listener.local_addr()?;
    println!(
        "gparml serve: {path} (m={}, q={}, d={}) listening on {local} \
         ({} worker thread(s), micro-batch cap {} rows)",
        pred.m(),
        pred.q(),
        pred.dout(),
        opts.workers,
        opts.max_batch_rows
    );
    let state = gparml::model::ServeState::with_path(pred, std::path::PathBuf::from(path));
    let stats = match args.get("control") {
        Some(control_addr) => {
            // `--advertise` is the address replicas are REACHED at —
            // defaults to the bound address, which only spans hosts if
            // `--listen` named a routable interface
            let advertise = args.get_str("advertise", "").to_string();
            let advertise = if advertise.is_empty() {
                local.to_string()
            } else {
                advertise
            };
            let heartbeat = common::interval_ms(args, "heartbeat-ms", 1000)?;
            println!("fleet: registering with control plane at {control_addr} as {advertise}");
            let stop = std::sync::atomic::AtomicBool::new(false);
            let (state_ref, stop_ref) = (&state, &stop);
            std::thread::scope(|s| {
                let registrar = s.spawn(|| {
                    gparml::fleet::client::registration_loop(
                        control_addr,
                        &advertise,
                        state_ref,
                        heartbeat,
                        stop_ref,
                    )
                });
                let stats = serve::serve(&listener, state_ref, &opts);
                stop_ref.store(true, std::sync::atomic::Ordering::Release);
                let _ = registrar.join();
                stats
            })?
        }
        None => serve::serve(&listener, &state, &opts)?,
    };
    eprintln!(
        "[gparml-serve] exiting after {} client(s): {} request(s), {} kernel batch(es), \
         {} coalesced job(s)",
        stats.clients, stats.requests, stats.batches, stats.coalesced_jobs
    );
    Ok(())
}

/// `gparml control`: the fleet control plane (DESIGN.md §12) — a
/// membership registry serve replicas register with. Holds no model
/// and forwards nothing; runs until killed.
fn control_cmd(args: &Args) -> Result<()> {
    let listen = common::listen_addr(args, "127.0.0.1:0")?;
    let opts = gparml::fleet::ControlOptions {
        stale_ms: args.get_usize("stale-ms", 5_000)?.max(1) as u64,
        sweep_ms: args.get_usize("sweep-ms", 500)? as u64,
    };
    let listener =
        std::net::TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
    println!(
        "gparml control: listening on {} (staleness window {}ms, sweep every {}ms)",
        listener.local_addr()?,
        opts.stale_ms,
        opts.sweep_ms
    );
    gparml::fleet::run_control(&listener, &opts)
}

/// `gparml lb`: the fleet front door — one serve-compatible address
/// backed by many replicas, discovered from a control plane
/// (`--connect`) or pinned statically (`--backends`).
fn lb_cmd(args: &Args) -> Result<()> {
    let listen = common::listen_addr(args, "127.0.0.1:0")?;
    let upstream = match (args.get("connect"), args.get("backends")) {
        (Some(control), None) => gparml::fleet::Upstream::Control(control.to_string()),
        (None, Some(list)) => {
            let backends: Vec<String> = list
                .split(',')
                .map(|a| a.trim().to_string())
                .filter(|a| !a.is_empty())
                .collect();
            anyhow::ensure!(!backends.is_empty(), "--backends needs at least one HOST:PORT");
            gparml::fleet::Upstream::Static(backends)
        }
        _ => bail!(
            "lb needs exactly one of --connect CONTROL_ADDR or \
             --backends HOST:PORT[,HOST:PORT...]"
        ),
    };
    let opts = gparml::fleet::LbOptions {
        max_clients: args.get_usize("clients", 0)? as u64,
        refresh_ms: common::interval_ms(args, "interval-ms", 1000)?.as_millis() as u64,
        drain_timeout_ms: args.get_usize("drain-timeout-ms", 10_000)? as u64,
        connect: serve::ConnectOpts::from_args(args)?,
    };
    let listener =
        std::net::TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
    let origin = match &upstream {
        gparml::fleet::Upstream::Control(addr) => format!("control plane {addr}"),
        gparml::fleet::Upstream::Static(list) => format!("{} static backend(s)", list.len()),
    };
    println!(
        "gparml lb: listening on {} ({origin}, refresh every {}ms)",
        listener.local_addr()?,
        opts.refresh_ms
    );
    let stats = gparml::fleet::run_lb(&listener, &upstream, &opts)?;
    eprintln!(
        "[gparml-lb] exiting after {} client(s): {} request(s), {} failover(s), \
         {} replica reload(s)",
        stats.clients, stats.requests, stats.failovers, stats.reloads
    );
    Ok(())
}

/// `gparml reload`: tell a running predict server to atomically
/// re-read its model artifact — the SIGHUP-equivalent control client.
/// Pointed at an lb, the same frame drives a fleet-wide rolling swap.
fn reload_cmd(args: &Args) -> Result<()> {
    let addr = common::connect_addr(
        args,
        "reload needs --connect ADDR (a running `gparml serve` or `gparml lb`)",
    )?;
    let mut client = serve::ServeClient::with_opts(addr, serve::ConnectOpts::from_args(args)?)?;
    let info = client.reload()?;
    client.hangup();
    println!(
        "reloaded: server at {addr} now serves model version {} (m={}, q={}, d={})",
        info.version, info.m, info.q, info.d
    );
    Ok(())
}

/// `gparml stats`: scrape a running predict server's live metrics
/// registry (the `ServeStats` control frame, answered inline by the
/// reader thread without queueing behind compute) and render it.
/// `--watch` re-polls every `--interval-ms` (default 1000) until
/// `--count` snapshots have been printed (0 = forever).
fn stats_cmd(args: &Args) -> Result<()> {
    let addr = common::connect_addr(
        args,
        "stats needs --connect ADDR (a running `gparml serve`, `control` or `lb`)",
    )?;
    let raw = args.has("json");
    let watch = args.has("watch");
    let interval = common::interval_ms(args, "interval-ms", 1000)?;
    let count = args.get_usize("count", 0)?;
    // ONE connection held across all polls — `--watch` used to dial a
    // fresh TCP connection per snapshot, inflating the very
    // client/connection counters it was watching. ServeClient
    // reconnects internally only after an error.
    let mut client = serve::ServeClient::with_opts(addr, serve::ConnectOpts::from_args(args)?)?;
    let mut printed = 0usize;
    loop {
        let snapshot = client.stats()?;
        if raw {
            println!("{snapshot}");
        } else {
            render_stats(addr, &snapshot)?;
        }
        printed += 1;
        if !watch || (count > 0 && printed >= count) {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

/// Human rendering of a metrics snapshot: headline serve gauges, the
/// coalescing ratio, then every counter/gauge/histogram by name.
fn render_stats(addr: &str, snapshot: &str) -> Result<()> {
    let json = Json::parse(snapshot).context("parsing stats snapshot")?;
    let section = |key: &str| -> Vec<(String, Json)> {
        json.opt(key)
            .and_then(|s| s.as_obj().ok().cloned())
            .map(|m| m.into_iter().collect())
            .unwrap_or_default()
    };
    let counters = section("counters");
    let gauges = section("gauges");
    let histograms = section("histograms");
    let counter = |name: &str| -> f64 {
        counters
            .iter()
            .find(|(k, _)| k == name)
            .and_then(|(_, v)| v.as_f64().ok())
            .unwrap_or(0.0)
    };
    let batches = counter("serve.batches");
    let coalesced = counter("serve.coalesced_jobs");
    let ratio = if batches > 0.0 { coalesced / batches } else { 0.0 };
    println!("stats from {addr}: coalescing ratio {ratio:.2} jobs/batch");
    for (name, v) in &gauges {
        if let Ok(x) = v.as_f64() {
            println!("  gauge    {name:<32} {x:.0}");
        }
    }
    for (name, v) in &counters {
        if let Ok(x) = v.as_f64() {
            println!("  counter  {name:<32} {x:.0}");
        }
    }
    for (name, h) in &histograms {
        let field = |f: &str| -> String {
            match h.opt(f).and_then(|v| v.as_f64().ok()) {
                Some(x) => format!("{x:.0}"),
                None => "-".to_string(),
            }
        };
        println!(
            "  hist     {name:<32} n={} p50={} p90={} p99={}",
            field("count"),
            field("p50"),
            field("p90"),
            field("p99")
        );
    }
    Ok(())
}

/// Run this process as a cluster worker node. `--math-mode` and
/// `--fill-threads` pin the node: an `Init` negotiating a different
/// value is rejected at bring-up.
fn worker(args: &Args) -> Result<()> {
    let artifacts = common::artifacts_dir(args);
    let pinned = common::math_mode_opt(args)?;
    let pinned_fill = common::fill_threads_opt(args)?;
    // `--heartbeat-ms N`: expected leader ping cadence. Sets the read
    // timeout used to count overdue heartbeats (obs metric
    // `heartbeat_overdue`); absent = block forever, as before.
    let heartbeat_ms = if args.get("heartbeat-ms").is_some() {
        Some(args.get_usize("heartbeat-ms", 5000)? as u64)
    } else {
        None
    };
    let served = if let Some(addr) = args.get("connect") {
        gparml::cluster::node::run_worker_connect(
            addr,
            &artifacts,
            pinned,
            pinned_fill,
            heartbeat_ms,
        )?
    } else {
        let addr = args.get_str("listen", "127.0.0.1:0");
        gparml::cluster::node::run_worker_listen(
            addr,
            &artifacts,
            pinned,
            pinned_fill,
            heartbeat_ms,
        )?
    };
    eprintln!("[gparml-worker] exiting after {served} requests");
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    let man = Manifest::load(&common::artifacts_dir(args))?;
    println!("artifacts in {} (dtype {}):", man.dir.display(), man.dtype);
    for (name, cfg) in &man.configs {
        println!(
            "  {name:>8}: m={:<4} q={:<3} d={:<4} B={:<5} block_n={:<4} entries={}",
            cfg.m,
            cfg.q,
            cfg.d,
            cfg.cap,
            cfg.block_n,
            cfg.entries.len()
        );
    }
    Ok(())
}

/// `gparml data <pack|inspect>`: the out-of-core sharded dataset
/// store (DESIGN.md §13). `pack` writes a store directory from a CSV
/// (`--csv FILE --x-cols C`) or any built-in generator
/// (`--gen synthetic|oilflow|digits|flights`); `inspect` prints a
/// store's manifest and, with `--verify`, streams every shard to check
/// all checksums against the manifest.
fn data_cmd(args: &Args) -> Result<()> {
    match args.positional.get(1).map(|s| s.as_str()) {
        Some("pack") => data_pack(args),
        Some("inspect") => data_inspect(args),
        other => bail!(
            "usage: gparml data <pack|inspect> [flags] (got {other:?})\n\
             pack:    --out STORE_DIR (--csv FILE [--x-cols C] | --gen \
             synthetic|oilflow|digits|flights)\n\
             \x20        [--n N] [--seed S] [--noise X] [--shard-rows R] \
             [--chunk-rows R] [--artifact NAME]\n\
             inspect: --store STORE_DIR [--verify]"
        ),
    }
}

fn data_pack(args: &Args) -> Result<()> {
    let out = args.get("out").context("data pack needs --out STORE_DIR")?;
    let dir = std::path::PathBuf::from(out);
    let shard_rows = args.get_usize("shard-rows", 8192)?;
    let chunk_rows = args.get_usize("chunk-rows", 2048)?.max(1);
    let seed = args.get_usize("seed", 0)? as u64;
    let t0 = std::time::Instant::now();
    let manifest = match (args.get("csv"), args.get("gen")) {
        (Some(csv), None) => {
            let x_cols = args.get_usize("x-cols", 0)?;
            let mut w = gparml::store::StoreWriter::create(
                &dir,
                x_cols,
                shard_rows,
                args.get("artifact"),
            )?;
            // stream the CSV in chunks: neither the file nor the matrix
            // is ever fully materialised
            for chunk in gparml::util::csv::read_matrix_chunked(
                std::path::Path::new(csv),
                chunk_rows,
            )? {
                w.append(&chunk?)?;
            }
            w.finish()?
        }
        (None, Some(gen)) => pack_generated(args, &dir, gen, shard_rows, chunk_rows, seed)?,
        _ => bail!(
            "data pack needs exactly one of --csv FILE or --gen \
             synthetic|oilflow|digits|flights"
        ),
    };
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "packed {} rows x {} cols ({} input col(s)) into {} shard(s) at {} ({:.2}s)",
        manifest.n,
        manifest.dims,
        manifest.x_cols,
        manifest.shards.len(),
        dir.display(),
        secs
    );
    Ok(())
}

/// Pack a built-in generator into a store. `flights` generates
/// chunk-by-chunk (O(chunk) memory at any n — the paper-scale path);
/// the other generators are modest and append from memory. Regression
/// generators store inputs-then-outputs rows with `x_cols` set; the
/// LVM generators (oilflow, digits) store outputs only (`x_cols` 0).
fn pack_generated(
    args: &Args,
    dir: &std::path::Path,
    gen: &str,
    shard_rows: usize,
    chunk_rows: usize,
    seed: u64,
) -> Result<gparml::store::StoreManifest> {
    let artifact = |default: &str| -> String {
        args.get_str("artifact", default).to_string()
    };
    match gen {
        "flights" => {
            let n = args.get_usize("n", 10_000)?;
            let mut w = gparml::store::StoreWriter::create(
                dir,
                flights::INPUT_COLS,
                shard_rows,
                Some(&artifact("flights")),
            )?;
            let mut start = 0usize;
            while start < n {
                let rows = chunk_rows.min(n - start);
                w.append(&flights::chunk(seed, start, rows))?;
                start += rows;
            }
            w.finish()
        }
        "synthetic" => {
            // same construction as `train --data synthetic --model reg`:
            // col 0 the true latent, col 1 a small nuisance input
            let n = args.get_usize("n", 2000)?;
            let noise = args.get_f64("noise", 0.05)?;
            let data = synthetic::generate(n, noise, seed);
            let mut rng = Rng::new(seed);
            let d = data.y.cols();
            let rows = Matrix::from_fn(n, 2 + d, |i, j| match j {
                0 => data.latent[i],
                1 => 0.1 * rng.normal(),
                _ => data.y[(i, j - 2)],
            });
            let mut w = gparml::store::StoreWriter::create(
                dir,
                2,
                shard_rows,
                Some(&artifact("small")),
            )?;
            w.append(&rows)?;
            w.finish()
        }
        "oilflow" => {
            let n = args.get_usize("n", 600)?;
            let data = oilflow::generate(n, seed);
            let mut w = gparml::store::StoreWriter::create(
                dir,
                0,
                shard_rows,
                Some(&artifact("oil")),
            )?;
            w.append(&data.y)?;
            w.finish()
        }
        "digits" => {
            let n = args.get_usize("n", 300)?;
            let noise = args.get_f64("noise", 0.02)?;
            let data = digits::generate(n, noise, seed);
            let mut w = gparml::store::StoreWriter::create(
                dir,
                0,
                shard_rows,
                Some(&artifact("digits")),
            )?;
            w.append(&data.y)?;
            w.finish()
        }
        other => bail!("unknown generator {other:?} (synthetic|oilflow|digits|flights)"),
    }
}

fn data_inspect(args: &Args) -> Result<()> {
    let dir = args
        .get("store")
        .context("data inspect needs --store STORE_DIR")?;
    let src = gparml::store::ShardedDiskSource::open(std::path::Path::new(dir))?;
    let m = src.manifest();
    println!(
        "store {dir}: {} rows x {} cols ({} input, {} output), {} shard(s)",
        m.n,
        m.dims,
        m.x_cols,
        m.y_cols(),
        m.shards.len()
    );
    if let Some(a) = &m.artifact {
        println!("  artifact hint: {a}");
    }
    for (i, s) in m.shards.iter().enumerate() {
        println!(
            "  shard {i:>3}: rows [{}, {})  checksum {:#018x}  {}",
            s.start,
            s.start + s.rows,
            s.checksum,
            s.file
        );
    }
    if args.has("verify") {
        let bytes = src.verify()?;
        println!("verified {bytes} bytes: every shard matches both its own checksum and the manifest");
    }
    Ok(())
}

/// Worker addresses from `--connect a,b,c` (leader side).
fn connect_addrs(args: &Args) -> Option<Vec<String>> {
    args.get("connect").map(|s| {
        s.split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect()
    })
}

fn train(args: &Args) -> Result<()> {
    let dataset = args.get_str("data", "synthetic");
    let iters = args.get_usize("iters", 30)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let math_mode = common::math_mode(args)?;
    let fill_threads = common::fill_threads(args)?;
    let addrs = connect_addrs(args);
    let workers = match &addrs {
        Some(a) => a.len(),
        None => args.get_usize("workers", 4)?,
    };
    if let Some(a) = &addrs {
        if a.is_empty() {
            bail!("--connect needs at least one worker address (host:port[,host:port...])");
        }
    }
    // `--store DIR`: out-of-core bring-up from a packed dataset store
    // (DESIGN.md §13); works over threads and `--connect` alike
    if args.get("store").is_some() {
        return train_from_store(args, iters, seed, math_mode, fill_threads, addrs, workers);
    }
    let model = match args.get_str("model", "lvm") {
        "reg" | "regression" => ModelKind::Regression,
        _ => ModelKind::Lvm,
    };
    if addrs.is_some() && dataset != "synthetic" {
        bail!(
            "--connect currently supports --data synthetic or --store DIR (use the \
             library API for the rest)"
        );
    }

    match dataset {
        "synthetic" => {
            let n = args.get_usize("n", 2000)?;
            let data = synthetic::generate(n, 0.05, seed);
            let (params, shards, cfg) = if model == ModelKind::Lvm {
                let init = common::lvm_init(&data.y, 16, 2, seed);
                let shards = partition(&init.xmu, &init.xvar, &data.y, 1.0, workers);
                let cfg = TrainConfig {
                    artifact: "small".into(),
                    artifacts_dir: common::artifacts_dir(args),
                    workers,
                    model,
                    global_opt: GlobalOpt::Scg,
                    math_mode,
                    fill_threads,
                    seed,
                    ..Default::default()
                };
                (init.params, shards, cfg)
            } else {
                let mut rng = Rng::new(seed);
                let xmu = Matrix::from_fn(n, 2, |i, j| {
                    if j == 0 {
                        data.latent[i]
                    } else {
                        0.1 * rng.normal()
                    }
                });
                let shards = partition(&xmu, &Matrix::zeros(n, 2), &data.y, 0.0, workers);
                let mut prng = Rng::new(seed ^ 1);
                let params = gparml::gp::GlobalParams {
                    z: Matrix::from_fn(16, 2, |_, _| prng.range(-3.0, 3.0)),
                    log_ls: vec![0.0, 0.0],
                    log_sf2: 0.0,
                    log_beta: 1.0,
                };
                let cfg = TrainConfig {
                    artifact: "small".into(),
                    artifacts_dir: common::artifacts_dir(args),
                    workers,
                    model,
                    global_opt: GlobalOpt::Scg,
                    math_mode,
                    fill_threads,
                    seed,
                    ..Default::default()
                };
                (params, shards, cfg)
            };
            match addrs {
                Some(addrs) => {
                    println!("cluster: {} TCP worker processes ({addrs:?})", addrs.len());
                    let mut t = Trainer::connect_tcp(cfg, params, shards, &addrs)?;
                    run_loop(&mut t, iters, args)?;
                    let (tx, rx) = t.log.total_network_bytes();
                    println!("network: {tx} B to workers, {rx} B back");
                    Ok(())
                }
                None => {
                    let mut t = Trainer::new(cfg, params, shards)?;
                    run_loop(&mut t, iters, args)
                }
            }
        }
        "oilflow" => {
            let n = args.get_usize("n", 600)?;
            let data = oilflow::generate(n, seed);
            let (mut t, _) = common::lvm_trainer(args, "oil", &data.y, 32, 6, workers, seed)?;
            run_loop(&mut t, iters, args)
        }
        "digits" => {
            let n = args.get_usize("n", 300)?;
            let data = digits::generate(n, 0.02, seed);
            let (mut t, _) = common::lvm_trainer(args, "digits", &data.y, 48, 8, workers, seed)?;
            run_loop(&mut t, iters, args)
        }
        other => bail!("unknown dataset {other:?} (synthetic|oilflow|digits)"),
    }
}

/// `gparml train --store DIR`: regression training streamed from a
/// packed dataset store. The leader never materialises the dataset —
/// rows flow disk -> `chunk-rows`-sized chunks -> workers, so leader
/// peak memory is bounded by the chunk size, not n (DESIGN.md §13).
/// `--shard-local` (wire v9) goes further: each worker loads its own
/// store shard from disk and verifies the manifest checksum, and no
/// data rows cross the wire at all (requires one store shard per
/// worker — repack with `--shard-rows n/workers`).
fn train_from_store(
    args: &Args,
    iters: usize,
    seed: u64,
    math_mode: gparml::gp::MathMode,
    fill_threads: usize,
    addrs: Option<Vec<String>>,
    workers: usize,
) -> Result<()> {
    let dir = args.get("store").expect("checked by caller");
    let src = gparml::store::ShardedDiskSource::open(std::path::Path::new(dir))?;
    let man = src.manifest().clone();
    if man.x_cols == 0 {
        bail!(
            "store {dir} has no input columns (x_cols 0) — `train --store` is \
             regression-only; outputs-only stores are consumed by \
             `gparml experiment mnist-lvm`"
        );
    }
    let (q, d) = (man.x_cols, man.y_cols());
    let artifact = args
        .get("artifact")
        .map(str::to_string)
        .or_else(|| man.artifact.clone())
        .context("store has no artifact hint; pass --artifact NAME")?;
    let cfg = TrainConfig {
        artifact: artifact.clone(),
        artifacts_dir: common::artifacts_dir(args),
        workers,
        model: ModelKind::Regression,
        global_opt: GlobalOpt::Scg,
        math_mode,
        fill_threads,
        seed,
        ..Default::default()
    };
    let art = Manifest::load(&cfg.artifacts_dir)?.config(&artifact)?.clone();
    if art.q != q || art.d != d {
        bail!(
            "store {dir} ({q} input, {d} output col(s)) does not fit artifact \
             {artifact} (q={}, d={})",
            art.q,
            art.d
        );
    }
    let mut prng = Rng::new(seed ^ 1);
    let params = gparml::gp::GlobalParams {
        z: Matrix::from_fn(art.m, q, |_, _| prng.range(-3.0, 3.0)),
        log_ls: vec![0.0; q],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let shard_refs = if args.has("shard-local") {
        if man.shards.len() != workers {
            bail!(
                "--shard-local needs exactly one store shard per worker ({} shard(s), \
                 {workers} worker(s)); repack with --shard-rows n/workers",
                man.shards.len()
            );
        }
        Some(
            man.shards
                .iter()
                .enumerate()
                .map(|(i, e)| gparml::cluster::wire::ShardRef {
                    path: src.shard_path(i).display().to_string(),
                    checksum: e.checksum,
                    rows: e.rows as u32,
                    x_cols: man.x_cols as u32,
                    kl_weight: 0.0,
                })
                .collect(),
        )
    } else {
        None
    };
    let mapper = gparml::store::SplitColumns { x_cols: man.x_cols };
    let stream = StreamConfig {
        source: &src,
        mapper: &mapper,
        chunk_rows: args.get_usize("chunk-rows", 4096)?.max(1),
        kl_weight: 0.0,
        shard_refs,
    };
    println!(
        "store {dir}: {} rows x {} cols, {} shard(s), artifact {artifact}{}",
        man.n,
        man.dims,
        man.shards.len(),
        if stream.shard_refs.is_some() {
            " (worker-local shard load)"
        } else {
            ""
        }
    );
    match addrs {
        Some(addrs) => {
            println!("cluster: {} TCP worker processes ({addrs:?})", addrs.len());
            let mut t = Trainer::connect_tcp_streaming(cfg, params, &stream, &addrs)?;
            run_loop(&mut t, iters, args)?;
            let (tx, rx) = t.log.total_network_bytes();
            println!("network: {tx} B to workers, {rx} B back");
            Ok(())
        }
        None => {
            let mut t = Trainer::new_streaming(cfg, params, &stream)?;
            run_loop(&mut t, iters, args)
        }
    }
}

/// The outer training loop plus the train/serve-split plumbing:
/// `--resume CKPT` restores global parameters before iterating,
/// `--checkpoint CKPT` snapshots them after every iteration, and
/// `--export MODEL` persists the `TrainedModel` artifact at the end.
fn run_loop<B: Backend>(t: &mut Trainer<B>, iters: usize, args: &Args) -> Result<()> {
    if let Some(path) = args.get("resume") {
        let done = t.restore_checkpoint(std::path::Path::new(path))?;
        println!("resumed from {path} ({done} iterations completed there)");
    }
    println!("training: {} workers, {} iterations", t.workers(), iters);
    let checkpoint = args.get("checkpoint");
    for i in 0..iters {
        let f = t.step()?;
        if let Some(path) = checkpoint {
            t.save_checkpoint(std::path::Path::new(path))?;
        }
        if i % 5 == 0 || i == iters - 1 {
            let it = t.log.iterations.last().unwrap();
            println!(
                "iter {i:>4}: F = {f:>14.3}  modeled {:.4}s  compute {:.4}s  failed {:?}",
                it.modeled_parallel_secs(),
                it.total_compute_secs(),
                it.failed_workers
            );
        }
    }
    // guard the summary: a 0-iteration run (a legitimate `--resume` +
    // `--export` re-export invocation) has no per-iteration series to
    // average — printing NaN% here would be noise, not signal
    if t.log.iterations.is_empty() {
        println!(
            "done. startup {:.2}s, no iterations run (re-export / resume-only invocation)",
            t.log.startup_secs
        );
    } else {
        println!(
            "done. startup {:.2}s, mean iteration (modeled parallel) {:.4}s, load gap {:.2}%",
            t.log.startup_secs,
            t.log.mean_iteration_modeled_secs(),
            t.log.mean_load_gap() * 100.0
        );
    }
    if let Some(path) = args.get("export") {
        let model = t.export_model()?;
        model.save(std::path::Path::new(path))?;
        println!(
            "exported TrainedModel to {path} (m={}, q={}, d={}, final bound {:.3})",
            model.m(),
            model.q(),
            model.dout,
            model.meta.final_bound
        );
    }
    Ok(())
}
