//! `gparml` — distributed variational inference for sparse GPs and the
//! GPLVM (Gal, van der Wilk & Rasmussen, 2014).
//!
//! ```text
//! gparml experiment <fig1..fig8|all> [--n N] [--iters I] [--workers W] ...
//! gparml train [--data synthetic|oilflow|digits] [--model reg|lvm] ...
//!              [--math-mode strict|fast]          # execution policy
//!              [--connect HOST:PORT,HOST:PORT]   # drive TCP workers
//! gparml worker (--listen ADDR | --connect LEADER) [--artifacts DIR]
//!               [--math-mode strict|fast]         # pin; reject the other
//! gparml bench psi [--config perf] [--reps R]    # writes BENCH_psi.json
//! gparml bench check [--baseline F] [--current F] # CI regression gate
//! gparml info                      # artifact manifest summary
//! ```
//!
//! `worker` turns this process into a cluster node: it either listens
//! for a leader (`--listen`) or dials one (`--connect`), then serves
//! map rounds over the binary wire protocol until shutdown. A leader
//! started with `train --connect a,b,c` drives those processes instead
//! of in-process threads.

use anyhow::{bail, Context, Result};

use gparml::cluster::Backend;
use gparml::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use gparml::data::{digits, oilflow, synthetic};
use gparml::experiments::{self, common};
use gparml::linalg::Matrix;
use gparml::runtime::Manifest;
use gparml::util::cli::Args;
use gparml::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("experiment") => {
            let name = args
                .positional
                .get(1)
                .context("usage: gparml experiment <fig1..fig8|all>")?;
            experiments::run(name, &args)
        }
        Some("train") => train(&args),
        Some("worker") => worker(&args),
        Some("bench") => bench(&args),
        Some("info") => info(&args),
        _ => {
            eprintln!(
                "usage: gparml <experiment|train|worker|bench|info> [flags]\n\
                 experiments: fig1 fig2 fig3 fig4 fig5 fig6 fig7 fig8 all\n\
                 common flags: --n --iters --workers --seed --out DIR --artifacts DIR\n\
                 cluster: gparml worker --connect LEADER_ADDR (or --listen ADDR),\n\
                          gparml train --connect W1,W2,... (synthetic dataset)\n\
                 math:    --math-mode strict|fast on train/bench/worker (DESIGN.md §8)\n\
                 bench:   gparml bench psi [--config perf] [--points B] [--reps R]\n\
                          [--out BENCH_psi.json],\n\
                          gparml bench check [--baseline F] [--current F] [--max-regress X]"
            );
            bail!("no command given")
        }
    }
}

/// Machine-readable hot-path benchmarks (`gparml bench psi`) and the
/// CI regression gate over their JSON (`gparml bench check`).
fn bench(args: &Args) -> Result<()> {
    match args.positional.get(1).map(|s| s.as_str()) {
        Some("psi") => gparml::runtime::psibench::run(args),
        Some("check") => gparml::runtime::psibench::check(args),
        other => bail!("usage: gparml bench <psi|check> [flags] (got {other:?})"),
    }
}

/// Run this process as a cluster worker node. `--math-mode` pins the
/// node: an `Init` negotiating the other mode is rejected at bring-up.
fn worker(args: &Args) -> Result<()> {
    let artifacts = common::artifacts_dir(args);
    let pinned = common::math_mode_opt(args)?;
    let served = if let Some(addr) = args.get("connect") {
        gparml::cluster::node::run_worker_connect(addr, &artifacts, pinned)?
    } else {
        let addr = args.get_str("listen", "127.0.0.1:0");
        gparml::cluster::node::run_worker_listen(addr, &artifacts, pinned)?
    };
    eprintln!("[gparml-worker] exiting after {served} requests");
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    let man = Manifest::load(&common::artifacts_dir(args))?;
    println!("artifacts in {} (dtype {}):", man.dir.display(), man.dtype);
    for (name, cfg) in &man.configs {
        println!(
            "  {name:>8}: m={:<4} q={:<3} d={:<4} B={:<5} block_n={:<4} entries={}",
            cfg.m,
            cfg.q,
            cfg.d,
            cfg.cap,
            cfg.block_n,
            cfg.entries.len()
        );
    }
    Ok(())
}

/// Worker addresses from `--connect a,b,c` (leader side).
fn connect_addrs(args: &Args) -> Option<Vec<String>> {
    args.get("connect").map(|s| {
        s.split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect()
    })
}

fn train(args: &Args) -> Result<()> {
    let dataset = args.get_str("data", "synthetic");
    let iters = args.get_usize("iters", 30)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let math_mode = common::math_mode(args)?;
    let addrs = connect_addrs(args);
    let workers = match &addrs {
        Some(a) => a.len(),
        None => args.get_usize("workers", 4)?,
    };
    let model = match args.get_str("model", "lvm") {
        "reg" | "regression" => ModelKind::Regression,
        _ => ModelKind::Lvm,
    };
    if let Some(a) = &addrs {
        if a.is_empty() {
            bail!("--connect needs at least one worker address (host:port[,host:port...])");
        }
        if dataset != "synthetic" {
            bail!("--connect currently supports --data synthetic (use the library API for the rest)");
        }
    }

    match dataset {
        "synthetic" => {
            let n = args.get_usize("n", 2000)?;
            let data = synthetic::generate(n, 0.05, seed);
            let (params, shards, cfg) = if model == ModelKind::Lvm {
                let init = common::lvm_init(&data.y, 16, 2, seed);
                let shards = partition(&init.xmu, &init.xvar, &data.y, 1.0, workers);
                let cfg = TrainConfig {
                    artifact: "small".into(),
                    artifacts_dir: common::artifacts_dir(args),
                    workers,
                    model,
                    global_opt: GlobalOpt::Scg,
                    math_mode,
                    seed,
                    ..Default::default()
                };
                (init.params, shards, cfg)
            } else {
                let mut rng = Rng::new(seed);
                let xmu = Matrix::from_fn(n, 2, |i, j| {
                    if j == 0 {
                        data.latent[i]
                    } else {
                        0.1 * rng.normal()
                    }
                });
                let shards = partition(&xmu, &Matrix::zeros(n, 2), &data.y, 0.0, workers);
                let mut prng = Rng::new(seed ^ 1);
                let params = gparml::gp::GlobalParams {
                    z: Matrix::from_fn(16, 2, |_, _| prng.range(-3.0, 3.0)),
                    log_ls: vec![0.0, 0.0],
                    log_sf2: 0.0,
                    log_beta: 1.0,
                };
                let cfg = TrainConfig {
                    artifact: "small".into(),
                    artifacts_dir: common::artifacts_dir(args),
                    workers,
                    model,
                    global_opt: GlobalOpt::Scg,
                    math_mode,
                    seed,
                    ..Default::default()
                };
                (params, shards, cfg)
            };
            match addrs {
                Some(addrs) => {
                    println!("cluster: {} TCP worker processes ({addrs:?})", addrs.len());
                    let mut t = Trainer::connect_tcp(cfg, params, shards, &addrs)?;
                    run_loop(&mut t, iters)?;
                    let (tx, rx) = t.log.total_network_bytes();
                    println!("network: {tx} B to workers, {rx} B back");
                    Ok(())
                }
                None => {
                    let mut t = Trainer::new(cfg, params, shards)?;
                    run_loop(&mut t, iters)
                }
            }
        }
        "oilflow" => {
            let n = args.get_usize("n", 600)?;
            let data = oilflow::generate(n, seed);
            let (mut t, _) = common::lvm_trainer(args, "oil", &data.y, 32, 6, workers, seed)?;
            run_loop(&mut t, iters)
        }
        "digits" => {
            let n = args.get_usize("n", 300)?;
            let data = digits::generate(n, 0.02, seed);
            let (mut t, _) = common::lvm_trainer(args, "digits", &data.y, 48, 8, workers, seed)?;
            run_loop(&mut t, iters)
        }
        other => bail!("unknown dataset {other:?} (synthetic|oilflow|digits)"),
    }
}

fn run_loop<B: Backend>(t: &mut Trainer<B>, iters: usize) -> Result<()> {
    println!("training: {} workers, {} iterations", t.workers(), iters);
    for i in 0..iters {
        let f = t.step()?;
        if i % 5 == 0 || i == iters - 1 {
            let it = t.log.iterations.last().unwrap();
            println!(
                "iter {i:>4}: F = {f:>14.3}  modeled {:.4}s  compute {:.4}s  failed {:?}",
                it.modeled_parallel_secs(),
                it.total_compute_secs(),
                it.failed_workers
            );
        }
    }
    println!(
        "done. startup {:.2}s, mean iteration (modeled parallel) {:.4}s, load gap {:.2}%",
        t.log.startup_secs,
        t.log.mean_iteration_modeled_secs(),
        t.log.mean_load_gap() * 100.0
    );
    Ok(())
}
