//! Global parameter set G = (Z, log lengthscales, log signal variance,
//! log noise precision) with flattening for the optimiser.

use crate::linalg::Matrix;

/// The global parameters the central node optimises (paper §3.2).
#[derive(Debug, Clone)]
pub struct GlobalParams {
    /// Inducing-point locations, m x q.
    pub z: Matrix,
    /// Log ARD lengthscales, length q.
    pub log_ls: Vec<f64>,
    /// Log signal variance log sigma^2.
    pub log_sf2: f64,
    /// Log noise precision log beta.
    pub log_beta: f64,
}

impl GlobalParams {
    pub fn m(&self) -> usize {
        self.z.rows()
    }

    pub fn q(&self) -> usize {
        self.z.cols()
    }

    pub fn beta(&self) -> f64 {
        self.log_beta.exp()
    }

    pub fn sf2(&self) -> f64 {
        self.log_sf2.exp()
    }

    /// ARD lengthscales (not squared).
    pub fn lengthscales(&self) -> Vec<f64> {
        self.log_ls.iter().map(|l| l.exp()).collect()
    }

    /// Number of scalar degrees of freedom.
    pub fn dof(&self) -> usize {
        self.m() * self.q() + self.q() + 2
    }

    /// Flatten to a parameter vector: [Z (row-major), log_ls, log_sf2, log_beta].
    pub fn flatten(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(self.dof());
        v.extend_from_slice(self.z.data());
        v.extend_from_slice(&self.log_ls);
        v.push(self.log_sf2);
        v.push(self.log_beta);
        v
    }

    /// Inverse of [`flatten`]; shape is taken from `self`.
    pub fn unflatten(&self, v: &[f64]) -> GlobalParams {
        assert_eq!(v.len(), self.dof());
        let (m, q) = (self.m(), self.q());
        GlobalParams {
            z: Matrix::from_vec(m, q, v[..m * q].to_vec()),
            log_ls: v[m * q..m * q + q].to_vec(),
            log_sf2: v[m * q + q],
            log_beta: v[m * q + q + 1],
        }
    }
}

/// Gradient w.r.t. the global parameters, same layout as [`GlobalParams`].
#[derive(Debug, Clone)]
pub struct GlobalGrads {
    pub d_z: Matrix,
    pub d_log_ls: Vec<f64>,
    pub d_log_sf2: f64,
    pub d_log_beta: f64,
}

impl GlobalGrads {
    pub fn zeros(m: usize, q: usize) -> GlobalGrads {
        GlobalGrads {
            d_z: Matrix::zeros(m, q),
            d_log_ls: vec![0.0; q],
            d_log_sf2: 0.0,
            d_log_beta: 0.0,
        }
    }

    /// Accumulate another partial gradient (the reduce of map step 2).
    pub fn accumulate(&mut self, other: &GlobalGrads) {
        self.d_z.axpy(1.0, &other.d_z);
        for (a, b) in self.d_log_ls.iter_mut().zip(&other.d_log_ls) {
            *a += b;
        }
        self.d_log_sf2 += other.d_log_sf2;
        self.d_log_beta += other.d_log_beta;
    }

    pub fn flatten(&self) -> Vec<f64> {
        let mut v = Vec::new();
        v.extend_from_slice(self.d_z.data());
        v.extend_from_slice(&self.d_log_ls);
        v.push(self.d_log_sf2);
        v.push(self.d_log_beta);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> GlobalParams {
        GlobalParams {
            z: Matrix::from_fn(3, 2, |i, j| (i * 2 + j) as f64),
            log_ls: vec![0.1, -0.2],
            log_sf2: 0.3,
            log_beta: 1.2,
        }
    }

    #[test]
    fn flatten_roundtrip() {
        let p = sample();
        let v = p.flatten();
        assert_eq!(v.len(), p.dof());
        let p2 = p.unflatten(&v);
        assert_eq!(p2.z.data(), p.z.data());
        assert_eq!(p2.log_ls, p.log_ls);
        assert_eq!(p2.log_sf2, p.log_sf2);
        assert_eq!(p2.log_beta, p.log_beta);
    }

    #[test]
    fn grads_accumulate() {
        let mut g = GlobalGrads::zeros(2, 2);
        let mut h = GlobalGrads::zeros(2, 2);
        h.d_log_sf2 = 1.5;
        h.d_z[(0, 1)] = 2.0;
        g.accumulate(&h);
        g.accumulate(&h);
        assert_eq!(g.d_log_sf2, 3.0);
        assert_eq!(g.d_z[(0, 1)], 4.0);
    }
}
