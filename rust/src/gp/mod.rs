//! Native GP core: the constant-size global step of the paper's
//! algorithm, plus native mirrors of the kernel statistics used by the
//! baselines and tests.
//!
//! The split of labour (DESIGN.md §2): worker nodes execute the AOT
//! Pallas/HLO artifacts for the O(n m^2 q) statistics and chain-rule
//! gradients; this module owns the O(m^3) algebra the central node runs —
//! assembling the collapsed bound (eq. 3.3) from accumulated statistics
//! and producing the adjoints that are broadcast back in map step 2.

pub mod bound;
pub mod exact;
pub mod kernel;
pub mod params;
pub mod stats;

pub use bound::{assemble_bound, Adjoints, BoundValue, PosteriorWeights};
pub use kernel::MathMode;
pub use params::GlobalParams;
pub use stats::Stats;
