//! Native SE-ARD kernel and psi-statistics — the Rust mirror of
//! `python/compile/kernels/ref.py`.
//!
//! Used by the native baselines (sequential / SVI / exact GP), the Fig-8
//! experiment, and as a cross-check against the HLO artifact path in the
//! integration tests. The distributed hot path does NOT go through this
//! code — workers run the AOT Pallas kernel.

use crate::linalg::Matrix;

use super::params::GlobalParams;
use super::stats::Stats;

/// k(X1, X2) for the SE-ARD kernel, [n1 x n2].
pub fn seard(x1: &Matrix, x2: &Matrix, p: &GlobalParams) -> Matrix {
    let q = p.q();
    assert_eq!(x1.cols(), q);
    assert_eq!(x2.cols(), q);
    let ls2: Vec<f64> = p.log_ls.iter().map(|l| (2.0 * l).exp()).collect();
    let sf2 = p.sf2();
    Matrix::from_fn(x1.rows(), x2.rows(), |i, j| {
        let mut s = 0.0;
        for (k, &l2) in ls2.iter().enumerate() {
            let d = x1[(i, k)] - x2[(j, k)];
            s += d * d / l2;
        }
        sf2 * (-0.5 * s).exp()
    })
}

/// Kmm = k(Z, Z) + jitter I.
pub fn kmm(p: &GlobalParams, jitter: f64) -> Matrix {
    seard(&p.z, &p.z, p).add_diag(jitter)
}

/// Psi1[i, j] = <k(x_i, z_j)>_{N(mu_i, diag(s_i))}, [B x m].
pub fn psi1(p: &GlobalParams, xmu: &Matrix, xvar: &Matrix) -> Matrix {
    let (bq, q) = (xmu.rows(), p.q());
    let m = p.m();
    let ls2: Vec<f64> = p.log_ls.iter().map(|l| (2.0 * l).exp()).collect();
    let sf2 = p.sf2();
    let mut out = Matrix::zeros(bq, m);
    for i in 0..bq {
        let mut log_scale = 0.0;
        for k in 0..q {
            log_scale -= 0.5 * (xvar[(i, k)] / ls2[k]).ln_1p();
        }
        for j in 0..m {
            let mut quad = 0.0;
            for k in 0..q {
                let d = xmu[(i, k)] - p.z[(j, k)];
                quad += d * d / (ls2[k] + xvar[(i, k)]);
            }
            out[(i, j)] = sf2 * (log_scale - 0.5 * quad).exp();
        }
    }
    out
}

/// Psi2_i[j, l] for a single point i, [m x m].
pub fn psi2_point(p: &GlobalParams, xmu_i: &[f64], xvar_i: &[f64]) -> Matrix {
    let (m, q) = (p.m(), p.q());
    let ls2: Vec<f64> = p.log_ls.iter().map(|l| (2.0 * l).exp()).collect();
    let sf2 = p.sf2();
    let mut log_scale = 0.0;
    for k in 0..q {
        log_scale -= 0.5 * (2.0 * xvar_i[k] / ls2[k]).ln_1p();
    }
    Matrix::from_fn(m, m, |j, l| {
        let mut e = log_scale;
        for k in 0..q {
            let dz = p.z[(j, k)] - p.z[(l, k)];
            let zbar = 0.5 * (p.z[(j, k)] + p.z[(l, k)]);
            let dm = xmu_i[k] - zbar;
            e -= dz * dz / (4.0 * ls2[k]) + dm * dm / (ls2[k] + 2.0 * xvar_i[k]);
        }
        sf2 * sf2 * e.exp()
    })
}

/// Full shard statistics (native path). `kl_weight` = 0 selects the
/// regression model, 1 the LVM; matches `ref.shard_stats_ref`.
pub fn shard_stats(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    y: &Matrix,
    mask: &[f64],
    kl_weight: f64,
) -> Stats {
    let b = xmu.rows();
    assert_eq!(mask.len(), b);
    let m = p.m();
    let mut st = Stats::zeros(m, y.cols());
    let p1 = psi1(p, xmu, xvar);
    for i in 0..b {
        let w = mask[i];
        if w == 0.0 {
            continue;
        }
        st.n += w;
        let yi = y.row(i);
        st.a += w * yi.iter().map(|v| v * v).sum::<f64>();
        // C += w * psi1_i^T y_i
        for j in 0..m {
            let pj = w * p1[(i, j)];
            for (cjd, &yv) in st.c.row_mut(j).iter_mut().zip(yi) {
                *cjd += pj * yv;
            }
        }
        st.d.axpy(w, &psi2_point(p, xmu.row(i), xvar.row(i)));
        if kl_weight > 0.0 {
            let mut kli = 0.0;
            for k in 0..p.q() {
                let (mu, s) = (xmu[(i, k)], xvar[(i, k)]);
                let log_s = if s > 0.0 { s.ln() } else { 0.0 };
                kli += mu * mu + s - log_s - 1.0;
            }
            st.kl += kl_weight * w * 0.5 * kli;
        }
    }
    st.psi0 = p.sf2() * st.n;
    st
}

/// Pullback of an adjoint A = dF/dKmm onto the kernel parameters
/// (the central node's direct term, paper §3.2 step 3) — the native
/// mirror of the `kmm_grads` artifact:
///
/// ```text
/// dF/dZ[j,q]    = sum_l (A[j,l] + A[l,j]) K[j,l] (z_lq - z_jq)/ls_q^2
/// dF/dlog_ls_q  = sum_{j,l} A[j,l] K[j,l] (z_jq - z_lq)^2 / ls_q^2
/// dF/dlog_sf2   = <A, K>
/// ```
pub fn kmm_vjp(p: &GlobalParams, adj: &Matrix) -> super::params::GlobalGrads {
    let (m, q) = (p.m(), p.q());
    let ls2: Vec<f64> = p.log_ls.iter().map(|l| (2.0 * l).exp()).collect();
    let k = seard(&p.z, &p.z, p);
    let mut g = super::params::GlobalGrads::zeros(m, q);
    for j in 0..m {
        for l in 0..m {
            let ak = adj[(j, l)] * k[(j, l)];
            g.d_log_sf2 += ak;
            for t in 0..q {
                let dz = p.z[(j, t)] - p.z[(l, t)];
                g.d_log_ls[t] += ak * dz * dz / ls2[t];
                // d/dZ[j,t] picks up both A[j,l] and A[l,j] terms; do the
                // A[j,l] half here, the transpose half lands when the loop
                // visits (l, j).
                g.d_z[(j, t)] += ak * (-dz / ls2[t]);
                g.d_z[(l, t)] += ak * (dz / ls2[t]);
            }
        }
    }
    g
}

/// Pullback of the map-step-2 adjoints through the psi statistics — the
/// native mirror of the `shard_grads` artifact. Given the central
/// node's adjoint message (dF/dpsi0, dF/dC, dF/dD, dF/dKL), chain-rules
/// through `C = sum_i Psi1_i^T Y_i`, `D = sum_i Psi2_i`,
/// `psi0 = sf2 * n` and the per-point KL onto the global parameters
/// (Z, log lengthscales, log sf2) and this shard's local parameters
/// (Xmu, Xvar in raw variance space).
///
/// Returns `(global grads, dF/dXmu [b x q], dF/dXvar [b x q])`;
/// `d_log_beta` is left 0 (it is central, paper §3.2 step 3).
/// Derivatives are w.r.t. the same explicit formulas as [`psi1`] /
/// [`psi2_point`]; validated against finite differences of the
/// assembled bound in the tests below.
pub fn shard_grads_vjp(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    y: &Matrix,
    kl_weight: f64,
    adj: &super::bound::Adjoints,
) -> (super::params::GlobalGrads, Matrix, Matrix) {
    let (b, q, m) = (xmu.rows(), p.q(), p.m());
    let dout = y.cols();
    let ls2: Vec<f64> = p.log_ls.iter().map(|l| (2.0 * l).exp()).collect();
    let sf2 = p.sf2();
    let mut g = super::params::GlobalGrads::zeros(m, q);
    let mut d_xmu = Matrix::zeros(b, q);
    let mut d_xvar = Matrix::zeros(b, q);

    // ---- Psi1 path: dF/dPsi1[i,j] = sum_d dF/dC[j,d] * Y[i,d] --------------
    let p1 = psi1(p, xmu, xvar);
    for i in 0..b {
        let yi = y.row(i);
        for j in 0..m {
            let mut a1 = 0.0;
            for dd in 0..dout {
                a1 += adj.d_c[(j, dd)] * yi[dd];
            }
            let w = a1 * p1[(i, j)];
            if w == 0.0 {
                continue;
            }
            g.d_log_sf2 += w;
            for k in 0..q {
                let dn = ls2[k] + xvar[(i, k)];
                let diff = xmu[(i, k)] - p.z[(j, k)];
                g.d_z[(j, k)] += w * diff / dn;
                d_xmu[(i, k)] -= w * diff / dn;
                d_xvar[(i, k)] += w * 0.5 * (diff * diff / (dn * dn) - 1.0 / dn);
                g.d_log_ls[k] += w * (xvar[(i, k)] / dn + ls2[k] * diff * diff / (dn * dn));
            }
        }
    }

    // ---- Psi2 path: dF/dPsi2_i[j,l] = dF/dD[j,l] --------------------------
    for i in 0..b {
        let p2 = psi2_point(p, xmu.row(i), xvar.row(i));
        for j in 0..m {
            for l in 0..m {
                let w = adj.d_d[(j, l)] * p2[(j, l)];
                if w == 0.0 {
                    continue;
                }
                g.d_log_sf2 += 2.0 * w;
                for k in 0..q {
                    let dn2 = ls2[k] + 2.0 * xvar[(i, k)];
                    let dz = p.z[(j, k)] - p.z[(l, k)];
                    let dm = xmu[(i, k)] - 0.5 * (p.z[(j, k)] + p.z[(l, k)]);
                    g.d_z[(j, k)] += w * (-dz / (2.0 * ls2[k]) + dm / dn2);
                    g.d_z[(l, k)] += w * (dz / (2.0 * ls2[k]) + dm / dn2);
                    d_xmu[(i, k)] -= w * 2.0 * dm / dn2;
                    d_xvar[(i, k)] += w * (2.0 * dm * dm / (dn2 * dn2) - 1.0 / dn2);
                    g.d_log_ls[k] += w
                        * (2.0 * xvar[(i, k)] / dn2
                            + dz * dz / (2.0 * ls2[k])
                            + 2.0 * ls2[k] * dm * dm / (dn2 * dn2));
                }
            }
        }
    }

    // ---- psi0 = sf2 * n: only log sf2 sees it ----------------------------
    g.d_log_sf2 += adj.d_psi0 * sf2 * b as f64;

    // ---- KL path: kl = klw * 0.5 sum_{i,k} (mu^2 + s - ln s - 1) ---------
    if kl_weight > 0.0 {
        for i in 0..b {
            for k in 0..q {
                let s = xvar[(i, k)];
                d_xmu[(i, k)] += adj.d_kl * kl_weight * xmu[(i, k)];
                let ds = if s > 0.0 { 0.5 * (1.0 - 1.0 / s) } else { 0.5 };
                d_xvar[(i, k)] += adj.d_kl * kl_weight * ds;
            }
        }
    }

    (g, d_xmu, d_xvar)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn params(m: usize, q: usize, seed: u64) -> GlobalParams {
        let mut rng = Rng::new(seed);
        GlobalParams {
            z: Matrix::from_fn(m, q, |_, _| rng.normal()),
            log_ls: (0..q).map(|_| 0.3 * rng.normal()).collect(),
            log_sf2: 0.2,
            log_beta: 1.0,
        }
    }

    #[test]
    fn seard_diag_is_sf2() {
        let p = params(4, 2, 0);
        let k = seard(&p.z, &p.z, &p);
        for i in 0..4 {
            assert!((k[(i, i)] - p.sf2()).abs() < 1e-14);
        }
    }

    #[test]
    fn seard_symmetric_and_bounded() {
        let p = params(5, 3, 1);
        let k = seard(&p.z, &p.z, &p);
        assert!(k.max_abs_diff(&k.transpose()) < 1e-15);
        for v in k.data() {
            assert!(*v > 0.0 && *v <= p.sf2() + 1e-14);
        }
    }

    #[test]
    fn psi1_reduces_to_kernel_at_zero_variance() {
        let p = params(4, 2, 2);
        let mut rng = Rng::new(3);
        let xmu = Matrix::from_fn(6, 2, |_, _| rng.normal());
        let xvar = Matrix::zeros(6, 2);
        let p1 = psi1(&p, &xmu, &xvar);
        let knm = seard(&xmu, &p.z, &p);
        assert!(p1.max_abs_diff(&knm) < 1e-13);
    }

    #[test]
    fn psi2_reduces_to_outer_product_at_zero_variance() {
        let p = params(3, 2, 4);
        let mut rng = Rng::new(5);
        let x: Vec<f64> = vec![rng.normal(), rng.normal()];
        let xm = Matrix::from_vec(1, 2, x.clone());
        let k = seard(&xm, &p.z, &p); // [1, m]
        let p2 = psi2_point(&p, &x, &[0.0, 0.0]);
        for j in 0..3 {
            for l in 0..3 {
                assert!((p2[(j, l)] - k[(0, j)] * k[(0, l)]).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn kmm_vjp_matches_finite_difference() {
        let p = params(4, 3, 10);
        let mut rng = Rng::new(11);
        let adj = Matrix::from_fn(4, 4, |_, _| rng.normal());
        let g = kmm_vjp(&p, &adj);
        let f_of = |p: &GlobalParams| adj.dot(&seard(&p.z, &p.z, p));
        let eps = 1e-6;
        // Z entries
        for &(j, t) in &[(0, 0), (2, 1), (3, 2)] {
            let mut pp = p.clone();
            pp.z[(j, t)] += eps;
            let mut pm = p.clone();
            pm.z[(j, t)] -= eps;
            let fd = (f_of(&pp) - f_of(&pm)) / (2.0 * eps);
            assert!(
                (g.d_z[(j, t)] - fd).abs() < 1e-6 * (1.0 + fd.abs()),
                "dZ[{j},{t}] {} vs {}",
                g.d_z[(j, t)],
                fd
            );
        }
        // log lengthscales
        for t in 0..3 {
            let mut pp = p.clone();
            pp.log_ls[t] += eps;
            let mut pm = p.clone();
            pm.log_ls[t] -= eps;
            let fd = (f_of(&pp) - f_of(&pm)) / (2.0 * eps);
            assert!((g.d_log_ls[t] - fd).abs() < 1e-6 * (1.0 + fd.abs()));
        }
        // log sf2
        let mut pp = p.clone();
        pp.log_sf2 += eps;
        let mut pm = p.clone();
        pm.log_sf2 -= eps;
        let fd = (f_of(&pp) - f_of(&pm)) / (2.0 * eps);
        assert!((g.d_log_sf2 - fd).abs() < 1e-6 * (1.0 + fd.abs()));
    }

    /// The full native gradient (shard VJP + central Kmm pullback) must
    /// match finite differences of the assembled bound — the same
    /// composition the distributed trainer runs every iteration, so this
    /// pins the whole native fallback path end to end.
    #[test]
    fn shard_grads_vjp_matches_finite_difference_of_bound() {
        let (m, q, dout, b) = (4, 2, 2, 6);
        let jitter = 1e-6;
        let klw = 1.0;
        let mut rng = Rng::new(77);
        let p0 = params(m, q, 20);
        let xmu0 = Matrix::from_fn(b, q, |_, _| rng.normal());
        let xvar0 = Matrix::from_fn(b, q, |_, _| 0.2 + 0.5 * rng.uniform());
        let y = Matrix::from_fn(b, dout, |_, _| rng.normal());

        let f_of = |p: &GlobalParams, xmu: &Matrix, xvar: &Matrix| -> f64 {
            let st = shard_stats(p, xmu, xvar, &y, &vec![1.0; b], klw);
            let kmm = kmm(p, jitter);
            let (bv, _) = crate::gp::assemble_bound(&st, &kmm, p.log_beta, dout).unwrap();
            bv.f
        };

        // analytic gradient: shard VJP + central Kmm pullback
        let st = shard_stats(&p0, &xmu0, &xvar0, &y, &vec![1.0; b], klw);
        let kmm0 = kmm(&p0, jitter);
        let (_, adj) = crate::gp::assemble_bound(&st, &kmm0, p0.log_beta, dout).unwrap();
        let (mut g, d_xmu, d_xvar) = shard_grads_vjp(&p0, &xmu0, &xvar0, &y, klw, &adj);
        g.accumulate(&kmm_vjp(&p0, &adj.d_kmm));

        let eps = 1e-6;
        let check = |analytic: f64, fd: f64, what: &str| {
            assert!(
                (analytic - fd).abs() < 2e-5 * (1.0 + fd.abs()),
                "{what}: analytic {analytic} vs fd {fd}"
            );
        };
        for &(j, k) in &[(0, 0), (1, 1), (3, 0)] {
            let mut pp = p0.clone();
            pp.z[(j, k)] += eps;
            let mut pm = p0.clone();
            pm.z[(j, k)] -= eps;
            let fd = (f_of(&pp, &xmu0, &xvar0) - f_of(&pm, &xmu0, &xvar0)) / (2.0 * eps);
            check(g.d_z[(j, k)], fd, &format!("dZ[{j},{k}]"));
        }
        for k in 0..q {
            let mut pp = p0.clone();
            pp.log_ls[k] += eps;
            let mut pm = p0.clone();
            pm.log_ls[k] -= eps;
            let fd = (f_of(&pp, &xmu0, &xvar0) - f_of(&pm, &xmu0, &xvar0)) / (2.0 * eps);
            check(g.d_log_ls[k], fd, &format!("dlog_ls[{k}]"));
        }
        {
            let mut pp = p0.clone();
            pp.log_sf2 += eps;
            let mut pm = p0.clone();
            pm.log_sf2 -= eps;
            let fd = (f_of(&pp, &xmu0, &xvar0) - f_of(&pm, &xmu0, &xvar0)) / (2.0 * eps);
            check(g.d_log_sf2, fd, "dlog_sf2");
        }
        for &(i, k) in &[(0, 0), (2, 1), (5, 0)] {
            let mut xp = xmu0.clone();
            xp[(i, k)] += eps;
            let mut xm = xmu0.clone();
            xm[(i, k)] -= eps;
            let fd = (f_of(&p0, &xp, &xvar0) - f_of(&p0, &xm, &xvar0)) / (2.0 * eps);
            check(d_xmu[(i, k)], fd, &format!("dXmu[{i},{k}]"));

            let mut vp = xvar0.clone();
            vp[(i, k)] += eps;
            let mut vm = xvar0.clone();
            vm[(i, k)] -= eps;
            let fd = (f_of(&p0, &xmu0, &vp) - f_of(&p0, &xmu0, &vm)) / (2.0 * eps);
            check(d_xvar[(i, k)], fd, &format!("dXvar[{i},{k}]"));
        }
    }

    #[test]
    fn stats_additive_over_split() {
        let p = params(4, 2, 6);
        let mut rng = Rng::new(7);
        let b = 10;
        let xmu = Matrix::from_fn(b, 2, |_, _| rng.normal());
        let xvar = Matrix::from_fn(b, 2, |_, _| rng.uniform() + 0.05);
        let y = Matrix::from_fn(b, 3, |_, _| rng.normal());
        let mask = vec![1.0; b];
        let whole = shard_stats(&p, &xmu, &xvar, &y, &mask, 1.0);
        let take = |r0: usize, r1: usize| {
            let rows = r1 - r0;
            (
                Matrix::from_fn(rows, 2, |i, j| xmu[(r0 + i, j)]),
                Matrix::from_fn(rows, 2, |i, j| xvar[(r0 + i, j)]),
                Matrix::from_fn(rows, 3, |i, j| y[(r0 + i, j)]),
            )
        };
        let (x1, v1, y1) = take(0, 4);
        let (x2, v2, y2) = take(4, 10);
        let mut acc = shard_stats(&p, &x1, &v1, &y1, &vec![1.0; 4], 1.0);
        acc.accumulate(&shard_stats(&p, &x2, &v2, &y2, &vec![1.0; 6], 1.0));
        assert!((acc.a - whole.a).abs() < 1e-12);
        assert!((acc.psi0 - whole.psi0).abs() < 1e-12);
        assert!((acc.kl - whole.kl).abs() < 1e-12);
        assert!(acc.c.max_abs_diff(&whole.c) < 1e-12);
        assert!(acc.d.max_abs_diff(&whole.d) < 1e-12);
    }
}
