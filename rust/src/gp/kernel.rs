//! Native SE-ARD kernel and psi-statistics — the Rust mirror of
//! `python/compile/kernels/ref.py` and, since the native executor
//! became the default, **the distributed hot path itself**: cluster
//! workers run these loops on every map round (the AOT Pallas/HLO
//! artifacts are only used under `--features pjrt`).
//!
//! The hot path is organised around [`ShardScratch`], a reusable
//! per-shard workspace: the statistics round ([`shard_stats_into`])
//! computes Psi1, the per-point Psi2 blocks and their exponent
//! components **once**, into caller-owned buffers, and the gradient
//! round ([`shard_grads_vjp_cached`]) consumes them instead of
//! recomputing — one psi pass per evaluation instead of two, with no
//! per-point allocation anywhere. The scratch also precomputes the
//! point-independent (j,l,k) exponent/chain tables, so the inner loops
//! only touch per-point terms. Every transformation is a bit-identical
//! re-grouping of the original expressions (same operations, same
//! order — property-tested in `tests/properties.rs`).
//!
//! The scratch-free [`shard_stats`] / [`shard_grads_vjp`] keep the
//! pre-refactor loop shapes **verbatim**: they are the forced-fresh
//! reference mode (`TrainConfig::psi_cache = false`), the "before"
//! series in `gparml bench psi`, and the entry the native baselines
//! (sequential / SVI / exact GP) and the Fig-8 experiment use.
//!
//! Every entry point above implements the **Strict** half of the
//! [`MathMode`] execution policy. The **Fast** half
//! ([`shard_stats_into_fast`] / [`shard_grads_vjp_cached_fast`]) is
//! exempt from the bit-for-bit contract: it hoists the per-point
//! denominators into precomputed reciprocals (multiply instead of
//! divide in the O(b m^2 q) loops), batches the per-(j,l,k) exponents
//! row-wise and runs one `linalg::fastmath` exp pass per block. Fast
//! results stay within 1e-9 relative of Strict on the bound and every
//! gradient (property- and finite-difference-tested; contract in
//! DESIGN.md §8). Shards whose Psi2 slab exceeds the
//! [`DEFAULT_SLAB_LIMIT`] gate are **streamed in tiles** in both modes:
//! round 2 refills the slab block-by-block instead of point-by-point.
//!
//! Since the fused-pass PR the fills are additionally **SIMD-blocked
//! and (optionally) multi-threaded** (DESIGN.md §11): the exponent
//! accumulations process `LANES` independent output elements per
//! step — each lane keeps its own sequential k-accumulator, so the
//! blocked loops are bit-identical to the scalar ones while the
//! compiler autovectorises across lanes — and every fill splits its
//! rows into [`fill_ranges`] disjoint windows run on
//! `ShardScratch::fill_threads` scoped threads. Only disjoint *writes*
//! are parallel; all floating-point *accumulations* (statistics,
//! gradients) stay sequential in historical order, which is what keeps
//! strict mode bit-for-bit for any thread count.

use crate::linalg::{fastmath, Matrix};

use super::params::GlobalParams;
use super::stats::Stats;

/// Numerical execution policy for the psi hot path, threaded from the
/// CLI through `TrainConfig`, the wire `Init` frame (v3) and the
/// executors down to the kernel loops.
///
/// * `Strict` (default): bit-for-bit reproducible against the seed
///   trace — every optimisation keeps the historical operation order
///   and rounding. The cluster trace-equality tests pin this mode.
/// * `Fast`: licensed to re-associate — reciprocal multiplies, batched
///   exponent blocks, `fastmath::exp`. Bound and gradients stay within
///   1e-9 relative of Strict (tested); traces are deterministic but
///   not bit-comparable across modes. Requires the psi cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MathMode {
    #[default]
    Strict,
    Fast,
}

impl MathMode {
    pub fn as_str(self) -> &'static str {
        match self {
            MathMode::Strict => "strict",
            MathMode::Fast => "fast",
        }
    }

    /// Parse a CLI spelling (`strict` / `fast`).
    pub fn parse(s: &str) -> Option<MathMode> {
        match s {
            "strict" => Some(MathMode::Strict),
            "fast" => Some(MathMode::Fast),
            _ => None,
        }
    }

    /// Wire encoding (`Init.math_mode`, protocol v3).
    pub fn code(self) -> u8 {
        match self {
            MathMode::Strict => 0,
            MathMode::Fast => 1,
        }
    }

    /// Decode the wire byte; unknown codes are a protocol error.
    pub fn from_code(c: u8) -> Option<MathMode> {
        match c {
            0 => Some(MathMode::Strict),
            1 => Some(MathMode::Fast),
            _ => None,
        }
    }
}

impl std::fmt::Display for MathMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// k(X1, X2) for the SE-ARD kernel, [n1 x n2].
pub fn seard(x1: &Matrix, x2: &Matrix, p: &GlobalParams) -> Matrix {
    let q = p.q();
    assert_eq!(x1.cols(), q);
    assert_eq!(x2.cols(), q);
    let ls2: Vec<f64> = p.log_ls.iter().map(|l| (2.0 * l).exp()).collect();
    let sf2 = p.sf2();
    Matrix::from_fn(x1.rows(), x2.rows(), |i, j| {
        let mut s = 0.0;
        for (k, &l2) in ls2.iter().enumerate() {
            let d = x1[(i, k)] - x2[(j, k)];
            s += d * d / l2;
        }
        sf2 * (-0.5 * s).exp()
    })
}

/// Kmm = k(Z, Z) + jitter I.
pub fn kmm(p: &GlobalParams, jitter: f64) -> Matrix {
    seard(&p.z, &p.z, p).add_diag(jitter)
}

/// Fixed SIMD lane width for the psi exponent accumulations: the hot
/// loops process `LANES` independent output elements per step, each
/// lane keeping its **own** sequential k-accumulator. The per-element
/// operation sequence is exactly the scalar loop's, so the blocked
/// form is bit-identical to it — the blocking only exposes `LANES`
/// independent dependency chains for the compiler to autovectorise
/// (f64x4 on AVX2, 2x f64x2 on NEON/SSE2).
const LANES: usize = 4;

/// One point's strict Psi1 row, lane-blocked over the inducing index j.
fn psi1_row_fill(
    z: &Matrix,
    q: usize,
    sf2: f64,
    xmu_i: &[f64],
    log_scale: f64,
    dn: &[f64],
    out: &mut [f64],
) {
    let mut chunks = out.chunks_exact_mut(LANES);
    let mut j0 = 0;
    for chunk in &mut chunks {
        let mut quad = [0.0f64; LANES];
        for k in 0..q {
            let mu = xmu_i[k];
            let den = dn[k];
            for (lane, acc) in quad.iter_mut().enumerate() {
                let d = mu - z[(j0 + lane, k)];
                *acc += d * d / den;
            }
        }
        for (o, &qd) in chunk.iter_mut().zip(quad.iter()) {
            *o = sf2 * (log_scale - 0.5 * qd).exp();
        }
        j0 += LANES;
    }
    for (r, o) in chunks.into_remainder().iter_mut().enumerate() {
        let j = j0 + r;
        let mut quad = 0.0;
        for k in 0..q {
            let d = xmu_i[k] - z[(j, k)];
            quad += d * d / dn[k];
        }
        *o = sf2 * (log_scale - 0.5 * quad).exp();
    }
}

/// Fill `rows` (rows `lo..hi`, stored from `rows[0]`) with strict Psi1.
/// `dn` is a length-q workspace for the per-point denominators
/// `ls2_k + s_ik` (hoisted out of the inducing loop; same expression as
/// the historical per-(j,k) computation, so the values are
/// bit-identical).
fn psi1_rows_fill(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    ls2: &[f64],
    sf2: f64,
    lo: usize,
    hi: usize,
    dn: &mut [f64],
    rows: &mut [f64],
) {
    let (q, m) = (p.q(), p.m());
    for i in lo..hi {
        let mut log_scale = 0.0;
        for k in 0..q {
            log_scale -= 0.5 * (xvar[(i, k)] / ls2[k]).ln_1p();
            dn[k] = ls2[k] + xvar[(i, k)];
        }
        let row = &mut rows[(i - lo) * m..(i - lo + 1) * m];
        psi1_row_fill(&p.z, q, sf2, xmu.row(i), log_scale, dn, row);
    }
}

/// Fill `out` with Psi1 [b x m] (strict, single pass over all rows).
fn psi1_fill(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    ls2: &[f64],
    sf2: f64,
    dn: &mut [f64],
    out: &mut Matrix,
) {
    let b = xmu.rows();
    out.reset(b, p.m(), 0.0);
    psi1_rows_fill(p, xmu, xvar, ls2, sf2, 0, b, dn, out.data_mut());
}

/// Psi1[i, j] = <k(x_i, z_j)>_{N(mu_i, diag(s_i))}, [B x m].
pub fn psi1(p: &GlobalParams, xmu: &Matrix, xvar: &Matrix) -> Matrix {
    let q = p.q();
    let ls2: Vec<f64> = p.log_ls.iter().map(|l| (2.0 * l).exp()).collect();
    let mut dn = vec![0.0; q];
    let mut out = Matrix::zeros(xmu.rows(), p.m());
    psi1_fill(p, xmu, xvar, &ls2, p.sf2(), &mut dn, &mut out);
    out
}

/// Per-point Psi2 log-scale: -(1/2) sum_k ln(1 + 2 s_ik / ls2_k).
fn psi2_point_log_scale(ls2: &[f64], xvar_i: &[f64]) -> f64 {
    let mut log_scale = 0.0;
    for (k, &l2) in ls2.iter().enumerate() {
        log_scale -= 0.5 * (2.0 * xvar_i[k] / l2).ln_1p();
    }
    log_scale
}

/// Fill `out` (length m*m, row-major) with one point's Psi2 block,
/// given the point's precomputed log-scale and denominators
/// `dn2[k] = ls2_k + 2 s_ik`. Expression order matches the historical
/// single-shot `psi2_point` exactly — bit-identical values.
fn psi2_point_fill(
    z: &Matrix,
    ls2: &[f64],
    sf2: f64,
    xmu_i: &[f64],
    log_scale: f64,
    dn2: &[f64],
    out: &mut [f64],
) {
    let (m, q) = (z.rows(), z.cols());
    debug_assert_eq!(out.len(), m * m);
    let mut idx = 0;
    for j in 0..m {
        for l in 0..m {
            let mut e = log_scale;
            for k in 0..q {
                let dz = z[(j, k)] - z[(l, k)];
                let zbar = 0.5 * (z[(j, k)] + z[(l, k)]);
                let dm = xmu_i[k] - zbar;
                e -= dz * dz / (4.0 * ls2[k]) + dm * dm / dn2[k];
            }
            out[idx] = sf2 * sf2 * e.exp();
            idx += 1;
        }
    }
}

/// Psi2_i[j, l] for a single point i, [m x m].
pub fn psi2_point(p: &GlobalParams, xmu_i: &[f64], xvar_i: &[f64]) -> Matrix {
    let (m, q) = (p.m(), p.q());
    let ls2: Vec<f64> = p.log_ls.iter().map(|l| (2.0 * l).exp()).collect();
    let log_scale = psi2_point_log_scale(&ls2, xvar_i);
    let dn2: Vec<f64> = (0..q).map(|k| ls2[k] + 2.0 * xvar_i[k]).collect();
    let mut out = Matrix::zeros(m, m);
    psi2_point_fill(&p.z, &ls2, p.sf2(), xmu_i, log_scale, &dn2, out.data_mut());
    out
}

/// Fill `out` with Psi1 [b x m] into caller-owned workspaces — the
/// allocation-free entry the standalone `model::Predictor` serves
/// batches through. `ls2` must be the squared lengthscales
/// `exp(2 log_ls)` and `sf2` the signal variance `exp(log_sf2)`; `dn`
/// is a length-q denominator workspace. Runs the exact strict fill of
/// [`psi1`], so the values are **bit-identical** to it (tested).
pub fn psi1_into(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    ls2: &[f64],
    sf2: f64,
    dn: &mut [f64],
    out: &mut Matrix,
) {
    psi1_fill(p, xmu, xvar, ls2, sf2, dn, out);
}

/// [`psi1_into`] with intra-call parallelism: the batch rows are split
/// into [`fill_ranges`]`(b, threads)` disjoint windows, one scoped
/// thread per window. Every row is filled by the exact strict per-row
/// kernel, so the output is **bit-identical** to [`psi1_into`] for any
/// `threads` (tested); `threads <= 1` takes the sequential path with no
/// spawn at all.
pub fn psi1_into_threaded(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    ls2: &[f64],
    sf2: f64,
    threads: usize,
    dn: &mut [f64],
    out: &mut Matrix,
) {
    let (b, m, q) = (xmu.rows(), p.m(), p.q());
    let ranges = fill_ranges(b, threads);
    if ranges.len() == 1 {
        psi1_fill(p, xmu, xvar, ls2, sf2, dn, out);
        return;
    }
    out.reset(b, m, 0.0);
    let mut rest: &mut [f64] = out.data_mut();
    std::thread::scope(|s| {
        for &(lo, hi) in &ranges {
            let (rows, r) = std::mem::take(&mut rest).split_at_mut((hi - lo) * m);
            rest = r;
            s.spawn(move || {
                let mut span = crate::obs::trace::span("psi_fill", crate::obs::trace::current());
                span.set_count((hi - lo) as u64);
                let mut dn = vec![0.0; q];
                psi1_rows_fill(p, xmu, xvar, ls2, sf2, lo, hi, &mut dn, rows);
            });
        }
    });
}

/// Fill `out` (length m*m, row-major) with one point's Psi2 block into
/// caller-owned workspaces — the allocation-free sibling of
/// [`psi2_point`], bit-identical to it (tested). `dn2` is a length-q
/// denominator workspace; `ls2`/`sf2` as in [`psi1_into`].
pub fn psi2_point_into(
    z: &Matrix,
    ls2: &[f64],
    sf2: f64,
    xmu_i: &[f64],
    xvar_i: &[f64],
    dn2: &mut [f64],
    out: &mut [f64],
) {
    let log_scale = psi2_point_log_scale(ls2, xvar_i);
    for (k, d) in dn2.iter_mut().enumerate() {
        *d = ls2[k] + 2.0 * xvar_i[k];
    }
    psi2_point_fill(z, ls2, sf2, xmu_i, log_scale, dn2, out);
}

/// Fill `out` with one point's Psi2 block from the scratch's
/// precomputed point-independent tables (`zq[(j,l,k)] = dz^2/(4 ls2)`,
/// `zbar[(j,l,k)] = (z_j + z_l)/2`). Each table entry is computed by
/// the exact expression [`psi2_point`] evaluates inline, so the block
/// is bit-identical to the untabled fill.
fn psi2_row_fill_tabled(
    m: usize,
    q: usize,
    zq: &[f64],
    zbar: &[f64],
    sf2: f64,
    xmu_i: &[f64],
    log_scale: f64,
    dn2: &[f64],
    out: &mut [f64],
) {
    debug_assert_eq!(out.len(), m * m);
    // lane-blocked over the flat (j,l) index: LANES independent
    // exponent accumulators share the k loop; each lane's operation
    // sequence matches the scalar element loop exactly (bit-identical)
    let mut t = 0;
    let mut chunks = out.chunks_exact_mut(LANES);
    for chunk in &mut chunks {
        let mut e = [log_scale; LANES];
        for k in 0..q {
            let mu = xmu_i[k];
            let den = dn2[k];
            for (lane, acc) in e.iter_mut().enumerate() {
                let o = t + lane * q + k;
                let dm = mu - zbar[o];
                *acc -= zq[o] + dm * dm / den;
            }
        }
        for (o, &ex) in chunk.iter_mut().zip(e.iter()) {
            *o = sf2 * sf2 * ex.exp();
        }
        t += LANES * q;
    }
    for o in chunks.into_remainder().iter_mut() {
        let mut e = log_scale;
        for k in 0..q {
            let dm = xmu_i[k] - zbar[t + k];
            e -= zq[t + k] + dm * dm / dn2[k];
        }
        *o = sf2 * sf2 * e.exp();
        t += q;
    }
}

/// One point's fast Psi1 row: lane-blocked exponents (reciprocal
/// multiplies), finished by one batched [`fastmath`] exp pass.
/// `MathMode::Fast` only — rounding differs from the strict fill.
fn psi1_row_fill_fast(
    z: &Matrix,
    q: usize,
    sf2: f64,
    xmu_i: &[f64],
    log_scale: f64,
    inv_dn: &[f64],
    out: &mut [f64],
) {
    let mut chunks = out.chunks_exact_mut(LANES);
    let mut j0 = 0;
    for chunk in &mut chunks {
        let mut quad = [0.0f64; LANES];
        for k in 0..q {
            let mu = xmu_i[k];
            let inv = inv_dn[k];
            for (lane, acc) in quad.iter_mut().enumerate() {
                let d = mu - z[(j0 + lane, k)];
                *acc += d * d * inv;
            }
        }
        for (o, &qd) in chunk.iter_mut().zip(quad.iter()) {
            *o = log_scale - 0.5 * qd;
        }
        j0 += LANES;
    }
    for (r, o) in chunks.into_remainder().iter_mut().enumerate() {
        let j = j0 + r;
        let mut quad = 0.0;
        for k in 0..q {
            let d = xmu_i[k] - z[(j, k)];
            quad += d * d * inv_dn[k];
        }
        *o = log_scale - 0.5 * quad;
    }
    fastmath::exp_scale_in_place(out, sf2);
}

/// Fill `rows` (rows `lo..hi`) with fast-mode Psi1: denominators
/// hoisted into reciprocals (one division per (i,k) instead of per
/// (i,j,k)), one batched exp pass per row.
fn psi1_rows_fill_fast(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    ls2: &[f64],
    sf2: f64,
    lo: usize,
    hi: usize,
    inv_dn: &mut [f64],
    rows: &mut [f64],
) {
    let (q, m) = (p.q(), p.m());
    for i in lo..hi {
        let mut log_scale = 0.0;
        for k in 0..q {
            log_scale -= 0.5 * (xvar[(i, k)] / ls2[k]).ln_1p();
            inv_dn[k] = 1.0 / (ls2[k] + xvar[(i, k)]);
        }
        let row = &mut rows[(i - lo) * m..(i - lo + 1) * m];
        psi1_row_fill_fast(&p.z, q, sf2, xmu.row(i), log_scale, inv_dn, row);
    }
}

/// Fast-path Psi1 fill over all rows (see [`psi1_rows_fill_fast`]).
fn psi1_fill_fast(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    ls2: &[f64],
    sf2: f64,
    inv_dn: &mut [f64],
    out: &mut Matrix,
) {
    let b = xmu.rows();
    out.reset(b, p.m(), 0.0);
    psi1_rows_fill_fast(p, xmu, xvar, ls2, sf2, 0, b, inv_dn, out.data_mut());
}

/// Fast-path variant of [`psi2_row_fill_tabled`]: reciprocal
/// denominators (`inv_dn2[k] = 1 / (ls2_k + 2 s_ik)`), exponents
/// accumulated into `out` first, then one batched exp pass over the
/// whole m*m block. `MathMode::Fast` only.
fn psi2_row_fill_fast(
    q: usize,
    zq: &[f64],
    zbar: &[f64],
    sf2: f64,
    xmu_i: &[f64],
    log_scale: f64,
    inv_dn2: &[f64],
    out: &mut [f64],
) {
    let mut t = 0;
    let mut chunks = out.chunks_exact_mut(LANES);
    for chunk in &mut chunks {
        let mut e = [log_scale; LANES];
        for k in 0..q {
            let mu = xmu_i[k];
            let inv = inv_dn2[k];
            for (lane, acc) in e.iter_mut().enumerate() {
                let o = t + lane * q + k;
                let dm = mu - zbar[o];
                *acc -= zq[o] + dm * dm * inv;
            }
        }
        chunk.copy_from_slice(&e);
        t += LANES * q;
    }
    for o in chunks.into_remainder().iter_mut() {
        let mut e = log_scale;
        for k in 0..q {
            let dm = xmu_i[k] - zbar[t + k];
            e -= zq[t + k] + dm * dm * inv_dn2[k];
        }
        *o = e;
        t += q;
    }
    fastmath::exp_scale_in_place(out, sf2 * sf2);
}

/// Default cap on the cached per-point Psi2 slab, in `b * m * m` f64
/// entries (8 MiB-entries = 64 MiB). Above it the slab holds one
/// **tile** of points at a time and the gradient round streams the
/// shard tile-by-tile (refilling the slab block-wise instead of
/// falling back to a per-point workspace) — still allocation-free,
/// still reusing Psi1 and the per-point log-scales.
pub const DEFAULT_SLAB_LIMIT: usize = 1 << 23;

/// Split `n_rows` into at most `threads` contiguous, disjoint row
/// ranges — the determinism contract of intra-worker parallel fill
/// (DESIGN.md §11): the split is a **pure function of
/// `(n_rows, threads)`** (the first `n_rows % threads` ranges get one
/// extra row, mirroring the coordinator's `split_even` sharding), so
/// which thread fills which rows never depends on scheduling, and the
/// filled bytes are identical for any thread count because every
/// per-row fill is row-independent.
pub fn fill_ranges(n_rows: usize, threads: usize) -> Vec<(usize, usize)> {
    let t = threads.max(1).min(n_rows.max(1));
    let base = n_rows / t;
    let extra = n_rows % t;
    let mut out = Vec::with_capacity(t);
    let mut lo = 0;
    for k in 0..t {
        let len = base + usize::from(k < extra);
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

/// Row-range core of the "head" pass (phase 1 of a fill): strict or
/// fast Psi1 rows plus every row's Psi2 log-scale. Each invocation
/// touches only rows `lo..hi` (stored from `psi1_rows[0]` /
/// `log_scales[0]`), so disjoint ranges can run on different threads
/// with bitwise-deterministic results.
fn head_fill_rows(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    ls2: &[f64],
    sf2: f64,
    mode: MathMode,
    lo: usize,
    hi: usize,
    dn: &mut [f64],
    psi1_rows: &mut [f64],
    log_scales: &mut [f64],
) {
    match mode {
        MathMode::Strict => psi1_rows_fill(p, xmu, xvar, ls2, sf2, lo, hi, dn, psi1_rows),
        MathMode::Fast => psi1_rows_fill_fast(p, xmu, xvar, ls2, sf2, lo, hi, dn, psi1_rows),
    }
    for i in lo..hi {
        log_scales[i - lo] = psi2_point_log_scale(ls2, xvar.row(i));
    }
}

/// Row-range core of a Psi2 tile fill (phase 2): one m*m block per row
/// of `slab_rows`, row `r` holding global point `row0 + r`.
/// `log_scales[r]` is that point's precomputed log-scale. Disjoint
/// `slab_rows` windows are thread-safe for the same reason as
/// [`head_fill_rows`].
fn psi2_fill_rows(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    ls2: &[f64],
    sf2: f64,
    mode: MathMode,
    row0: usize,
    zq: &[f64],
    zbar: &[f64],
    log_scales: &[f64],
    dn2: &mut [f64],
    slab_rows: &mut [f64],
) {
    let (m, q) = (p.m(), p.q());
    let mm = m * m;
    for (r, block) in slab_rows.chunks_exact_mut(mm).enumerate() {
        let i = row0 + r;
        match mode {
            MathMode::Strict => {
                for (k, d) in dn2.iter_mut().enumerate() {
                    *d = ls2[k] + 2.0 * xvar[(i, k)];
                }
                psi2_row_fill_tabled(m, q, zq, zbar, sf2, xmu.row(i), log_scales[r], dn2, block);
            }
            MathMode::Fast => {
                for (k, d) in dn2.iter_mut().enumerate() {
                    *d = 1.0 / (ls2[k] + 2.0 * xvar[(i, k)]);
                }
                psi2_row_fill_fast(q, zq, zbar, sf2, xmu.row(i), log_scales[r], dn2, block);
            }
        }
    }
}

/// Reusable per-shard workspace for one bound/gradient evaluation.
///
/// Filled by [`shard_stats_into`] (map round 1), consumed by
/// [`shard_grads_vjp_cached`] (map round 2). Owns every intermediate
/// the two rounds share — squared lengthscales, Psi1, the per-point
/// Psi2 blocks (or just their exponent components when the slab is
/// gated off by `slab_limit`) — plus the small per-point denominator
/// buffers, so a steady-state evaluation performs **zero** heap
/// allocation in the psi loops. Lifetime/versioning is owned by the
/// executor layer (`runtime::ShardExecutor::begin_eval`): the scratch
/// itself only knows whether it is `filled` for given shapes.
pub struct ShardScratch {
    /// squared lengthscales exp(2 log_ls), length q
    ls2: Vec<f64>,
    /// kernel variance exp(log_sf2)
    sf2: f64,
    /// cached Psi1 [b x m]
    psi1: Matrix,
    /// per-point Psi2 log-scale, length b
    psi2_log_scale: Vec<f64>,
    /// per-point Psi2 slab: every point's block [b * m * m] when the
    /// shard fits within `slab_limit`, otherwise one streamed tile of
    /// `tile_rows` blocks refilled block-by-block by round 2
    psi2: Vec<f64>,
    /// whether `psi2` holds every point's block
    psi2_cached: bool,
    /// blocks `psi2` holds at once when streaming (== b when cached)
    tile_rows: usize,
    /// intra-worker fill parallelism: psi fills split their rows into
    /// [`fill_ranges`]`(rows, fill_threads)` and run one scoped thread
    /// per range (1 = the sequential path, no threads spawned).
    /// Deterministic by construction — see DESIGN.md §11.
    fill_threads: usize,
    /// Psi1-adjoint workspace `Y (dF/dC)^T` [b x m] (gradient round)
    a1: Matrix,
    /// per-point Psi1 denominators ls2_k + s_ik, length q
    dn: Vec<f64>,
    /// per-point Psi2 denominators ls2_k + 2 s_ik, length q
    dn2: Vec<f64>,
    /// point-independent Psi2 tables, flat (j,l,k) of length m*m*q:
    /// exponent term dz^2/(4 ls2), midpoint (z_j+z_l)/2, and the chain
    /// terms dz/(2 ls2) and dz^2/(2 ls2) — computed once per fill by
    /// the exact inline expressions they replace
    zq: Vec<f64>,
    zbar: Vec<f64>,
    zd: Vec<f64>,
    zdd: Vec<f64>,
    /// 2 ls2_k, length q
    tl2: Vec<f64>,
    /// per-point chain hoists 1/dn2, 2 s_ik/dn2, dn2^2, length q each
    inv_dn2: Vec<f64>,
    xv2: Vec<f64>,
    dn2sq: Vec<f64>,
    /// Fast-mode reciprocal hoists 1/dn, 1/dn^2, 1/dn2^2, length q each
    inv_dn: Vec<f64>,
    inv_dnsq: Vec<f64>,
    inv_dn2sq: Vec<f64>,
    /// shapes the scratch is currently sized for
    b: usize,
    m: usize,
    q: usize,
    /// slab gate: maximum `b * m * m` entries cached
    slab_limit: usize,
    /// psi intermediates are valid for every point of the shard
    filled: bool,
    /// full psi passes computed through this scratch (telemetry)
    fills: u64,
}

impl Default for ShardScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardScratch {
    pub fn new() -> ShardScratch {
        ShardScratch::with_slab_limit(DEFAULT_SLAB_LIMIT)
    }

    /// `slab_limit` caps the cached Psi2 slab in `b * m * m` entries.
    /// A shard over the cap is **streamed**: round 2 refills the slab
    /// one `tile_rows`-block tile at a time (`slab_limit = 0` degrades
    /// to single-point tiles — the minimal-memory mode).
    pub fn with_slab_limit(slab_limit: usize) -> ShardScratch {
        ShardScratch {
            ls2: Vec::new(),
            sf2: 0.0,
            psi1: Matrix::zeros(0, 0),
            psi2_log_scale: Vec::new(),
            psi2: Vec::new(),
            psi2_cached: false,
            tile_rows: 0,
            fill_threads: 1,
            a1: Matrix::zeros(0, 0),
            dn: Vec::new(),
            dn2: Vec::new(),
            zq: Vec::new(),
            zbar: Vec::new(),
            zd: Vec::new(),
            zdd: Vec::new(),
            tl2: Vec::new(),
            inv_dn2: Vec::new(),
            xv2: Vec::new(),
            dn2sq: Vec::new(),
            inv_dn: Vec::new(),
            inv_dnsq: Vec::new(),
            inv_dn2sq: Vec::new(),
            b: 0,
            m: 0,
            q: 0,
            slab_limit,
            filled: false,
            fills: 0,
        }
    }

    /// Drop the cached psi intermediates (parameters or shard changed).
    /// Buffers keep their allocations for the next fill.
    pub fn invalidate(&mut self) {
        self.filled = false;
    }

    /// Is the scratch filled for a (b, m, q) shard?
    pub fn is_filled_for(&self, b: usize, m: usize, q: usize) -> bool {
        self.filled && self.b == b && self.m == m && self.q == q
    }

    /// Cumulative count of full psi passes computed through this
    /// scratch — the per-evaluation "psi recompute" telemetry signal.
    pub fn psi_fills(&self) -> u64 {
        self.fills
    }

    /// Whether the last fill kept the full per-point Psi2 slab.
    pub fn psi2_slab_cached(&self) -> bool {
        self.filled && self.psi2_cached
    }

    /// Set the intra-worker fill parallelism (clamped to >= 1). The
    /// cached psi intermediates stay valid: thread count never changes
    /// the filled bytes (DESIGN.md §11), only how many cores fill them.
    pub fn set_fill_threads(&mut self, threads: usize) {
        self.fill_threads = threads.max(1);
    }

    /// Current intra-worker fill parallelism.
    pub fn fill_threads(&self) -> usize {
        self.fill_threads
    }

    /// (Re)size every buffer for a (b, m, q) shard and precompute the
    /// parameter-dependent scalars. Reuses allocations across calls.
    fn prepare(&mut self, p: &GlobalParams, b: usize) {
        let (m, q) = (p.m(), p.q());
        self.b = b;
        self.m = m;
        self.q = q;
        self.ls2.clear();
        self.ls2.extend(p.log_ls.iter().map(|l| (2.0 * l).exp()));
        self.sf2 = p.sf2();
        self.psi2_log_scale.clear();
        self.psi2_log_scale.resize(b, 0.0);
        let mm = m * m;
        self.psi2_cached = b * mm <= self.slab_limit;
        self.tile_rows = if self.psi2_cached {
            b
        } else {
            // streaming: as many whole blocks as the limit allows, at
            // least one (b >= 1 here, else the shard would be cached)
            (self.slab_limit / mm).max(1).min(b)
        };
        self.psi2.clear();
        self.psi2.resize(self.tile_rows * mm, 0.0);
        self.dn.clear();
        self.dn.resize(q, 0.0);
        self.dn2.clear();
        self.dn2.resize(q, 0.0);
        // point-independent Psi2 tables (O(m^2 q) once per fill, saving
        // the same expressions per point in the O(b m^2 q) loops)
        let mmq = m * m * q;
        self.zq.clear();
        self.zq.resize(mmq, 0.0);
        self.zbar.clear();
        self.zbar.resize(mmq, 0.0);
        self.zd.clear();
        self.zd.resize(mmq, 0.0);
        self.zdd.clear();
        self.zdd.resize(mmq, 0.0);
        let mut t = 0;
        for j in 0..m {
            for l in 0..m {
                for k in 0..q {
                    let dz = p.z[(j, k)] - p.z[(l, k)];
                    self.zq[t + k] = dz * dz / (4.0 * self.ls2[k]);
                    self.zbar[t + k] = 0.5 * (p.z[(j, k)] + p.z[(l, k)]);
                    self.zd[t + k] = dz / (2.0 * self.ls2[k]);
                    self.zdd[t + k] = dz * dz / (2.0 * self.ls2[k]);
                }
                t += q;
            }
        }
        self.tl2.clear();
        self.tl2.extend(self.ls2.iter().map(|l2| 2.0 * l2));
        self.inv_dn2.clear();
        self.inv_dn2.resize(q, 0.0);
        self.xv2.clear();
        self.xv2.resize(q, 0.0);
        self.dn2sq.clear();
        self.dn2sq.resize(q, 0.0);
        self.inv_dn.clear();
        self.inv_dn.resize(q, 0.0);
        self.inv_dnsq.clear();
        self.inv_dnsq.resize(q, 0.0);
        self.inv_dn2sq.clear();
        self.inv_dn2sq.resize(q, 0.0);
        self.filled = false;
    }

    /// Phase 1 of a fill: Psi1 rows + every point's Psi2 log-scale,
    /// split over [`fill_ranges`]`(b, fill_threads)` scoped threads.
    /// Each thread writes a disjoint row window, so the bytes are
    /// independent of scheduling and identical for every thread count.
    /// The scratch must be [`ShardScratch::prepare`]d.
    fn head_fill(&mut self, p: &GlobalParams, xmu: &Matrix, xvar: &Matrix, mode: MathMode) {
        let (b, m, q) = (self.b, self.m, self.q);
        self.psi1.reset(b, m, 0.0);
        let ranges = fill_ranges(b, self.fill_threads);
        if ranges.len() == 1 {
            // sequential path: reuse the scratch-owned workspace, no spawn
            head_fill_rows(
                p,
                xmu,
                xvar,
                &self.ls2,
                self.sf2,
                mode,
                0,
                b,
                &mut self.dn,
                self.psi1.data_mut(),
                &mut self.psi2_log_scale,
            );
            return;
        }
        let (ls2, sf2) = (&self.ls2, self.sf2);
        let mut psi1_rest: &mut [f64] = self.psi1.data_mut();
        let mut ls_rest: &mut [f64] = &mut self.psi2_log_scale;
        std::thread::scope(|s| {
            for &(lo, hi) in &ranges {
                let rows = hi - lo;
                let (p1, rest) = std::mem::take(&mut psi1_rest).split_at_mut(rows * m);
                psi1_rest = rest;
                let (lsc, rest) = std::mem::take(&mut ls_rest).split_at_mut(rows);
                ls_rest = rest;
                s.spawn(move || {
                    let mut span =
                        crate::obs::trace::span("psi_fill", crate::obs::trace::current());
                    span.set_count(rows as u64);
                    let mut dn = vec![0.0; q];
                    head_fill_rows(p, xmu, xvar, ls2, sf2, mode, lo, hi, &mut dn, p1, lsc);
                });
            }
        });
    }

    /// Phase 2 of a fill: the Psi2 blocks of rows `lo..hi` into the
    /// slab (block of row `i` at slab offset `(i - lo) * m * m`; a
    /// cached slab is one tile with `lo = 0`), split over
    /// [`fill_ranges`]`(hi - lo, fill_threads)` scoped threads with the
    /// same disjoint-write determinism as [`ShardScratch::head_fill`].
    /// Requires the head pass's per-point log-scales.
    fn psi2_tile_fill(
        &mut self,
        p: &GlobalParams,
        xmu: &Matrix,
        xvar: &Matrix,
        lo: usize,
        hi: usize,
        mode: MathMode,
    ) {
        let (m, q) = (self.m, self.q);
        let mm = m * m;
        let rows = hi - lo;
        let ranges = fill_ranges(rows, self.fill_threads);
        if ranges.len() == 1 {
            psi2_fill_rows(
                p,
                xmu,
                xvar,
                &self.ls2,
                self.sf2,
                mode,
                lo,
                &self.zq,
                &self.zbar,
                &self.psi2_log_scale[lo..hi],
                &mut self.dn2,
                &mut self.psi2[..rows * mm],
            );
            return;
        }
        let (ls2, sf2) = (&self.ls2, self.sf2);
        let (zq, zbar) = (&self.zq, &self.zbar);
        let log_scales = &self.psi2_log_scale;
        let mut slab_rest: &mut [f64] = &mut self.psi2[..rows * mm];
        std::thread::scope(|s| {
            for &(r0, r1) in &ranges {
                let (slab, rest) = std::mem::take(&mut slab_rest).split_at_mut((r1 - r0) * mm);
                slab_rest = rest;
                let lsc = &log_scales[lo + r0..lo + r1];
                s.spawn(move || {
                    let mut span =
                        crate::obs::trace::span("psi_fill", crate::obs::trace::current());
                    span.set_count((r1 - r0) as u64);
                    let mut dn2 = vec![0.0; q];
                    psi2_fill_rows(
                        p,
                        xmu,
                        xvar,
                        ls2,
                        sf2,
                        mode,
                        lo + r0,
                        zq,
                        zbar,
                        lsc,
                        &mut dn2,
                        slab,
                    );
                });
            }
        });
    }

    /// Full psi pass with no statistics accumulation — the gradient
    /// round's fallback when round 1 did not run at this parameter
    /// version (or ran masked). Values are bit-identical to what
    /// [`shard_stats_into`] fills.
    fn fill(&mut self, p: &GlobalParams, xmu: &Matrix, xvar: &Matrix) {
        self.fill_mode(p, xmu, xvar, MathMode::Strict);
    }

    /// Fast-mode counterpart of [`ShardScratch::fill`]: same structure,
    /// fast fill kernels. Values match what [`shard_stats_into_fast`]
    /// fills (both funnel through the same fast helpers).
    fn fill_fast(&mut self, p: &GlobalParams, xmu: &Matrix, xvar: &Matrix) {
        self.fill_mode(p, xmu, xvar, MathMode::Fast);
    }

    fn fill_mode(&mut self, p: &GlobalParams, xmu: &Matrix, xvar: &Matrix, mode: MathMode) {
        let b = xmu.rows();
        self.prepare(p, b);
        self.head_fill(p, xmu, xvar, mode);
        if self.psi2_cached {
            self.psi2_tile_fill(p, xmu, xvar, 0, b, mode);
        }
        self.filled = true;
    }
}

/// Full shard statistics, computed **into** `scratch` so the gradient
/// round can reuse the psi intermediates. `kl_weight` = 0 selects the
/// regression model, 1 the LVM; matches `ref.shard_stats_ref`.
///
/// The gradient round may only reuse the scratch when every point was
/// live: a masked-out row leaves its Psi2 block stale, so a masked pass
/// does not mark the scratch filled (the gradient round then refills).
pub fn shard_stats_into(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    y: &Matrix,
    mask: &[f64],
    kl_weight: f64,
    scratch: &mut ShardScratch,
) -> Stats {
    shard_stats_mode(p, xmu, xvar, y, mask, kl_weight, scratch, MathMode::Strict)
}

/// Shared body of the two statistics entries: a **two-phase** pass.
/// Phase 1 fills Psi1 + log-scales (all rows, [`fill_ranges`]-parallel);
/// phase 2 walks the shard one Psi2 tile at a time — parallel tile
/// fill, then a **sequential** accumulation of (n, a, C, D, KL) in
/// ascending point order. Only disjoint writes are threaded; every
/// floating-point accumulation keeps the historical i-order, so the
/// statistics are bit-identical for any `fill_threads` (tested).
/// Masked rows are filled (their blocks land in the tile like any
/// other) but never accumulated, and leave the scratch unfilled for
/// round 2, exactly like the pre-threading code.
fn shard_stats_mode(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    y: &Matrix,
    mask: &[f64],
    kl_weight: f64,
    scratch: &mut ShardScratch,
    mode: MathMode,
) -> Stats {
    let b = xmu.rows();
    assert_eq!(mask.len(), b);
    let (m, q) = (p.m(), p.q());
    scratch.prepare(p, b);
    let mut st = Stats::zeros(m, y.cols());
    scratch.head_fill(p, xmu, xvar, mode);
    let mm = m * m;
    let mut complete = true;
    let mut lo = 0;
    while lo < b {
        let hi = (lo + scratch.tile_rows).min(b);
        scratch.psi2_tile_fill(p, xmu, xvar, lo, hi, mode);
        for i in lo..hi {
            let w = mask[i];
            if w == 0.0 {
                complete = false;
                continue;
            }
            st.n += w;
            let yi = y.row(i);
            st.a += w * yi.iter().map(|v| v * v).sum::<f64>();
            // C += w * psi1_i^T y_i
            for j in 0..m {
                let pj = w * scratch.psi1[(i, j)];
                for (cjd, &yv) in st.c.row_mut(j).iter_mut().zip(yi) {
                    *cjd += pj * yv;
                }
            }
            // D += w * Psi2_i, straight out of the tile's slab row
            let row = &scratch.psi2[(i - lo) * mm..(i - lo + 1) * mm];
            for (dv, &v) in st.d.data_mut().iter_mut().zip(row.iter()) {
                *dv += w * v;
            }
            if kl_weight > 0.0 {
                let mut kli = 0.0;
                for k in 0..q {
                    let (mu, s) = (xmu[(i, k)], xvar[(i, k)]);
                    let log_s = if s > 0.0 { s.ln() } else { 0.0 };
                    kli += mu * mu + s - log_s - 1.0;
                }
                st.kl += kl_weight * w * 0.5 * kli;
            }
        }
        lo = hi;
    }
    st.psi0 = scratch.sf2 * st.n;
    scratch.filled = complete;
    scratch.fills += 1;
    st
}

/// `MathMode::Fast` variant of [`shard_stats_into`]: identical
/// structure and caching/masking semantics, but the psi blocks are
/// produced by the fast fill kernels — reciprocal denominators, batched
/// row-wise exponents, one [`fastmath`] exp pass per block. Statistics
/// agree with the Strict path to 1e-9 relative (property-tested), not
/// bit-for-bit. A scratch filled here must be consumed by
/// [`shard_grads_vjp_cached_fast`] (the executor fixes the mode, so
/// modes can never mix within one scratch).
pub fn shard_stats_into_fast(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    y: &Matrix,
    mask: &[f64],
    kl_weight: f64,
    scratch: &mut ShardScratch,
) -> Stats {
    shard_stats_mode(p, xmu, xvar, y, mask, kl_weight, scratch, MathMode::Fast)
}

/// Full shard statistics, pre-refactor loop shape kept **verbatim**
/// (one fresh Psi1 block plus a per-point `psi2_point` allocation):
/// the forced-fresh reference the scratch pipeline is proven
/// bit-identical against, and the "before" series of `bench psi`.
/// `kl_weight` = 0 selects the regression model, 1 the LVM; matches
/// `ref.shard_stats_ref`.
pub fn shard_stats(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    y: &Matrix,
    mask: &[f64],
    kl_weight: f64,
) -> Stats {
    let b = xmu.rows();
    assert_eq!(mask.len(), b);
    let m = p.m();
    let mut st = Stats::zeros(m, y.cols());
    let p1 = psi1(p, xmu, xvar);
    for i in 0..b {
        let w = mask[i];
        if w == 0.0 {
            continue;
        }
        st.n += w;
        let yi = y.row(i);
        st.a += w * yi.iter().map(|v| v * v).sum::<f64>();
        // C += w * psi1_i^T y_i
        for j in 0..m {
            let pj = w * p1[(i, j)];
            for (cjd, &yv) in st.c.row_mut(j).iter_mut().zip(yi) {
                *cjd += pj * yv;
            }
        }
        st.d.axpy(w, &psi2_point(p, xmu.row(i), xvar.row(i)));
        if kl_weight > 0.0 {
            let mut kli = 0.0;
            for k in 0..p.q() {
                let (mu, s) = (xmu[(i, k)], xvar[(i, k)]);
                let log_s = if s > 0.0 { s.ln() } else { 0.0 };
                kli += mu * mu + s - log_s - 1.0;
            }
            st.kl += kl_weight * w * 0.5 * kli;
        }
    }
    st.psi0 = p.sf2() * st.n;
    st
}

/// Pullback of an adjoint A = dF/dKmm onto the kernel parameters
/// (the central node's direct term, paper §3.2 step 3) — the native
/// mirror of the `kmm_grads` artifact:
///
/// ```text
/// dF/dZ[j,q]    = sum_l (A[j,l] + A[l,j]) K[j,l] (z_lq - z_jq)/ls_q^2
/// dF/dlog_ls_q  = sum_{j,l} A[j,l] K[j,l] (z_jq - z_lq)^2 / ls_q^2
/// dF/dlog_sf2   = <A, K>
/// ```
pub fn kmm_vjp(p: &GlobalParams, adj: &Matrix) -> super::params::GlobalGrads {
    let (m, q) = (p.m(), p.q());
    let ls2: Vec<f64> = p.log_ls.iter().map(|l| (2.0 * l).exp()).collect();
    let k = seard(&p.z, &p.z, p);
    let mut g = super::params::GlobalGrads::zeros(m, q);
    for j in 0..m {
        for l in 0..m {
            let ak = adj[(j, l)] * k[(j, l)];
            g.d_log_sf2 += ak;
            for t in 0..q {
                let dz = p.z[(j, t)] - p.z[(l, t)];
                g.d_log_ls[t] += ak * dz * dz / ls2[t];
                // d/dZ[j,t] picks up both A[j,l] and A[l,j] terms; do the
                // A[j,l] half here, the transpose half lands when the loop
                // visits (l, j).
                g.d_z[(j, t)] += ak * (-dz / ls2[t]);
                g.d_z[(l, t)] += ak * (dz / ls2[t]);
            }
        }
    }
    g
}

/// Pullback of the map-step-2 adjoints through the psi statistics — the
/// native mirror of the `shard_grads` artifact. Given the central
/// node's adjoint message (dF/dpsi0, dF/dC, dF/dD, dF/dKL), chain-rules
/// through `C = sum_i Psi1_i^T Y_i`, `D = sum_i Psi2_i`,
/// `psi0 = sf2 * n` and the per-point KL onto the global parameters
/// (Z, log lengthscales, log sf2) and this shard's local parameters
/// (Xmu, Xvar in raw variance space).
///
/// Consumes the psi intermediates `scratch` holds from the statistics
/// round of the same evaluation; if the scratch is not filled for this
/// shard (different shapes, masked round 1, or an invalidated cache)
/// it refills first — the result is bit-identical either way.
///
/// Returns `(global grads, dF/dXmu [b x q], dF/dXvar [b x q])`;
/// `d_log_beta` is left 0 (it is central, paper §3.2 step 3).
/// Derivatives are w.r.t. the same explicit formulas as [`psi1`] /
/// [`psi2_point`]; validated against finite differences of the
/// assembled bound in the tests below.
pub fn shard_grads_vjp_cached(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    y: &Matrix,
    kl_weight: f64,
    adj: &super::bound::Adjoints,
    scratch: &mut ShardScratch,
) -> (super::params::GlobalGrads, Matrix, Matrix) {
    let (b, q, m) = (xmu.rows(), p.q(), p.m());
    let fresh = !scratch.is_filled_for(b, m, q);
    if fresh {
        scratch.fill(p, xmu, xvar);
    }
    if fresh || !scratch.psi2_cached {
        // this call performs a psi pass of its own (full refill, or the
        // slab-less per-point Psi2 recompute)
        scratch.fills += 1;
    }
    let mut g = super::params::GlobalGrads::zeros(m, q);
    let mut d_xmu = Matrix::zeros(b, q);
    let mut d_xvar = Matrix::zeros(b, q);

    // ---- Psi1 path: dF/dPsi1[i,j] = sum_d dF/dC[j,d] * Y[i,d] --------------
    // a1 = Y (dF/dC)^T, into the scratch workspace
    y.matmul_t_into(&adj.d_c, &mut scratch.a1);
    for i in 0..b {
        for k in 0..q {
            scratch.dn[k] = scratch.ls2[k] + xvar[(i, k)];
        }
        for j in 0..m {
            let w = scratch.a1[(i, j)] * scratch.psi1[(i, j)];
            if w == 0.0 {
                continue;
            }
            g.d_log_sf2 += w;
            for k in 0..q {
                let dn = scratch.dn[k];
                let diff = xmu[(i, k)] - p.z[(j, k)];
                // `w * diff / dn` feeds both dZ and dXmu — one division
                let t = w * diff / dn;
                g.d_z[(j, k)] += t;
                d_xmu[(i, k)] -= t;
                d_xvar[(i, k)] += w * 0.5 * (diff * diff / (dn * dn) - 1.0 / dn);
                g.d_log_ls[k] += w * (xvar[(i, k)] / dn + scratch.ls2[k] * diff * diff / (dn * dn));
            }
        }
    }

    // ---- Psi2 path: dF/dPsi2_i[j,l] = dF/dD[j,l] --------------------------
    // The (j,l,k) terms come from the scratch tables; per-point terms are
    // hoisted out of the m^2 loop. Every substitution reproduces the
    // historical expression exactly (same grouping, same rounding). A
    // shard too large for the slab is STREAMED: refill a tile of
    // `tile_rows` points' blocks, consume them, move to the next tile —
    // per-point fill expressions and accumulation order are unchanged,
    // so the result is bit-identical to the fully-cached path.
    let mm = m * m;
    let mut lo = 0;
    while lo < b {
        let hi = if scratch.psi2_cached {
            b
        } else {
            (lo + scratch.tile_rows).min(b)
        };
        if !scratch.psi2_cached {
            // parallel tile refill (disjoint writes); the chain-rule
            // consumption below stays sequential in i-order — GlobalGrads
            // is one shared accumulator, so its summation order is part
            // of the bit-identity contract
            scratch.psi2_tile_fill(p, xmu, xvar, lo, hi, MathMode::Strict);
        }
        for i in lo..hi {
            for k in 0..q {
                scratch.dn2[k] = scratch.ls2[k] + 2.0 * xvar[(i, k)];
                scratch.inv_dn2[k] = 1.0 / scratch.dn2[k];
                scratch.xv2[k] = 2.0 * xvar[(i, k)] / scratch.dn2[k];
                scratch.dn2sq[k] = scratch.dn2[k] * scratch.dn2[k];
            }
            let base = if scratch.psi2_cached { i } else { i - lo };
            let p2 = &scratch.psi2[base * mm..(base + 1) * mm];
            let mut ti = 0;
            for j in 0..m {
                for l in 0..m {
                    let w = adj.d_d[(j, l)] * p2[j * m + l];
                    if w == 0.0 {
                        ti += q;
                        continue;
                    }
                    g.d_log_sf2 += 2.0 * w;
                    for k in 0..q {
                        let dn2 = scratch.dn2[k];
                        let dm = xmu[(i, k)] - scratch.zbar[ti + k];
                        let zd = scratch.zd[ti + k];
                        let md = dm / dn2;
                        g.d_z[(j, k)] += w * (-zd + md);
                        g.d_z[(l, k)] += w * (zd + md);
                        d_xmu[(i, k)] -= w * 2.0 * dm / dn2;
                        d_xvar[(i, k)] +=
                            w * (2.0 * dm * dm / scratch.dn2sq[k] - scratch.inv_dn2[k]);
                        g.d_log_ls[k] += w
                            * (scratch.xv2[k]
                                + scratch.zdd[ti + k]
                                + scratch.tl2[k] * dm * dm / scratch.dn2sq[k]);
                    }
                    ti += q;
                }
            }
        }
        lo = hi;
    }

    // ---- psi0 = sf2 * n: only log sf2 sees it ----------------------------
    g.d_log_sf2 += adj.d_psi0 * scratch.sf2 * b as f64;

    // ---- KL path: kl = klw * 0.5 sum_{i,k} (mu^2 + s - ln s - 1) ---------
    if kl_weight > 0.0 {
        for i in 0..b {
            for k in 0..q {
                let s = xvar[(i, k)];
                d_xmu[(i, k)] += adj.d_kl * kl_weight * xmu[(i, k)];
                let ds = if s > 0.0 { 0.5 * (1.0 - 1.0 / s) } else { 0.5 };
                d_xvar[(i, k)] += adj.d_kl * kl_weight * ds;
            }
        }
    }

    (g, d_xmu, d_xvar)
}

/// `MathMode::Fast` variant of [`shard_grads_vjp_cached`]: the same
/// chain rules with every per-point division hoisted into a precomputed
/// reciprocal (the strict loop divides by the denominators up to m^2
/// times per point; this multiplies), shared squared terms factored
/// once, and the streamed-tile Psi2 refills produced by the fast fill
/// kernels. Gradients agree with the Strict path to 1e-9 relative and
/// with finite differences of the bound (both tested).
pub fn shard_grads_vjp_cached_fast(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    y: &Matrix,
    kl_weight: f64,
    adj: &super::bound::Adjoints,
    scratch: &mut ShardScratch,
) -> (super::params::GlobalGrads, Matrix, Matrix) {
    let (b, q, m) = (xmu.rows(), p.q(), p.m());
    let fresh = !scratch.is_filled_for(b, m, q);
    if fresh {
        scratch.fill_fast(p, xmu, xvar);
    }
    if fresh || !scratch.psi2_cached {
        // this call performs a psi pass of its own (full refill, or the
        // tile-streamed Psi2 recompute)
        scratch.fills += 1;
    }
    let mut g = super::params::GlobalGrads::zeros(m, q);
    let mut d_xmu = Matrix::zeros(b, q);
    let mut d_xvar = Matrix::zeros(b, q);

    // ---- Psi1 path: dF/dPsi1[i,j] = sum_d dF/dC[j,d] * Y[i,d] --------------
    y.matmul_t_into(&adj.d_c, &mut scratch.a1);
    for i in 0..b {
        for k in 0..q {
            let inv = 1.0 / (scratch.ls2[k] + xvar[(i, k)]);
            scratch.inv_dn[k] = inv;
            scratch.inv_dnsq[k] = inv * inv;
        }
        for j in 0..m {
            let w = scratch.a1[(i, j)] * scratch.psi1[(i, j)];
            if w == 0.0 {
                continue;
            }
            g.d_log_sf2 += w;
            for k in 0..q {
                let inv = scratch.inv_dn[k];
                let diff = xmu[(i, k)] - p.z[(j, k)];
                let t = w * diff * inv;
                g.d_z[(j, k)] += t;
                d_xmu[(i, k)] -= t;
                let d2 = diff * diff * scratch.inv_dnsq[k];
                d_xvar[(i, k)] += w * 0.5 * (d2 - inv);
                g.d_log_ls[k] += w * (xvar[(i, k)] * inv + scratch.ls2[k] * d2);
            }
        }
    }

    // ---- Psi2 path: dF/dPsi2_i[j,l] = dF/dD[j,l] --------------------------
    let mm = m * m;
    let mut lo = 0;
    while lo < b {
        let hi = if scratch.psi2_cached {
            b
        } else {
            (lo + scratch.tile_rows).min(b)
        };
        if !scratch.psi2_cached {
            // parallel tile refill; consumption stays sequential (see
            // the strict variant)
            scratch.psi2_tile_fill(p, xmu, xvar, lo, hi, MathMode::Fast);
        }
        for i in lo..hi {
            for k in 0..q {
                let inv = 1.0 / (scratch.ls2[k] + 2.0 * xvar[(i, k)]);
                scratch.inv_dn2[k] = inv;
                scratch.inv_dn2sq[k] = inv * inv;
                scratch.xv2[k] = 2.0 * xvar[(i, k)] * inv;
            }
            let base = if scratch.psi2_cached { i } else { i - lo };
            let p2 = &scratch.psi2[base * mm..(base + 1) * mm];
            let mut ti = 0;
            for j in 0..m {
                for l in 0..m {
                    let w = adj.d_d[(j, l)] * p2[j * m + l];
                    if w == 0.0 {
                        ti += q;
                        continue;
                    }
                    g.d_log_sf2 += 2.0 * w;
                    for k in 0..q {
                        let inv = scratch.inv_dn2[k];
                        let dm = xmu[(i, k)] - scratch.zbar[ti + k];
                        let zd = scratch.zd[ti + k];
                        let md = dm * inv;
                        g.d_z[(j, k)] += w * (-zd + md);
                        g.d_z[(l, k)] += w * (zd + md);
                        d_xmu[(i, k)] -= 2.0 * w * md;
                        let r2 = dm * dm * scratch.inv_dn2sq[k];
                        d_xvar[(i, k)] += w * (2.0 * r2 - inv);
                        g.d_log_ls[k] +=
                            w * (scratch.xv2[k] + scratch.zdd[ti + k] + scratch.tl2[k] * r2);
                    }
                    ti += q;
                }
            }
        }
        lo = hi;
    }

    // ---- psi0 = sf2 * n: only log sf2 sees it ----------------------------
    g.d_log_sf2 += adj.d_psi0 * scratch.sf2 * b as f64;

    // ---- KL path: kl = klw * 0.5 sum_{i,k} (mu^2 + s - ln s - 1) ---------
    if kl_weight > 0.0 {
        for i in 0..b {
            for k in 0..q {
                let s = xvar[(i, k)];
                d_xmu[(i, k)] += adj.d_kl * kl_weight * xmu[(i, k)];
                let ds = if s > 0.0 { 0.5 * (1.0 - 1.0 / s) } else { 0.5 };
                d_xvar[(i, k)] += adj.d_kl * kl_weight * ds;
            }
        }
    }

    (g, d_xmu, d_xvar)
}

/// Adjoint chain rule through the psi statistics, pre-refactor loop
/// shape kept **verbatim** (full psi recompute, per-point `psi2_point`
/// allocation, per-(j,l) denominator recompute): the forced-fresh
/// reference mode. [`shard_grads_vjp_cached`] must reproduce it
/// bit-for-bit (unit- and property-tested); the cluster trace tests
/// pin the equality end to end.
pub fn shard_grads_vjp(
    p: &GlobalParams,
    xmu: &Matrix,
    xvar: &Matrix,
    y: &Matrix,
    kl_weight: f64,
    adj: &super::bound::Adjoints,
) -> (super::params::GlobalGrads, Matrix, Matrix) {
    let (b, q, m) = (xmu.rows(), p.q(), p.m());
    let dout = y.cols();
    let ls2: Vec<f64> = p.log_ls.iter().map(|l| (2.0 * l).exp()).collect();
    let sf2 = p.sf2();
    let mut g = super::params::GlobalGrads::zeros(m, q);
    let mut d_xmu = Matrix::zeros(b, q);
    let mut d_xvar = Matrix::zeros(b, q);

    // ---- Psi1 path: dF/dPsi1[i,j] = sum_d dF/dC[j,d] * Y[i,d] --------------
    let p1 = psi1(p, xmu, xvar);
    for i in 0..b {
        let yi = y.row(i);
        for j in 0..m {
            let mut a1 = 0.0;
            for dd in 0..dout {
                a1 += adj.d_c[(j, dd)] * yi[dd];
            }
            let w = a1 * p1[(i, j)];
            if w == 0.0 {
                continue;
            }
            g.d_log_sf2 += w;
            for k in 0..q {
                let dn = ls2[k] + xvar[(i, k)];
                let diff = xmu[(i, k)] - p.z[(j, k)];
                g.d_z[(j, k)] += w * diff / dn;
                d_xmu[(i, k)] -= w * diff / dn;
                d_xvar[(i, k)] += w * 0.5 * (diff * diff / (dn * dn) - 1.0 / dn);
                g.d_log_ls[k] += w * (xvar[(i, k)] / dn + ls2[k] * diff * diff / (dn * dn));
            }
        }
    }

    // ---- Psi2 path: dF/dPsi2_i[j,l] = dF/dD[j,l] --------------------------
    for i in 0..b {
        let p2 = psi2_point(p, xmu.row(i), xvar.row(i));
        for j in 0..m {
            for l in 0..m {
                let w = adj.d_d[(j, l)] * p2[(j, l)];
                if w == 0.0 {
                    continue;
                }
                g.d_log_sf2 += 2.0 * w;
                for k in 0..q {
                    let dn2 = ls2[k] + 2.0 * xvar[(i, k)];
                    let dz = p.z[(j, k)] - p.z[(l, k)];
                    let dm = xmu[(i, k)] - 0.5 * (p.z[(j, k)] + p.z[(l, k)]);
                    g.d_z[(j, k)] += w * (-dz / (2.0 * ls2[k]) + dm / dn2);
                    g.d_z[(l, k)] += w * (dz / (2.0 * ls2[k]) + dm / dn2);
                    d_xmu[(i, k)] -= w * 2.0 * dm / dn2;
                    d_xvar[(i, k)] += w * (2.0 * dm * dm / (dn2 * dn2) - 1.0 / dn2);
                    g.d_log_ls[k] += w
                        * (2.0 * xvar[(i, k)] / dn2
                            + dz * dz / (2.0 * ls2[k])
                            + 2.0 * ls2[k] * dm * dm / (dn2 * dn2));
                }
            }
        }
    }

    // ---- psi0 = sf2 * n: only log sf2 sees it ----------------------------
    g.d_log_sf2 += adj.d_psi0 * sf2 * b as f64;

    // ---- KL path: kl = klw * 0.5 sum_{i,k} (mu^2 + s - ln s - 1) ---------
    if kl_weight > 0.0 {
        for i in 0..b {
            for k in 0..q {
                let s = xvar[(i, k)];
                d_xmu[(i, k)] += adj.d_kl * kl_weight * xmu[(i, k)];
                let ds = if s > 0.0 { 0.5 * (1.0 - 1.0 / s) } else { 0.5 };
                d_xvar[(i, k)] += adj.d_kl * kl_weight * ds;
            }
        }
    }

    (g, d_xmu, d_xvar)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::bound::Adjoints;
    use crate::util::rng::Rng;

    fn params(m: usize, q: usize, seed: u64) -> GlobalParams {
        let mut rng = Rng::new(seed);
        GlobalParams {
            z: Matrix::from_fn(m, q, |_, _| rng.normal()),
            log_ls: (0..q).map(|_| 0.3 * rng.normal()).collect(),
            log_sf2: 0.2,
            log_beta: 1.0,
        }
    }

    #[test]
    fn seard_diag_is_sf2() {
        let p = params(4, 2, 0);
        let k = seard(&p.z, &p.z, &p);
        for i in 0..4 {
            assert!((k[(i, i)] - p.sf2()).abs() < 1e-14);
        }
    }

    #[test]
    fn seard_symmetric_and_bounded() {
        let p = params(5, 3, 1);
        let k = seard(&p.z, &p.z, &p);
        assert!(k.max_abs_diff(&k.transpose()) < 1e-15);
        for v in k.data() {
            assert!(*v > 0.0 && *v <= p.sf2() + 1e-14);
        }
    }

    /// The `_into` psi fills (the standalone Predictor's hot path) must
    /// be bit-identical to the allocating `psi1` / `psi2_point`.
    #[test]
    fn psi_into_variants_match_allocating_variants_bitwise() {
        let p = params(5, 3, 17);
        let mut rng = Rng::new(18);
        let b = 7;
        let xmu = Matrix::from_fn(b, 3, |_, _| rng.normal());
        let xvar = Matrix::from_fn(b, 3, |_, _| 0.05 + rng.uniform());
        let ls2: Vec<f64> = p.log_ls.iter().map(|l| (2.0 * l).exp()).collect();
        let sf2 = p.sf2();

        // deliberately dirty, mis-shaped workspaces
        let mut dn = vec![f64::NAN; 3];
        let mut out = Matrix::from_fn(2, 2, |_, _| f64::NAN);
        psi1_into(&p, &xmu, &xvar, &ls2, sf2, &mut dn, &mut out);
        let reference = psi1(&p, &xmu, &xvar);
        assert_eq!((out.rows(), out.cols()), (b, 5));
        for (a, r) in out.data().iter().zip(reference.data()) {
            assert_eq!(a.to_bits(), r.to_bits(), "psi1_into diverged from psi1");
        }

        let mut dn2 = vec![f64::NAN; 3];
        let mut block = vec![f64::NAN; 25];
        for i in 0..b {
            psi2_point_into(&p.z, &ls2, sf2, xmu.row(i), xvar.row(i), &mut dn2, &mut block);
            let reference = psi2_point(&p, xmu.row(i), xvar.row(i));
            for (a, r) in block.iter().zip(reference.data()) {
                assert_eq!(
                    a.to_bits(),
                    r.to_bits(),
                    "psi2_point_into diverged from psi2_point at point {i}"
                );
            }
        }
    }

    #[test]
    fn psi1_reduces_to_kernel_at_zero_variance() {
        let p = params(4, 2, 2);
        let mut rng = Rng::new(3);
        let xmu = Matrix::from_fn(6, 2, |_, _| rng.normal());
        let xvar = Matrix::zeros(6, 2);
        let p1 = psi1(&p, &xmu, &xvar);
        let knm = seard(&xmu, &p.z, &p);
        assert!(p1.max_abs_diff(&knm) < 1e-13);
    }

    #[test]
    fn psi2_reduces_to_outer_product_at_zero_variance() {
        let p = params(3, 2, 4);
        let mut rng = Rng::new(5);
        let x: Vec<f64> = vec![rng.normal(), rng.normal()];
        let xm = Matrix::from_vec(1, 2, x.clone());
        let k = seard(&xm, &p.z, &p); // [1, m]
        let p2 = psi2_point(&p, &x, &[0.0, 0.0]);
        for j in 0..3 {
            for l in 0..3 {
                assert!((p2[(j, l)] - k[(0, j)] * k[(0, l)]).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn kmm_vjp_matches_finite_difference() {
        let p = params(4, 3, 10);
        let mut rng = Rng::new(11);
        let adj = Matrix::from_fn(4, 4, |_, _| rng.normal());
        let g = kmm_vjp(&p, &adj);
        let f_of = |p: &GlobalParams| adj.dot(&seard(&p.z, &p.z, p));
        let eps = 1e-6;
        // Z entries
        for &(j, t) in &[(0, 0), (2, 1), (3, 2)] {
            let mut pp = p.clone();
            pp.z[(j, t)] += eps;
            let mut pm = p.clone();
            pm.z[(j, t)] -= eps;
            let fd = (f_of(&pp) - f_of(&pm)) / (2.0 * eps);
            assert!(
                (g.d_z[(j, t)] - fd).abs() < 1e-6 * (1.0 + fd.abs()),
                "dZ[{j},{t}] {} vs {}",
                g.d_z[(j, t)],
                fd
            );
        }
        // log lengthscales
        for t in 0..3 {
            let mut pp = p.clone();
            pp.log_ls[t] += eps;
            let mut pm = p.clone();
            pm.log_ls[t] -= eps;
            let fd = (f_of(&pp) - f_of(&pm)) / (2.0 * eps);
            assert!((g.d_log_ls[t] - fd).abs() < 1e-6 * (1.0 + fd.abs()));
        }
        // log sf2
        let mut pp = p.clone();
        pp.log_sf2 += eps;
        let mut pm = p.clone();
        pm.log_sf2 -= eps;
        let fd = (f_of(&pp) - f_of(&pm)) / (2.0 * eps);
        assert!((g.d_log_sf2 - fd).abs() < 1e-6 * (1.0 + fd.abs()));
    }

    /// The full native gradient (shard VJP + central Kmm pullback) must
    /// match finite differences of the assembled bound — the same
    /// composition the distributed trainer runs every iteration, so this
    /// pins the whole native fallback path end to end.
    #[test]
    fn shard_grads_vjp_matches_finite_difference_of_bound() {
        let (m, q, dout, b) = (4, 2, 2, 6);
        let jitter = 1e-6;
        let klw = 1.0;
        let mut rng = Rng::new(77);
        let p0 = params(m, q, 20);
        let xmu0 = Matrix::from_fn(b, q, |_, _| rng.normal());
        let xvar0 = Matrix::from_fn(b, q, |_, _| 0.2 + 0.5 * rng.uniform());
        let y = Matrix::from_fn(b, dout, |_, _| rng.normal());

        let f_of = |p: &GlobalParams, xmu: &Matrix, xvar: &Matrix| -> f64 {
            let st = shard_stats(p, xmu, xvar, &y, &vec![1.0; b], klw);
            let kmm = kmm(p, jitter);
            let (bv, _) = crate::gp::assemble_bound(&st, &kmm, p.log_beta, dout).unwrap();
            bv.f
        };

        // analytic gradient: shard VJP + central Kmm pullback
        let st = shard_stats(&p0, &xmu0, &xvar0, &y, &vec![1.0; b], klw);
        let kmm0 = kmm(&p0, jitter);
        let (_, adj) = crate::gp::assemble_bound(&st, &kmm0, p0.log_beta, dout).unwrap();
        let (mut g, d_xmu, d_xvar) = shard_grads_vjp(&p0, &xmu0, &xvar0, &y, klw, &adj);
        g.accumulate(&kmm_vjp(&p0, &adj.d_kmm));

        let eps = 1e-6;
        let check = |analytic: f64, fd: f64, what: &str| {
            assert!(
                (analytic - fd).abs() < 2e-5 * (1.0 + fd.abs()),
                "{what}: analytic {analytic} vs fd {fd}"
            );
        };
        for &(j, k) in &[(0, 0), (1, 1), (3, 0)] {
            let mut pp = p0.clone();
            pp.z[(j, k)] += eps;
            let mut pm = p0.clone();
            pm.z[(j, k)] -= eps;
            let fd = (f_of(&pp, &xmu0, &xvar0) - f_of(&pm, &xmu0, &xvar0)) / (2.0 * eps);
            check(g.d_z[(j, k)], fd, &format!("dZ[{j},{k}]"));
        }
        for k in 0..q {
            let mut pp = p0.clone();
            pp.log_ls[k] += eps;
            let mut pm = p0.clone();
            pm.log_ls[k] -= eps;
            let fd = (f_of(&pp, &xmu0, &xvar0) - f_of(&pm, &xmu0, &xvar0)) / (2.0 * eps);
            check(g.d_log_ls[k], fd, &format!("dlog_ls[{k}]"));
        }
        {
            let mut pp = p0.clone();
            pp.log_sf2 += eps;
            let mut pm = p0.clone();
            pm.log_sf2 -= eps;
            let fd = (f_of(&pp, &xmu0, &xvar0) - f_of(&pm, &xmu0, &xvar0)) / (2.0 * eps);
            check(g.d_log_sf2, fd, "dlog_sf2");
        }
        for &(i, k) in &[(0, 0), (2, 1), (5, 0)] {
            let mut xp = xmu0.clone();
            xp[(i, k)] += eps;
            let mut xm = xmu0.clone();
            xm[(i, k)] -= eps;
            let fd = (f_of(&p0, &xp, &xvar0) - f_of(&p0, &xm, &xvar0)) / (2.0 * eps);
            check(d_xmu[(i, k)], fd, &format!("dXmu[{i},{k}]"));

            let mut vp = xvar0.clone();
            vp[(i, k)] += eps;
            let mut vm = xvar0.clone();
            vm[(i, k)] -= eps;
            let fd = (f_of(&p0, &xmu0, &vp) - f_of(&p0, &xmu0, &vm)) / (2.0 * eps);
            check(d_xvar[(i, k)], fd, &format!("dXvar[{i},{k}]"));
        }
    }

    #[test]
    fn stats_additive_over_split() {
        let p = params(4, 2, 6);
        let mut rng = Rng::new(7);
        let b = 10;
        let xmu = Matrix::from_fn(b, 2, |_, _| rng.normal());
        let xvar = Matrix::from_fn(b, 2, |_, _| rng.uniform() + 0.05);
        let y = Matrix::from_fn(b, 3, |_, _| rng.normal());
        let mask = vec![1.0; b];
        let whole = shard_stats(&p, &xmu, &xvar, &y, &mask, 1.0);
        let take = |r0: usize, r1: usize| {
            let rows = r1 - r0;
            (
                Matrix::from_fn(rows, 2, |i, j| xmu[(r0 + i, j)]),
                Matrix::from_fn(rows, 2, |i, j| xvar[(r0 + i, j)]),
                Matrix::from_fn(rows, 3, |i, j| y[(r0 + i, j)]),
            )
        };
        let (x1, v1, y1) = take(0, 4);
        let (x2, v2, y2) = take(4, 10);
        let mut acc = shard_stats(&p, &x1, &v1, &y1, &vec![1.0; 4], 1.0);
        acc.accumulate(&shard_stats(&p, &x2, &v2, &y2, &vec![1.0; 6], 1.0));
        assert!((acc.a - whole.a).abs() < 1e-12);
        assert!((acc.psi0 - whole.psi0).abs() < 1e-12);
        assert!((acc.kl - whole.kl).abs() < 1e-12);
        assert!(acc.c.max_abs_diff(&whole.c) < 1e-12);
        assert!(acc.d.max_abs_diff(&whole.d) < 1e-12);
    }

    fn random_adjoints(rng: &mut Rng, m: usize, dout: usize) -> Adjoints {
        Adjoints {
            d_psi0: rng.normal(),
            d_c: Matrix::from_fn(m, dout, |_, _| rng.normal()),
            d_d: Matrix::from_fn(m, m, |_, _| rng.normal()),
            d_kl: rng.normal(),
            d_kmm: Matrix::zeros(m, m),
            d_log_beta: 0.0,
        }
    }

    fn assert_mat_bits_eq(a: &Matrix, b: &Matrix, what: &str) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{what}: shape");
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: {x} vs {y}");
        }
    }

    /// Cached round 2 (slab on AND slab gated off) must equal the
    /// scratch-free path bit-for-bit — the invariant the distributed
    /// trace-equality tests rest on.
    #[test]
    fn cached_stats_and_grads_match_fresh_bitwise() {
        let (m, q, dout, b) = (5, 3, 2, 9);
        let mut rng = Rng::new(41);
        let p = params(m, q, 40);
        let xmu = Matrix::from_fn(b, q, |_, _| rng.normal());
        let xvar = Matrix::from_fn(b, q, |_, _| 0.1 + rng.uniform());
        let y = Matrix::from_fn(b, dout, |_, _| rng.normal());
        let mask = vec![1.0; b];
        let adj = random_adjoints(&mut rng, m, dout);

        let st_ref = shard_stats(&p, &xmu, &xvar, &y, &mask, 1.0);
        let (g_ref, dmu_ref, dvar_ref) = shard_grads_vjp(&p, &xmu, &xvar, &y, 1.0, &adj);

        for limit in [usize::MAX, 0] {
            let mut scratch = ShardScratch::with_slab_limit(limit);
            // two evaluations in a row: the second reuses the buffers
            for _ in 0..2 {
                let st = shard_stats_into(&p, &xmu, &xvar, &y, &mask, 1.0, &mut scratch);
                assert_eq!(st.a.to_bits(), st_ref.a.to_bits());
                assert_eq!(st.psi0.to_bits(), st_ref.psi0.to_bits());
                assert_eq!(st.kl.to_bits(), st_ref.kl.to_bits());
                assert_eq!(st.n.to_bits(), st_ref.n.to_bits());
                assert_mat_bits_eq(&st.c, &st_ref.c, "C");
                assert_mat_bits_eq(&st.d, &st_ref.d, "D");
                let (g, dmu, dvar) =
                    shard_grads_vjp_cached(&p, &xmu, &xvar, &y, 1.0, &adj, &mut scratch);
                assert_mat_bits_eq(&g.d_z, &g_ref.d_z, "dZ");
                assert_eq!(g.d_log_sf2.to_bits(), g_ref.d_log_sf2.to_bits());
                for (a, b) in g.d_log_ls.iter().zip(&g_ref.d_log_ls) {
                    assert_eq!(a.to_bits(), b.to_bits(), "dlog_ls");
                }
                assert_mat_bits_eq(&dmu, &dmu_ref, "dXmu");
                assert_mat_bits_eq(&dvar, &dvar_ref, "dXvar");
            }
        }
    }

    /// Streaming tiles (slab smaller than the shard) must reproduce the
    /// fully-cached strict results bit-for-bit: the tiling layer only
    /// re-blocks the per-point work, it never changes an expression.
    #[test]
    fn strict_tiled_streaming_matches_full_slab_bitwise() {
        let (m, q, dout, b) = (5, 3, 2, 9);
        let mm = m * m;
        let mut rng = Rng::new(61);
        let p = params(m, q, 60);
        let xmu = Matrix::from_fn(b, q, |_, _| rng.normal());
        let xvar = Matrix::from_fn(b, q, |_, _| 0.1 + rng.uniform());
        let y = Matrix::from_fn(b, dout, |_, _| rng.normal());
        let mask = vec![1.0; b];
        let adj = random_adjoints(&mut rng, m, dout);

        let st_ref = shard_stats(&p, &xmu, &xvar, &y, &mask, 1.0);
        let (g_ref, dmu_ref, dvar_ref) = shard_grads_vjp(&p, &xmu, &xvar, &y, 1.0, &adj);

        // tiles of 4, 2 and 1 points, plus the degenerate 0-limit
        for limit in [4 * mm, 2 * mm + 3, mm, 0] {
            let mut scratch = ShardScratch::with_slab_limit(limit);
            let st = shard_stats_into(&p, &xmu, &xvar, &y, &mask, 1.0, &mut scratch);
            assert!(!scratch.psi2_slab_cached(), "limit {limit} must stream");
            assert_eq!(st.a.to_bits(), st_ref.a.to_bits());
            assert_mat_bits_eq(&st.c, &st_ref.c, "C (tiled)");
            assert_mat_bits_eq(&st.d, &st_ref.d, "D (tiled)");
            let (g, dmu, dvar) =
                shard_grads_vjp_cached(&p, &xmu, &xvar, &y, 1.0, &adj, &mut scratch);
            assert_mat_bits_eq(&g.d_z, &g_ref.d_z, "dZ (tiled)");
            assert_eq!(g.d_log_sf2.to_bits(), g_ref.d_log_sf2.to_bits());
            for (a, b) in g.d_log_ls.iter().zip(&g_ref.d_log_ls) {
                assert_eq!(a.to_bits(), b.to_bits(), "dlog_ls (tiled)");
            }
            assert_mat_bits_eq(&dmu, &dmu_ref, "dXmu (tiled)");
            assert_mat_bits_eq(&dvar, &dvar_ref, "dXvar (tiled)");
        }
    }

    /// Fast mode is deterministic: tiled streaming must reproduce the
    /// fully-cached fast results bit-for-bit (within the mode).
    #[test]
    fn fast_tiled_streaming_matches_fast_full_slab_bitwise() {
        let (m, q, dout, b) = (4, 2, 3, 11);
        let mm = m * m;
        let mut rng = Rng::new(71);
        let p = params(m, q, 70);
        let xmu = Matrix::from_fn(b, q, |_, _| rng.normal());
        let xvar = Matrix::from_fn(b, q, |_, _| 0.1 + rng.uniform());
        let y = Matrix::from_fn(b, dout, |_, _| rng.normal());
        let mask = vec![1.0; b];
        let adj = random_adjoints(&mut rng, m, dout);

        let mut full = ShardScratch::new();
        let st_ref = shard_stats_into_fast(&p, &xmu, &xvar, &y, &mask, 1.0, &mut full);
        let (g_ref, dmu_ref, dvar_ref) =
            shard_grads_vjp_cached_fast(&p, &xmu, &xvar, &y, 1.0, &adj, &mut full);

        for limit in [3 * mm, mm, 0] {
            let mut scratch = ShardScratch::with_slab_limit(limit);
            let st = shard_stats_into_fast(&p, &xmu, &xvar, &y, &mask, 1.0, &mut scratch);
            assert_eq!(st.a.to_bits(), st_ref.a.to_bits());
            assert_mat_bits_eq(&st.c, &st_ref.c, "fast C (tiled)");
            assert_mat_bits_eq(&st.d, &st_ref.d, "fast D (tiled)");
            let (g, dmu, dvar) =
                shard_grads_vjp_cached_fast(&p, &xmu, &xvar, &y, 1.0, &adj, &mut scratch);
            assert_mat_bits_eq(&g.d_z, &g_ref.d_z, "fast dZ (tiled)");
            assert_mat_bits_eq(&dmu, &dmu_ref, "fast dXmu (tiled)");
            assert_mat_bits_eq(&dvar, &dvar_ref, "fast dXvar (tiled)");
        }
    }

    /// The fast-mode analytic gradient must match finite differences of
    /// the fast-mode bound — the same end-to-end composition the
    /// distributed trainer runs under `--math-mode fast`.
    #[test]
    fn fast_grads_match_finite_difference_of_fast_bound() {
        let (m, q, dout, b) = (4, 2, 2, 6);
        let jitter = 1e-6;
        let klw = 1.0;
        let mut rng = Rng::new(87);
        let p0 = params(m, q, 21);
        let xmu0 = Matrix::from_fn(b, q, |_, _| rng.normal());
        let xvar0 = Matrix::from_fn(b, q, |_, _| 0.2 + 0.5 * rng.uniform());
        let y = Matrix::from_fn(b, dout, |_, _| rng.normal());
        let mask = vec![1.0; b];

        let f_of = |p: &GlobalParams, xmu: &Matrix, xvar: &Matrix| -> f64 {
            let mut scratch = ShardScratch::new();
            let st = shard_stats_into_fast(p, xmu, xvar, &y, &mask, klw, &mut scratch);
            let kmm = kmm(p, jitter);
            let (bv, _) = crate::gp::assemble_bound(&st, &kmm, p.log_beta, dout).unwrap();
            bv.f
        };

        let mut scratch = ShardScratch::new();
        let st = shard_stats_into_fast(&p0, &xmu0, &xvar0, &y, &mask, klw, &mut scratch);
        let kmm0 = kmm(&p0, jitter);
        let (_, adj) = crate::gp::assemble_bound(&st, &kmm0, p0.log_beta, dout).unwrap();
        let (mut g, d_xmu, d_xvar) =
            shard_grads_vjp_cached_fast(&p0, &xmu0, &xvar0, &y, klw, &adj, &mut scratch);
        g.accumulate(&kmm_vjp(&p0, &adj.d_kmm));

        let eps = 1e-6;
        let check = |analytic: f64, fd: f64, what: &str| {
            assert!(
                (analytic - fd).abs() < 2e-5 * (1.0 + fd.abs()),
                "{what}: analytic {analytic} vs fd {fd}"
            );
        };
        for &(j, k) in &[(0, 0), (1, 1), (3, 0)] {
            let mut pp = p0.clone();
            pp.z[(j, k)] += eps;
            let mut pm = p0.clone();
            pm.z[(j, k)] -= eps;
            let fd = (f_of(&pp, &xmu0, &xvar0) - f_of(&pm, &xmu0, &xvar0)) / (2.0 * eps);
            check(g.d_z[(j, k)], fd, &format!("fast dZ[{j},{k}]"));
        }
        for k in 0..q {
            let mut pp = p0.clone();
            pp.log_ls[k] += eps;
            let mut pm = p0.clone();
            pm.log_ls[k] -= eps;
            let fd = (f_of(&pp, &xmu0, &xvar0) - f_of(&pm, &xmu0, &xvar0)) / (2.0 * eps);
            check(g.d_log_ls[k], fd, &format!("fast dlog_ls[{k}]"));
        }
        {
            let mut pp = p0.clone();
            pp.log_sf2 += eps;
            let mut pm = p0.clone();
            pm.log_sf2 -= eps;
            let fd = (f_of(&pp, &xmu0, &xvar0) - f_of(&pm, &xmu0, &xvar0)) / (2.0 * eps);
            check(g.d_log_sf2, fd, "fast dlog_sf2");
        }
        for &(i, k) in &[(0, 0), (2, 1), (5, 0)] {
            let mut xp = xmu0.clone();
            xp[(i, k)] += eps;
            let mut xm = xmu0.clone();
            xm[(i, k)] -= eps;
            let fd = (f_of(&p0, &xp, &xvar0) - f_of(&p0, &xm, &xvar0)) / (2.0 * eps);
            check(d_xmu[(i, k)], fd, &format!("fast dXmu[{i},{k}]"));

            let mut vp = xvar0.clone();
            vp[(i, k)] += eps;
            let mut vm = xvar0.clone();
            vm[(i, k)] -= eps;
            let fd = (f_of(&p0, &xmu0, &vp) - f_of(&p0, &xmu0, &vm)) / (2.0 * eps);
            check(d_xvar[(i, k)], fd, &format!("fast dXvar[{i},{k}]"));
        }
    }

    /// With the slab, one evaluation = exactly one psi pass; without it
    /// (or after a masked statistics round) the gradient round pays its
    /// own pass.
    #[test]
    fn scratch_counts_psi_passes() {
        let (m, q, dout, b) = (4, 2, 2, 6);
        let mut rng = Rng::new(51);
        let p = params(m, q, 50);
        let xmu = Matrix::from_fn(b, q, |_, _| rng.normal());
        let xvar = Matrix::from_fn(b, q, |_, _| 0.1 + rng.uniform());
        let y = Matrix::from_fn(b, dout, |_, _| rng.normal());
        let mask = vec![1.0; b];
        let adj = random_adjoints(&mut rng, m, dout);

        let mut scratch = ShardScratch::new();
        shard_stats_into(&p, &xmu, &xvar, &y, &mask, 1.0, &mut scratch);
        assert_eq!(scratch.psi_fills(), 1);
        assert!(scratch.psi2_slab_cached());
        shard_grads_vjp_cached(&p, &xmu, &xvar, &y, 1.0, &adj, &mut scratch);
        assert_eq!(scratch.psi_fills(), 1, "cached round 2 must not refill");
        // a second gradient round at the same fill is still a hit
        shard_grads_vjp_cached(&p, &xmu, &xvar, &y, 1.0, &adj, &mut scratch);
        assert_eq!(scratch.psi_fills(), 1);
        // invalidation forces a refill
        scratch.invalidate();
        shard_grads_vjp_cached(&p, &xmu, &xvar, &y, 1.0, &adj, &mut scratch);
        assert_eq!(scratch.psi_fills(), 2);

        // slab gated off: both rounds pay a pass
        let mut nocache = ShardScratch::with_slab_limit(0);
        shard_stats_into(&p, &xmu, &xvar, &y, &mask, 1.0, &mut nocache);
        shard_grads_vjp_cached(&p, &xmu, &xvar, &y, 1.0, &adj, &mut nocache);
        assert_eq!(nocache.psi_fills(), 2);

        // a masked statistics round must NOT be reused (stale slab rows)
        let mut masked = ShardScratch::new();
        let mut holes = mask.clone();
        holes[2] = 0.0;
        shard_stats_into(&p, &xmu, &xvar, &y, &holes, 1.0, &mut masked);
        assert!(!masked.is_filled_for(b, m, q));
        let (g, dmu, dvar) = shard_grads_vjp_cached(&p, &xmu, &xvar, &y, 1.0, &adj, &mut masked);
        assert_eq!(masked.psi_fills(), 2, "masked round 1 must trigger a refill");
        let (g_ref, dmu_ref, dvar_ref) = shard_grads_vjp(&p, &xmu, &xvar, &y, 1.0, &adj);
        assert_mat_bits_eq(&g.d_z, &g_ref.d_z, "dZ after masked fill");
        assert_mat_bits_eq(&dmu, &dmu_ref, "dXmu after masked fill");
        assert_mat_bits_eq(&dvar, &dvar_ref, "dXvar after masked fill");
    }

    /// The row-range split is a pure function of (rows, threads):
    /// contiguous, disjoint, covering, never more ranges than rows, and
    /// the first `rows % threads` ranges carry the extra row.
    #[test]
    fn fill_ranges_is_a_pure_even_split() {
        for rows in 0..20 {
            for threads in 1..8 {
                let r = fill_ranges(rows, threads);
                assert!(!r.is_empty());
                assert!(r.len() <= threads.max(1));
                assert_eq!(r[0].0, 0);
                assert_eq!(r.last().unwrap().1, rows);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
                }
                let lens: Vec<usize> = r.iter().map(|&(lo, hi)| hi - lo).collect();
                let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(mx - mn <= 1, "even split: {lens:?}");
                if rows > 0 {
                    assert!(*mn >= 1, "no empty ranges for rows={rows}: {lens:?}");
                }
            }
        }
        assert_eq!(fill_ranges(0, 4), vec![(0, 0)]);
        assert_eq!(fill_ranges(9, 4), vec![(0, 3), (3, 5), (5, 7), (7, 9)]);
    }

    /// The degenerate corners pin down exactly: more threads than rows
    /// collapses to one range per row (never an empty range), zero rows
    /// yields the single empty `(0, 0)` whatever the thread count,
    /// one thread (or the `threads == 0` guard) takes every row.
    #[test]
    fn fill_ranges_edge_cases() {
        // threads > rows: one range per row, no empties
        assert_eq!(fill_ranges(3, 8), vec![(0, 1), (1, 2), (2, 3)]);
        assert_eq!(fill_ranges(1, 64), vec![(0, 1)]);
        // zero rows: a single empty range, regardless of threads
        assert_eq!(fill_ranges(0, 1), vec![(0, 0)]);
        assert_eq!(fill_ranges(0, 7), vec![(0, 0)]);
        assert_eq!(fill_ranges(0, 0), vec![(0, 0)]);
        // one thread (and the threads == 0 guard): the whole row span
        assert_eq!(fill_ranges(9, 1), vec![(0, 9)]);
        assert_eq!(fill_ranges(5, 0), vec![(0, 5)]);
    }

    /// Threaded fills (strict) must be bit-identical to the scratch-free
    /// reference at every thread count, across cached, tiled-streaming
    /// and degenerate slab configurations — the determinism contract of
    /// DESIGN.md §11: scheduling never changes bytes.
    #[test]
    fn threaded_fill_matches_reference_bitwise() {
        let (m, q, dout, b) = (5, 3, 2, 9);
        let mm = m * m;
        let mut rng = Rng::new(91);
        let p = params(m, q, 90);
        let xmu = Matrix::from_fn(b, q, |_, _| rng.normal());
        let xvar = Matrix::from_fn(b, q, |_, _| 0.1 + rng.uniform());
        let y = Matrix::from_fn(b, dout, |_, _| rng.normal());
        let mask = vec![1.0; b];
        let adj = random_adjoints(&mut rng, m, dout);

        let st_ref = shard_stats(&p, &xmu, &xvar, &y, &mask, 1.0);
        let (g_ref, dmu_ref, dvar_ref) = shard_grads_vjp(&p, &xmu, &xvar, &y, 1.0, &adj);

        // threads x tile_rows interaction: every combination must land
        // on the same bytes (including threads > rows-per-tile)
        for limit in [usize::MAX, 4 * mm, 2 * mm + 3, mm, 0] {
            for threads in [1, 2, 4, 7] {
                let mut scratch = ShardScratch::with_slab_limit(limit);
                scratch.set_fill_threads(threads);
                let st = shard_stats_into(&p, &xmu, &xvar, &y, &mask, 1.0, &mut scratch);
                assert_eq!(st.a.to_bits(), st_ref.a.to_bits());
                assert_eq!(st.n.to_bits(), st_ref.n.to_bits());
                assert_eq!(st.kl.to_bits(), st_ref.kl.to_bits());
                assert_mat_bits_eq(&st.c, &st_ref.c, "C (threaded)");
                assert_mat_bits_eq(&st.d, &st_ref.d, "D (threaded)");
                let (g, dmu, dvar) =
                    shard_grads_vjp_cached(&p, &xmu, &xvar, &y, 1.0, &adj, &mut scratch);
                assert_mat_bits_eq(&g.d_z, &g_ref.d_z, "dZ (threaded)");
                assert_eq!(g.d_log_sf2.to_bits(), g_ref.d_log_sf2.to_bits());
                for (a, b) in g.d_log_ls.iter().zip(&g_ref.d_log_ls) {
                    assert_eq!(a.to_bits(), b.to_bits(), "dlog_ls (threaded)");
                }
                assert_mat_bits_eq(&dmu, &dmu_ref, "dXmu (threaded)");
                assert_mat_bits_eq(&dvar, &dvar_ref, "dXvar (threaded)");
            }
        }
    }

    /// Fast mode is equally deterministic under threading: any thread
    /// count reproduces the single-thread fast bytes (within the mode).
    #[test]
    fn fast_threaded_fill_matches_single_thread_bitwise() {
        let (m, q, dout, b) = (4, 2, 3, 11);
        let mm = m * m;
        let mut rng = Rng::new(95);
        let p = params(m, q, 94);
        let xmu = Matrix::from_fn(b, q, |_, _| rng.normal());
        let xvar = Matrix::from_fn(b, q, |_, _| 0.1 + rng.uniform());
        let y = Matrix::from_fn(b, dout, |_, _| rng.normal());
        let mask = vec![1.0; b];
        let adj = random_adjoints(&mut rng, m, dout);

        for limit in [usize::MAX, 3 * mm, 0] {
            let mut single = ShardScratch::with_slab_limit(limit);
            let st_ref = shard_stats_into_fast(&p, &xmu, &xvar, &y, &mask, 1.0, &mut single);
            let (g_ref, dmu_ref, dvar_ref) =
                shard_grads_vjp_cached_fast(&p, &xmu, &xvar, &y, 1.0, &adj, &mut single);
            for threads in [2, 4] {
                let mut scratch = ShardScratch::with_slab_limit(limit);
                scratch.set_fill_threads(threads);
                let st = shard_stats_into_fast(&p, &xmu, &xvar, &y, &mask, 1.0, &mut scratch);
                assert_eq!(st.a.to_bits(), st_ref.a.to_bits());
                assert_mat_bits_eq(&st.c, &st_ref.c, "fast C (threaded)");
                assert_mat_bits_eq(&st.d, &st_ref.d, "fast D (threaded)");
                let (g, dmu, dvar) =
                    shard_grads_vjp_cached_fast(&p, &xmu, &xvar, &y, 1.0, &adj, &mut scratch);
                assert_mat_bits_eq(&g.d_z, &g_ref.d_z, "fast dZ (threaded)");
                assert_mat_bits_eq(&dmu, &dmu_ref, "fast dXmu (threaded)");
                assert_mat_bits_eq(&dvar, &dvar_ref, "fast dXvar (threaded)");
            }
        }
    }

    /// The threaded Psi1 batch entry the Predictor serves through must
    /// be bit-identical to the sequential entry for any thread count.
    #[test]
    fn psi1_into_threaded_matches_sequential_bitwise() {
        let (m, q, b) = (6, 3, 10);
        let mut rng = Rng::new(99);
        let p = params(m, q, 98);
        let xmu = Matrix::from_fn(b, q, |_, _| rng.normal());
        let xvar = Matrix::from_fn(b, q, |_, _| 0.1 + rng.uniform());
        let ls2: Vec<f64> = p.log_ls.iter().map(|l| (2.0 * l).exp()).collect();
        let mut dn = vec![0.0; q];
        let mut seq = Matrix::zeros(b, m);
        psi1_into(&p, &xmu, &xvar, &ls2, p.sf2(), &mut dn, &mut seq);
        for threads in [1, 2, 3, 4, 16] {
            let mut thr = Matrix::zeros(b, m);
            psi1_into_threaded(&p, &xmu, &xvar, &ls2, p.sf2(), threads, &mut dn, &mut thr);
            assert_mat_bits_eq(&thr, &seq, "psi1_into_threaded");
        }
    }
}
