//! Exact O(n^3) GP regression — the correctness anchor the collapsed
//! bound is checked against (F <= exact log marginal likelihood, equality
//! at Z = X), and the `full_gp` baseline for small-n comparisons.

use anyhow::Result;

use crate::linalg::{Cholesky, Matrix};

use super::kernel::seard;
use super::params::GlobalParams;

/// log N(Y; 0, Knn + beta^-1 I) summed over output dimensions.
pub fn log_marginal(p: &GlobalParams, x: &Matrix, y: &Matrix) -> Result<f64> {
    let n = x.rows();
    let d = y.cols() as f64;
    let ky = seard(x, x, p).add_diag((-p.log_beta).exp());
    let chol = Cholesky::new_with_jitter(&ky, 1e-12, 8)?;
    let alpha = chol.solve(y);
    Ok(-0.5 * n as f64 * d * (2.0 * std::f64::consts::PI).ln()
        - 0.5 * d * chol.log_det()
        - 0.5 * y.dot(&alpha))
}

/// Exact GP posterior prediction at test inputs: (mean [t x d], var [t]).
pub fn predict(p: &GlobalParams, x: &Matrix, y: &Matrix, xt: &Matrix) -> Result<(Matrix, Vec<f64>)> {
    let ky = seard(x, x, p).add_diag((-p.log_beta).exp());
    let chol = Cholesky::new_with_jitter(&ky, 1e-12, 8)?;
    let kts = seard(xt, x, p); // t x n
    let mean = kts.matmul(&chol.solve(y));
    let sf2 = p.sf2();
    let v = chol.solve_lower(&kts.transpose()); // n x t
    let var = (0..xt.rows())
        .map(|t| {
            let mut s = 0.0;
            for i in 0..x.rows() {
                s += v[(i, t)] * v[(i, t)];
            }
            sf2 - s
        })
        .collect();
    Ok((mean, var))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup() -> (GlobalParams, Matrix, Matrix) {
        let mut rng = Rng::new(0);
        let n = 20;
        let x = Matrix::from_fn(n, 1, |i, _| i as f64 * 0.2 - 2.0);
        let y = Matrix::from_fn(n, 1, |i, _| x[(i, 0)].sin() + 0.05 * rng.normal());
        let p = GlobalParams {
            z: x.clone(),
            log_ls: vec![0.0],
            log_sf2: 0.0,
            log_beta: (400.0_f64).ln(),
        };
        (p, x, y)
    }

    #[test]
    fn interpolates_training_data() {
        let (p, x, y) = setup();
        let (mean, var) = predict(&p, &x, &y, &x).unwrap();
        let rmse = (0..x.rows())
            .map(|i| (mean[(i, 0)] - y[(i, 0)]).powi(2))
            .sum::<f64>()
            .sqrt()
            / (x.rows() as f64).sqrt();
        assert!(rmse < 0.08, "rmse={rmse}"); // ~noise level (std 0.05)
        for i in 0..x.rows() {
            assert!(var[i] >= -1e-9 && var[i] < 0.1);
        }
    }

    #[test]
    fn marginal_likelihood_prefers_true_noise() {
        let (mut p, x, y) = setup();
        let ll_true = log_marginal(&p, &x, &y).unwrap();
        p.log_beta = (1.0_f64).ln(); // far too noisy
        let ll_off = log_marginal(&p, &x, &y).unwrap();
        assert!(ll_true > ll_off);
    }

    #[test]
    fn variance_grows_away_from_data() {
        let (p, x, y) = setup();
        let near = Matrix::from_vec(1, 1, vec![0.0]);
        let far = Matrix::from_vec(1, 1, vec![10.0]);
        let (_, v_near) = predict(&p, &x, &y, &near).unwrap();
        let (_, v_far) = predict(&p, &x, &y, &far).unwrap();
        assert!(v_far[0] > v_near[0]);
        assert!((v_far[0] - p.sf2()).abs() < 1e-6); // reverts to prior
    }
}
