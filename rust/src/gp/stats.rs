//! The sufficient statistics of the re-parametrised bound (paper §3.1)
//! and their accumulation — the constant-size reduce messages.

use crate::linalg::Matrix;

/// Partial (or accumulated) statistics:
///
/// ```text
/// a    = sum_i |Y_i|^2          psi0 = sum_i <k(x_i, x_i)>
/// c    = Psi1^T Y  (m x d)      d    = Psi2 (m x m)
/// kl   = sum_i KL(q(X_i)||p)    n    = number of live points
/// ```
///
/// Statistics are additive over shards — the invariant the whole
/// Map-Reduce inference rests on (tested in `properties.rs`).
#[derive(Debug, Clone)]
pub struct Stats {
    pub a: f64,
    pub psi0: f64,
    pub c: Matrix,
    pub d: Matrix,
    pub kl: f64,
    pub n: f64,
}

impl Stats {
    pub fn zeros(m: usize, dout: usize) -> Stats {
        Stats {
            a: 0.0,
            psi0: 0.0,
            c: Matrix::zeros(m, dout),
            d: Matrix::zeros(m, m),
            kl: 0.0,
            n: 0.0,
        }
    }

    /// The reduce operation: element-wise sum.
    pub fn accumulate(&mut self, other: &Stats) {
        self.a += other.a;
        self.psi0 += other.psi0;
        self.c.axpy(1.0, &other.c);
        self.d.axpy(1.0, &other.d);
        self.kl += other.kl;
        self.n += other.n;
    }

    /// Size of the reduce message in scalars — constant in the data size
    /// (requirement 3 in the paper's introduction).
    pub fn message_scalars(&self) -> usize {
        3 + 1 + self.c.rows() * self.c.cols() + self.d.rows() * self.d.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_is_elementwise_sum() {
        let mut s = Stats::zeros(2, 3);
        let mut t = Stats::zeros(2, 3);
        t.a = 1.0;
        t.psi0 = 2.0;
        t.kl = 3.0;
        t.n = 4.0;
        t.c[(1, 2)] = 5.0;
        t.d[(0, 1)] = 6.0;
        s.accumulate(&t);
        s.accumulate(&t);
        assert_eq!(s.a, 2.0);
        assert_eq!(s.psi0, 4.0);
        assert_eq!(s.kl, 6.0);
        assert_eq!(s.n, 8.0);
        assert_eq!(s.c[(1, 2)], 10.0);
        assert_eq!(s.d[(0, 1)], 12.0);
    }

    #[test]
    fn message_size_independent_of_data() {
        let s = Stats::zeros(8, 3);
        assert_eq!(s.message_scalars(), 3 + 1 + 24 + 64);
    }
}
