//! The collapsed variational bound (eq. 3.3) and its hand-derived
//! adjoints — the central node's global step.
//!
//! Given the accumulated statistics (a, psi0, C, D, KL) and
//! Kmm = k(Z, Z) + jitter I, with beta = exp(log_beta) and
//! Sigma = Kmm + beta D:
//!
//! ```text
//! F = -nd/2 log 2pi + nd/2 log beta + d/2 log|Kmm| - d/2 log|Sigma|
//!     - beta/2 a - beta d/2 psi0 + beta d/2 tr(Kmm^-1 D)
//!     + beta^2/2 tr(C^T Sigma^-1 C) - KL
//! ```
//!
//! Adjoints (matrix calculus over the symmetric inputs; validated to
//! ~1e-9 against JAX autodiff via `artifacts/testvectors.json`):
//!
//! ```text
//! dF/dC    = beta^2 P                     with P = Sigma^-1 C,  Q = P P^T
//! dF/dD    = (beta d/2)(Kmm^-1 - Sigma^-1) - (beta^3/2) Q
//! dF/dpsi0 = -beta d / 2
//! dF/dKL   = -1
//! dF/dKmm  = d/2 Kmm^-1 - d/2 Sigma^-1
//!            - beta d/2 Kmm^-1 D Kmm^-1 - beta^2/2 Q
//! dF/dlogbeta = beta * [ nd/(2 beta) - a/2 - d psi0/2
//!               + d/2 tr(Kmm^-1 D) - d/2 tr(Sigma^-1 D)
//!               + beta tr(C^T P) - beta^2/2 tr(P^T D P) ]
//! ```
//!
//! These are the constant-size (m x m, m x d) messages broadcast to the
//! workers in map step 2 (paper §3.2 step 3).

use anyhow::Result;

use crate::linalg::{Cholesky, Matrix};

/// Value of the bound plus the intermediates worth keeping.
#[derive(Debug, Clone)]
pub struct BoundValue {
    /// The collapsed lower bound F (log marginal likelihood bound).
    pub f: f64,
    /// log|Kmm| and log|Sigma| (diagnostics).
    pub log_det_kmm: f64,
    pub log_det_sigma: f64,
}

/// The adjoint message of map step 2.
#[derive(Debug, Clone)]
pub struct Adjoints {
    pub d_psi0: f64,
    pub d_c: Matrix,
    pub d_d: Matrix,
    pub d_kl: f64,
    pub d_kmm: Matrix,
    pub d_log_beta: f64,
}

/// Weight matrices for prediction, derived from the same factorisation:
/// `w1 = beta Sigma^-1 C` (mean weights) and `wv = Kmm^-1 - Sigma^-1`
/// (variance weights). The optimal q(u) is
/// mu_u = Kmm w1, S_u = Kmm Sigma^-1 Kmm.
#[derive(Debug, Clone)]
pub struct PosteriorWeights {
    pub w1: Matrix,
    pub wv: Matrix,
    pub qu_mean: Matrix,
    pub qu_cov: Matrix,
}

/// Assemble F and the adjoints from accumulated statistics.
///
/// `n` is the number of (live) data points and `dout` the output
/// dimensionality d. O(m^3) throughout — constant in the dataset size.
pub fn assemble_bound(
    stats: &crate::gp::Stats,
    kmm: &Matrix,
    log_beta: f64,
    dout: usize,
) -> Result<(BoundValue, Adjoints)> {
    let beta = log_beta.exp();
    let d = dout as f64;
    let n = stats.n;
    let m = kmm.rows();

    // Treat the bound as an explicitly symmetric function of D and Kmm
    // (both are symmetric by construction; symmetrizing makes the adjoint
    // convention match the JAX oracle exactly — see testvectors.rs).
    let d_sym = stats.d.symmetrize();
    let kmm = &kmm.symmetrize();
    let sigma = {
        let mut s = d_sym.scale(beta);
        s.axpy(1.0, kmm);
        s
    };
    let chol_k = Cholesky::new_with_jitter(kmm, 1e-10, 8)?;
    let chol_s = Cholesky::new_with_jitter(&sigma, 1e-10, 8)?;

    let kinv = chol_k.inverse();
    let sinv = chol_s.inverse();
    let p = chol_s.solve(&stats.c); // Sigma^-1 C, m x d
    let kinv_d = chol_k.solve(&d_sym); // Kmm^-1 D

    let log_det_kmm = chol_k.log_det();
    let log_det_sigma = chol_s.log_det();
    let tr_kinv_d = kinv_d.trace();
    let tr_ctp = stats.c.dot(&p); // tr(C^T Sigma^-1 C)

    let f = -0.5 * n * d * (2.0 * std::f64::consts::PI).ln()
        + 0.5 * n * d * log_beta
        + 0.5 * d * log_det_kmm
        - 0.5 * d * log_det_sigma
        - 0.5 * beta * stats.a
        - 0.5 * beta * d * stats.psi0
        + 0.5 * beta * d * tr_kinv_d
        + 0.5 * beta * beta * tr_ctp
        - stats.kl;

    // ---- adjoints --------------------------------------------------------
    let q_mat = p.matmul_t(&p); // Q = P P^T, m x m

    let d_c = p.scale(beta * beta);

    let mut d_d = kinv.sub(&sinv).scale(0.5 * beta * d);
    d_d.axpy(-0.5 * beta * beta * beta, &q_mat);

    // Kmm^-1 D Kmm^-1 = (Kmm^-1 D) Kmm^-1; symmetrize against roundoff.
    let kinv_d_kinv = kinv_d.matmul(&kinv).symmetrize();
    let mut d_kmm = kinv.sub(&sinv).scale(0.5 * d);
    d_kmm.axpy(-0.5 * beta * d, &kinv_d_kinv);
    d_kmm.axpy(-0.5 * beta * beta, &q_mat);

    let tr_sinv_d = sinv.dot(&d_sym); // tr(Sigma^-1 D), both symmetric
    let pt_d_p = {
        // tr(P^T D P)
        let dp = d_sym.matmul(&p);
        p.dot(&dp)
    };
    let df_dbeta = 0.5 * n * d / beta
        - 0.5 * stats.a
        - 0.5 * d * stats.psi0
        + 0.5 * d * tr_kinv_d
        - 0.5 * d * tr_sinv_d
        + beta * tr_ctp
        - 0.5 * beta * beta * pt_d_p;
    let d_log_beta = beta * df_dbeta;

    debug_assert_eq!(d_kmm.rows(), m);
    Ok((
        BoundValue {
            f,
            log_det_kmm,
            log_det_sigma,
        },
        Adjoints {
            d_psi0: -0.5 * beta * d,
            d_c,
            d_d,
            d_kl: -1.0,
            d_kmm,
            d_log_beta,
        },
    ))
}

/// Posterior weights / optimal q(u) from accumulated statistics.
pub fn posterior_weights(
    stats: &crate::gp::Stats,
    kmm: &Matrix,
    log_beta: f64,
) -> Result<PosteriorWeights> {
    let beta = log_beta.exp();
    let kmm = &kmm.symmetrize();
    let sigma = {
        let mut s = stats.d.symmetrize().scale(beta);
        s.axpy(1.0, kmm);
        s
    };
    let chol_k = Cholesky::new_with_jitter(kmm, 1e-10, 8)?;
    let chol_s = Cholesky::new_with_jitter(&sigma, 1e-10, 8)?;
    let w1 = chol_s.solve(&stats.c).scale(beta);
    let wv = chol_k.inverse().sub(&chol_s.inverse()).symmetrize();
    let qu_mean = kmm.matmul(&w1); // beta Kmm Sigma^-1 C
    let qu_cov = kmm.matmul(&chol_s.solve(kmm)).symmetrize();
    Ok(PosteriorWeights {
        w1,
        wv,
        qu_mean,
        qu_cov,
    })
}

/// Native prediction mirror (tests + baselines): mean = Psi1* W1,
/// var_i = sf2 - tr(Wv Psi2*_i). The artifact `predict_{cfg}` computes
/// the same quantities on the PJRT path.
pub fn predict_native(
    params: &crate::gp::GlobalParams,
    weights: &PosteriorWeights,
    xt_mu: &Matrix,
    xt_var: &Matrix,
) -> (Matrix, Vec<f64>) {
    let p1 = crate::gp::kernel::psi1(params, xt_mu, xt_var);
    let mean = p1.matmul(&weights.w1);
    let sf2 = params.sf2();
    let var = (0..xt_mu.rows())
        .map(|i| {
            let p2 = crate::gp::kernel::psi2_point(params, xt_mu.row(i), xt_var.row(i));
            sf2 - weights.wv.dot(&p2)
        })
        .collect();
    (mean, var)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::kernel;
    use crate::gp::{GlobalParams, Stats};
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (GlobalParams, Stats, Matrix, usize) {
        let mut rng = Rng::new(seed);
        let (m, q, dout, n) = (5, 2, 3, 30);
        let p = GlobalParams {
            z: Matrix::from_fn(m, q, |_, _| rng.normal()),
            log_ls: (0..q).map(|_| 0.2 * rng.normal()).collect(),
            log_sf2: 0.1,
            log_beta: 1.0,
        };
        let xmu = Matrix::from_fn(n, q, |_, _| rng.normal());
        let xvar = Matrix::from_fn(n, q, |_, _| 0.05 + rng.uniform());
        let y = Matrix::from_fn(n, dout, |_, _| rng.normal());
        let stats = kernel::shard_stats(&p, &xmu, &xvar, &y, &vec![1.0; n], 1.0);
        let kmm = kernel::kmm(&p, 1e-6);
        (p, stats, kmm, dout)
    }

    #[test]
    fn bound_is_finite_and_negative_for_random_data() {
        let (p, stats, kmm, dout) = setup(0);
        let (bv, _) = assemble_bound(&stats, &kmm, p.log_beta, dout).unwrap();
        assert!(bv.f.is_finite());
        assert!(bv.f < 0.0); // random targets: bound far below 0
    }

    #[test]
    fn adjoint_d_matches_finite_difference() {
        let (p, stats, kmm, dout) = setup(1);
        let (_, adj) = assemble_bound(&stats, &kmm, p.log_beta, dout).unwrap();
        let eps = 1e-6;
        // perturb D[1, 2] and D[2, 1] symmetrically? No: the adjoint is the
        // free-matrix gradient, so perturb a single entry.
        for &(i, j) in &[(0, 0), (1, 2), (3, 1)] {
            let mut sp = stats.clone();
            sp.d[(i, j)] += eps;
            let (fp, _) = assemble_bound(&sp, &kmm, p.log_beta, dout).unwrap();
            let mut sm = stats.clone();
            sm.d[(i, j)] -= eps;
            let (fm, _) = assemble_bound(&sm, &kmm, p.log_beta, dout).unwrap();
            let fd = (fp.f - fm.f) / (2.0 * eps);
            assert!(
                (adj.d_d[(i, j)] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                "dD[{i},{j}]: adjoint {} vs fd {}",
                adj.d_d[(i, j)],
                fd
            );
        }
    }

    #[test]
    fn adjoint_c_psi0_kl_match_finite_difference() {
        let (p, stats, kmm, dout) = setup(2);
        let (_, adj) = assemble_bound(&stats, &kmm, p.log_beta, dout).unwrap();
        let eps = 1e-6;
        let fd_of = |f: &dyn Fn(&mut Stats, f64)| {
            let mut sp = stats.clone();
            f(&mut sp, eps);
            let (fp, _) = assemble_bound(&sp, &kmm, p.log_beta, dout).unwrap();
            let mut sm = stats.clone();
            f(&mut sm, -eps);
            let (fm, _) = assemble_bound(&sm, &kmm, p.log_beta, dout).unwrap();
            (fp.f - fm.f) / (2.0 * eps)
        };
        let fd_c = fd_of(&|s, e| s.c[(2, 1)] += e);
        assert!((adj.d_c[(2, 1)] - fd_c).abs() < 1e-5 * (1.0 + fd_c.abs()));
        let fd_p0 = fd_of(&|s, e| s.psi0 += e);
        assert!((adj.d_psi0 - fd_p0).abs() < 1e-5 * (1.0 + fd_p0.abs()));
        let fd_kl = fd_of(&|s, e| s.kl += e);
        assert!((adj.d_kl - fd_kl).abs() < 1e-7);
    }

    #[test]
    fn adjoint_kmm_and_beta_match_finite_difference() {
        let (p, stats, kmm, dout) = setup(3);
        let (_, adj) = assemble_bound(&stats, &kmm, p.log_beta, dout).unwrap();
        let eps = 1e-6;
        for &(i, j) in &[(0, 0), (1, 3)] {
            let mut kp = kmm.clone();
            kp[(i, j)] += eps;
            let (fp, _) = assemble_bound(&stats, &kp, p.log_beta, dout).unwrap();
            let mut km = kmm.clone();
            km[(i, j)] -= eps;
            let (fm, _) = assemble_bound(&stats, &km, p.log_beta, dout).unwrap();
            let fd = (fp.f - fm.f) / (2.0 * eps);
            assert!(
                (adj.d_kmm[(i, j)] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "dKmm[{i},{j}]: {} vs {}",
                adj.d_kmm[(i, j)],
                fd
            );
        }
        let (fp, _) = assemble_bound(&stats, &kmm, p.log_beta + eps, dout).unwrap();
        let (fm, _) = assemble_bound(&stats, &kmm, p.log_beta - eps, dout).unwrap();
        let fd = (fp.f - fm.f) / (2.0 * eps);
        assert!((adj.d_log_beta - fd).abs() < 1e-5 * (1.0 + fd.abs()));
    }

    #[test]
    fn posterior_cov_is_spd() {
        let (p, stats, kmm, _) = setup(4);
        let w = posterior_weights(&stats, &kmm, p.log_beta).unwrap();
        assert!(Cholesky::new(&w.qu_cov.add_diag(1e-12)).is_ok());
    }

    #[test]
    fn predict_recovers_targets_with_low_noise() {
        // regression sanity: fit at the training inputs with Z = X subset
        let mut rng = Rng::new(9);
        let n = 25;
        let x = Matrix::from_fn(n, 1, |i, _| i as f64 / n as f64 * 4.0 - 2.0);
        let y = Matrix::from_fn(n, 1, |i, _| (x[(i, 0)] * 2.0).sin() + 0.01 * rng.normal());
        let p = GlobalParams {
            z: Matrix::from_fn(12, 1, |i, _| i as f64 / 12.0 * 4.0 - 2.0),
            log_ls: vec![(0.6_f64).ln()],
            log_sf2: 0.0,
            log_beta: (1.0 / (0.05_f64 * 0.05)).ln(),
        };
        let xvar = Matrix::zeros(n, 1);
        let stats = kernel::shard_stats(&p, &x, &xvar, &y, &vec![1.0; n], 0.0);
        let kmm = kernel::kmm(&p, 1e-8);
        let w = posterior_weights(&stats, &kmm, p.log_beta).unwrap();
        let (mean, var) = predict_native(&p, &w, &x, &xvar);
        let rmse = (0..n)
            .map(|i| (mean[(i, 0)] - y[(i, 0)]).powi(2))
            .sum::<f64>()
            .sqrt()
            / (n as f64).sqrt();
        assert!(rmse < 0.1, "rmse={rmse}");
        assert!(var.iter().all(|v| *v > -1e-9 && *v < 1.0));
    }
}
