//! Worker-node state and the worker daemon loop.
//!
//! [`WorkerNode`] is the single implementation of the per-node state
//! machine (executor + data shard + local optimiser state) shared by
//! *both* backends: the in-process [`PoolBackend`](super::PoolBackend)
//! runs one `WorkerNode` per OS thread, the TCP daemon runs one per
//! process. Keeping the request handler identical is what makes the
//! two backends bit-for-bit interchangeable.
//!
//! The daemon (`gparml worker --connect LEADER` or `--listen ADDR`)
//! speaks the `wire` protocol: handshake (`Hello`/`HelloAck`), one
//! `Init` frame carrying shapes + shard, then a request/response loop
//! until `Shutdown` or leader disconnect.

use std::net::{TcpListener, TcpStream};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::gp::MathMode;
use crate::linalg::Matrix;
use crate::obs;
use crate::optim::Adam;
use crate::runtime::{build_executor_threads, ArtifactConfig, ShardData, ShardExecutor};
use crate::store;
use crate::util::timer::thread_cpu_secs;

use super::wire::{self, Frame, Init, Request, Response};

/// Per-node state: compiled executor (stateful: it owns the per-shard
/// psi scratch), the data shard, and local optimiser state for the
/// LVM's q(X) parameters.
pub struct WorkerNode {
    exec: ShardExecutor,
    shard: ShardData,
    adam_mu: Adam,
    adam_ls: Adam, // over log s
    local_lr: f64,
    min_xvar: f64,
    lvm: bool,
    /// reuse psi intermediates across the two rounds of one evaluation
    /// (keyed by the requests' parameter version); false = recompute
    /// fresh every round
    psi_cache: bool,
}

impl WorkerNode {
    /// Build the node from an `Init` message. Native builds need only
    /// the shapes; PJRT builds compile the artifacts from
    /// `artifacts_dir`. The executor is built under the cluster-wide
    /// `Init.math_mode`; fast mode without the psi cache is rejected
    /// (the forced-fresh path exists to pin the strict reference trace,
    /// so it has no fast variant — DESIGN.md §8). The cluster-wide
    /// `Init.fill_threads` (v7) selects the intra-worker psi-fill
    /// parallelism; 0 is rejected (the wire decoder already refuses it,
    /// this guards in-process construction too). An `Init.shard_ref`
    /// (v9) makes the node load and checksum-verify its shard from the
    /// on-disk dataset store instead of taking rows off the wire; any
    /// mismatch is a bring-up error the leader surfaces loudly.
    pub fn build(init: &Init, artifacts_dir: &Path) -> Result<WorkerNode> {
        ensure!(
            init.psi_cache || init.math_mode == MathMode::Strict,
            "math mode {} requires the psi cache (psi_cache=false selects the strict \
             forced-fresh reference)",
            init.math_mode
        );
        ensure!(
            init.fill_threads >= 1,
            "fill_threads must be >= 1 (got {})",
            init.fill_threads
        );
        let exec = build_executor_threads(
            &init.artifact,
            artifacts_dir,
            init.math_mode,
            init.fill_threads as usize,
        )?;
        let shard = match &init.shard_ref {
            None => init.shard.clone(),
            Some(r) => {
                ensure!(
                    init.shard.len() == 0,
                    "Init carries both wire shard rows and a shard_ref; the leader must \
                     pick one bring-up path"
                );
                ensure!(
                    !init.lvm,
                    "shard_ref bring-up is regression-only: LVM latent initialisation is \
                     leader-derived and must ship over the wire"
                );
                Self::load_shard_ref_into(r, &init.artifact)?
            }
        };
        let dof = shard.xmu.rows() * shard.xmu.cols();
        Ok(WorkerNode {
            exec,
            shard,
            adam_mu: Adam::new(dof, init.local_lr),
            adam_ls: Adam::new(dof, init.local_lr),
            local_lr: init.local_lr,
            min_xvar: init.min_xvar,
            lvm: init.lvm,
            psi_cache: init.psi_cache,
        })
    }

    /// Worker-local shard load (wire v9, DESIGN.md §13): read the
    /// referenced store shard file, verify its checksum against the
    /// leader-sent manifest record, and split its columns into the
    /// regression `ShardData` (first `x_cols` columns are `Xmu` with a
    /// delta q(X), the rest are `Y`). Every disagreement — checksum,
    /// row count, column split — is a named bring-up error.
    fn load_shard_ref_into(r: &wire::ShardRef, art: &ArtifactConfig) -> Result<ShardData> {
        let q = r.x_cols as usize;
        ensure!(
            q == art.q,
            "shard_ref has {} input columns but the artifact's latent dimensionality is {}",
            q,
            art.q
        );
        let (m, sum) = store::codec::read_shard(Path::new(&r.path))
            .with_context(|| format!("worker-local shard load from {}", r.path))?;
        ensure!(
            sum == r.checksum,
            "shard_ref checksum mismatch: leader expects {:#018x}, {} holds {:#018x} — \
             refusing bring-up",
            r.checksum,
            r.path,
            sum
        );
        ensure!(
            m.rows() == r.rows as usize,
            "shard_ref row count mismatch: leader expects {} rows, {} holds {}",
            r.rows,
            r.path,
            m.rows()
        );
        ensure!(
            m.cols() == q + art.d,
            "shard_ref column mismatch: {} has {} columns but the artifact implies \
             q + d = {}",
            r.path,
            m.cols(),
            q + art.d
        );
        let xmu = Matrix::from_fn(m.rows(), q, |i, j| m[(i, j)]);
        let y = Matrix::from_fn(m.rows(), art.d, |i, j| m[(i, q + j)]);
        Ok(ShardData {
            xmu,
            xvar: Matrix::zeros(m.rows(), q),
            y,
            kl_weight: r.kl_weight,
        })
    }

    /// Apply one local ascent step on (mu, log s) from raw-space grads
    /// (paper step 4: "at the same time the end-point nodes optimise
    /// L_k").
    fn local_update(&mut self, d_xmu: &Matrix, d_xvar: &Matrix) {
        if !self.lvm || self.shard.len() == 0 {
            return;
        }
        // minimise -F: negate the ascent gradients
        let g_mu: Vec<f64> = d_xmu.data().iter().map(|g| -g).collect();
        // chain rule d/dlog s = s * d/ds
        let g_ls: Vec<f64> = d_xvar
            .data()
            .iter()
            .zip(self.shard.xvar.data())
            .map(|(g, s)| -g * s)
            .collect();
        self.adam_mu.step(self.shard.xmu.data_mut(), &g_mu);
        let mut log_s: Vec<f64> = self
            .shard
            .xvar
            .data()
            .iter()
            .map(|s| s.max(self.min_xvar).ln())
            .collect();
        self.adam_ls.step(&mut log_s, &g_ls);
        for (s, l) in self.shard.xvar.data_mut().iter_mut().zip(&log_s) {
            *s = l.exp().max(self.min_xvar);
        }
    }

    /// Execute one request. Errors are folded into [`Response::Err`] so
    /// the node never dies on a bad request — the leader decides.
    pub fn handle(&mut self, req: &Request) -> Response {
        self.handle_counted(req).0
    }

    /// Execute one request, also reporting how many full psi
    /// recomputations it triggered (0 on a cache-hit gradient round) —
    /// the per-round telemetry both backends ship back to the leader.
    pub fn handle_counted(&mut self, req: &Request) -> (Response, u32) {
        let before = self.exec.psi_fills();
        let resp = match self.dispatch(req) {
            Ok(resp) => resp,
            Err(e) => Response::Err(format!("{e:#}")),
        };
        let fills = (self.exec.psi_fills() - before) as u32;
        (resp, fills)
    }

    fn dispatch(&mut self, req: &Request) -> Result<Response> {
        Ok(match req {
            Request::Stats { params, version } => {
                let st = if self.psi_cache {
                    let tok = self.exec.begin_eval(*version);
                    self.exec.shard_stats_cached(&tok, params, &self.shard)?
                } else {
                    self.exec.shard_stats(params, &self.shard)?
                };
                Response::Stats(st)
            }
            Request::Grads {
                params,
                adj,
                update_locals,
                version,
            } => {
                let (g, local) = if self.psi_cache {
                    let tok = self.exec.begin_eval(*version);
                    self.exec.shard_grads_cached(&tok, params, &self.shard, adj)?
                } else {
                    self.exec.shard_grads(params, &self.shard, adj)?
                };
                if *update_locals {
                    self.local_update(&local.d_xmu, &local.d_xvar);
                    // the local parameters moved under the scratch
                    self.exec.invalidate_cache();
                }
                Response::Grads(g)
            }
            Request::FetchShard { clear } => {
                let s = self.shard.clone();
                if *clear {
                    self.shard = ShardData {
                        xmu: Matrix::zeros(0, s.xmu.cols()),
                        xvar: Matrix::zeros(0, s.xvar.cols()),
                        y: Matrix::zeros(0, s.y.cols()),
                        kl_weight: s.kl_weight,
                    };
                    self.exec.invalidate_cache();
                }
                Response::Shard(s)
            }
            Request::AppendShard { part } => {
                self.shard.xmu = self.shard.xmu.vstack(&part.xmu);
                self.shard.xvar = self.shard.xvar.vstack(&part.xvar);
                self.shard.y = self.shard.y.vstack(&part.y);
                // optimiser state is shape-bound: rebuild (documented
                // trade-off of the reassign strategy); the psi scratch
                // is stale for the grown shard too
                let dof = self.shard.xmu.rows() * self.shard.xmu.cols();
                self.adam_mu = Adam::new(dof, self.local_lr);
                self.adam_ls = Adam::new(dof, self.local_lr);
                self.exec.invalidate_cache();
                Response::Ok
            }
            Request::GatherLocals => Response::Locals {
                xmu: self.shard.xmu.clone(),
                xvar: self.shard.xvar.clone(),
            },
            Request::Predict {
                params,
                xt_mu,
                xt_var,
                w1,
                wv,
            } => {
                let (mean, var) = self.exec.predict(params, xt_mu, xt_var, w1, wv)?;
                Response::Predict { mean, var }
            }
            Request::ModelInfo => {
                let cfg = self.exec.config();
                Response::ModelInfo {
                    m: cfg.m as u32,
                    q: cfg.q as u32,
                    d: cfg.d as u32,
                    // version 0 = "not a serve model": workers hold
                    // executor shapes, not a reloadable artifact
                    version: 0,
                }
            }
            Request::ServePredict { .. } | Request::ServeProject { .. } => bail!(
                "ServePredict/ServeProject are answered by the `gparml serve` predict \
                 server, which holds a TrainedModel; cluster workers hold no posterior \
                 weights"
            ),
            Request::Reload => bail!(
                "Reload is a `gparml serve` control frame; cluster workers hold no \
                 model artifact to reload"
            ),
            Request::ServeStats => bail!(
                "ServeStats is answered inline by the worker daemon / predict server, \
                 not by the node state machine"
            ),
            Request::Register { .. }
            | Request::Deregister { .. }
            | Request::ReplicaHeartbeat { .. }
            | Request::FleetInfo => bail!(
                "fleet control frames (Register/Deregister/ReplicaHeartbeat/FleetInfo) \
                 are answered by the `gparml control` plane, not by cluster workers"
            ),
        })
    }
}

// ---------------------------------------------------------------------------
// worker daemon
// ---------------------------------------------------------------------------

/// Serve one leader over an established connection until `Shutdown` or
/// disconnect. Returns the number of requests served.
///
/// `pinned_mode` pins this worker to one [`MathMode`]
/// (`gparml worker --math-mode ...`): an `Init` frame carrying the
/// other mode is answered with an error and the daemon exits, so a
/// mixed-mode cluster fails loudly at bring-up on the leader
/// (`None` accepts whatever mode the leader negotiates).
///
/// `pinned_fill_threads` is the same bring-up guard for the v7
/// intra-worker psi-fill parallelism (`gparml worker --fill-threads N`):
/// an `Init` negotiating a different thread count is rejected. Unlike
/// `math_mode` a mismatch would still be bit-identical (DESIGN.md §11)
/// — the pin exists so a capacity plan ("this box runs 4 fill threads")
/// cannot be silently overridden by a leader config.
///
/// `heartbeat_ms` (`gparml worker --heartbeat-ms`) is the worker-side
/// leader-liveness expectation: when set, an idle stretch of that many
/// milliseconds without any frame from the leader (heartbeats are
/// leader-initiated `Ping`s) bumps the `heartbeat_overdue` counter in
/// the worker's metrics registry and emits a trace event, instead of
/// blocking silently. `None` (the default) keeps the blocking read.
pub fn serve_connection(
    mut stream: TcpStream,
    artifacts_dir: &Path,
    pinned_mode: Option<MathMode>,
    pinned_fill_threads: Option<u32>,
    heartbeat_ms: Option<u64>,
) -> Result<u64> {
    stream.set_nodelay(true).ok();

    // handshake: leader assigns our worker id
    let worker_id = match wire::read_frame(&mut stream)? {
        Some((Frame::Hello { worker_id }, _)) => worker_id,
        Some((f, _)) => bail!("expected Hello, got {f:?}"),
        None => bail!("leader disconnected before Hello"),
    };
    wire::write_frame(&mut stream, &Frame::HelloAck)?;

    // initialisation: shapes, model flags, math mode and our shard
    let built = match wire::read_frame(&mut stream)? {
        Some((Frame::Init(init), _)) => check_pinned_mode(pinned_mode, init.math_mode)
            .and_then(|()| check_pinned_fill_threads(pinned_fill_threads, init.fill_threads))
            .and_then(|()| WorkerNode::build(&init, artifacts_dir))
            .with_context(|| format!("worker {worker_id}: building node state")),
        Some((f, _)) => bail!("expected Init, got {f:?}"),
        None => bail!("leader disconnected before Init"),
    };
    let mut node = match built {
        Ok(node) => node,
        Err(e) => {
            // tell the leader why before dying, instead of letting its
            // handshake read run into the timeout
            let _ = wire::write_frame(
                &mut stream,
                &Frame::Response {
                    trace_id: 0,
                    secs: 0.0,
                    psi_fills: 0,
                    resp: Box::new(Response::Err(format!("{e:#}"))),
                },
            );
            return Err(e);
        }
    };
    wire::write_frame(
        &mut stream,
        &Frame::Response {
            trace_id: 0,
            secs: 0.0,
            psi_fills: 0,
            resp: Box::new(Response::Ok),
        },
    )?;
    eprintln!(
        "[gparml-worker {worker_id}] initialised: shard of {} points",
        node.shard.len()
    );

    // per-process live metrics, answered inline over `ServeStats`
    let reg = obs::Registry::new();
    let requests_ctr = reg.counter("requests");
    let pings_ctr = reg.counter("pings");
    let psi_fills_ctr = reg.counter("psi_fills");
    let cache_hits_ctr = reg.counter("psi_cache_hits");
    let overdue_ctr = reg.counter("heartbeat_overdue");
    let request_hist = reg.histogram("request_cpu_ns");
    if let Some(ms) = heartbeat_ms {
        stream
            .set_read_timeout(Some(std::time::Duration::from_millis(ms.max(1))))
            .context("setting worker heartbeat window")?;
    }

    let mut served = 0u64;
    loop {
        match read_frame_idle(&mut stream, &overdue_ctr, worker_id)? {
            None => return Ok(served), // leader gone: exit quietly
            Some((Frame::Ping, _)) => {
                pings_ctr.inc();
                wire::write_frame(&mut stream, &Frame::Pong)?;
            }
            Some((Frame::Shutdown, _)) => {
                eprintln!("[gparml-worker {worker_id}] shutdown after {served} requests");
                return Ok(served);
            }
            Some((Frame::Request { trace_id, req }, _)) => {
                requests_ctr.inc();
                if matches!(*req, Request::ServeStats) {
                    // answered inline, like ModelInfo on the serve path
                    wire::write_frame(
                        &mut stream,
                        &Frame::Response {
                            trace_id,
                            secs: 0.0,
                            psi_fills: 0,
                            resp: Box::new(Response::StatsJson(
                                reg.snapshot_json().to_string(),
                            )),
                        },
                    )?;
                    served += 1;
                    continue;
                }
                let c0 = thread_cpu_secs();
                let (resp, psi_fills) = {
                    let mut span = obs::trace::span("worker_request", trace_id);
                    let out = node.handle_counted(&req);
                    span.set_count(out.1 as u64);
                    out
                };
                let secs = thread_cpu_secs() - c0;
                request_hist.record((secs * 1e9) as u64);
                // the psi fill / cache-hit signal, tagged with the
                // evaluation's trace id (map rounds only)
                if matches!(*req, Request::Stats { .. } | Request::Grads { .. }) {
                    if psi_fills > 0 {
                        psi_fills_ctr.add(psi_fills as u64);
                        obs::trace::event("psi_fill", trace_id, psi_fills as u64);
                    } else {
                        cache_hits_ctr.inc();
                        obs::trace::event("psi_cache_hit", trace_id, 0);
                    }
                }
                wire::write_frame(
                    &mut stream,
                    &Frame::Response {
                        trace_id,
                        secs,
                        psi_fills,
                        resp: Box::new(resp),
                    },
                )?;
                served += 1;
            }
            Some((f, _)) => bail!("unexpected frame {f:?}"),
        }
    }
}

/// Read one frame, tolerating read-timeout "idle ticks": when the
/// worker runs with `--heartbeat-ms` the stream has a read timeout,
/// and an idle window without any leader frame records an overdue
/// heartbeat instead of erroring. EOF at a frame boundary is a clean
/// `None`, exactly like [`wire::read_frame`].
fn read_frame_idle(
    stream: &mut TcpStream,
    overdue: &obs::Counter,
    worker_id: u32,
) -> Result<Option<(Frame, u64)>> {
    use std::io::Read as _;
    let mut first = [0u8; 1];
    loop {
        match stream.read(&mut first) {
            Ok(0) => return Ok(None),
            Ok(_) => {
                let mut chained = (&first[..]).chain(&mut *stream);
                return wire::read_frame(&mut chained);
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                overdue.inc();
                obs::trace::event("worker_heartbeat_overdue", 0, worker_id as u64);
            }
            Err(e) => return Err(e).context("reading frame header"),
        }
    }
}

/// Mixed-mode bring-up guard: a worker pinned to one math mode refuses
/// an `Init` negotiated for the other.
fn check_pinned_mode(pinned: Option<MathMode>, negotiated: MathMode) -> Result<()> {
    if let Some(pin) = pinned {
        ensure!(
            pin == negotiated,
            "worker is pinned to math mode {pin} but the leader negotiated {negotiated}; \
             mixed-mode clusters are rejected at bring-up"
        );
    }
    Ok(())
}

/// Bring-up guard for the v7 fill-thread negotiation: a worker pinned
/// to a thread count refuses an `Init` carrying a different one.
fn check_pinned_fill_threads(pinned: Option<u32>, negotiated: u32) -> Result<()> {
    if let Some(pin) = pinned {
        ensure!(
            pin == negotiated,
            "worker is pinned to {pin} fill threads but the leader negotiated {negotiated}; \
             mismatched fill-thread clusters are rejected at bring-up"
        );
    }
    Ok(())
}

/// Dial a listening leader and serve it (the `worker --connect` mode
/// used by spawned cluster processes).
pub fn run_worker_connect(
    addr: &str,
    artifacts_dir: &Path,
    pinned_mode: Option<MathMode>,
    pinned_fill_threads: Option<u32>,
    heartbeat_ms: Option<u64>,
) -> Result<u64> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to leader at {addr}"))?;
    serve_connection(stream, artifacts_dir, pinned_mode, pinned_fill_threads, heartbeat_ms)
}

/// Bind `addr`, print the bound address, and serve the first leader
/// that dials in (the `worker --listen` mode).
pub fn run_worker_listen(
    addr: &str,
    artifacts_dir: &Path,
    pinned_mode: Option<MathMode>,
    pinned_fill_threads: Option<u32>,
    heartbeat_ms: Option<u64>,
) -> Result<u64> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    println!("gparml worker listening on {local}");
    let (stream, peer) = listener.accept().context("accepting leader")?;
    eprintln!("[gparml-worker] leader connected from {peer}");
    serve_connection(stream, artifacts_dir, pinned_mode, pinned_fill_threads, heartbeat_ms)
}
