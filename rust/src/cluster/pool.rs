//! In-process backend: one [`WorkerNode`] per OS thread via the typed
//! [`mapreduce::Pool`](crate::mapreduce::Pool).
//!
//! Runs the exact same request handler as the TCP worker daemon, minus
//! the sockets — requests are shared by `Arc` instead of serialised,
//! so `bytes_tx`/`bytes_rx` are 0. This is the default backend
//! (`Trainer::new`) and the bit-for-bit reference the TCP backend is
//! tested against.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::mapreduce::Pool;

use super::node::WorkerNode;
use super::wire::{Init, Request};
use super::{Backend, WorkerReply};

/// Thread-pool Map-Reduce backend.
pub struct PoolBackend {
    pool: Pool<WorkerNode>,
}

impl PoolBackend {
    /// Spawn one worker thread per init; `inits[k]` becomes worker `k`.
    /// Node state (executor compilation included) is built on each
    /// worker's own thread.
    pub fn new(inits: Vec<Init>, artifacts_dir: PathBuf) -> Result<PoolBackend> {
        let n = inits.len();
        let inits = Arc::new(inits);
        let pool = Pool::new(n, move |k| WorkerNode::build(&inits[k], &artifacts_dir))?;
        Ok(PoolBackend { pool })
    }

    fn reply(r: crate::mapreduce::MapResult<(super::wire::Response, u32)>) -> WorkerReply {
        let (value, psi_fills) = r.value;
        WorkerReply {
            worker: r.worker,
            value,
            secs: r.secs,
            bytes_tx: 0,
            bytes_rx: 0,
            psi_fills,
        }
    }
}

impl Backend for PoolBackend {
    fn workers(&self) -> usize {
        self.pool.len()
    }

    fn map_subset(&mut self, include: &[bool], req: &Request) -> Vec<Option<WorkerReply>> {
        let req = Arc::new(req.clone());
        self.pool
            .map_subset(include, move |_, node: &mut WorkerNode| {
                node.handle_counted(&req)
            })
            .into_iter()
            .map(|slot| slot.map(Self::reply))
            .collect()
    }

    fn map_one(&mut self, k: usize, req: &Request) -> Option<WorkerReply> {
        let req = req.clone();
        self.pool
            .map_one(k, move |_, node: &mut WorkerNode| node.handle_counted(&req))
            .map(Self::reply)
    }

    fn heartbeat(&mut self) -> Vec<bool> {
        self.pool.alive()
    }

    fn shutdown(&mut self) {
        // threads exit when the Pool drops its senders
    }
}
