//! The cluster layer: pluggable Map-Reduce backends.
//!
//! The paper's inference is two map rounds plus a constant-size reduce
//! per iteration (§3.2). [`Backend`] abstracts *where* those rounds
//! run:
//!
//! * [`PoolBackend`] — worker nodes as OS threads in this process
//!   (the original GParML multicore setting; zero-copy, no sockets).
//! * [`TcpBackend`] — worker nodes as separate processes speaking the
//!   versioned binary [`wire`] protocol over TCP, with leader-side
//!   membership: a dead socket or missed heartbeat maps the worker
//!   onto the paper's §5.2 drop-the-partial-term failure path instead
//!   of stalling the round.
//!
//! Both backends drive the same [`node::WorkerNode`] request handler,
//! and every number crosses the TCP wire bit-for-bit, so for a fixed
//! seed the two backends produce *identical* training traces (enforced
//! by `tests/cluster.rs`).

pub mod node;
pub mod pool;
pub mod tcp;
pub mod wire;

pub use node::WorkerNode;
pub use pool::PoolBackend;
pub use tcp::TcpBackend;

/// One worker's reply to a map round, with the accounting the
/// telemetry layer records per round.
#[derive(Debug, Clone)]
pub struct WorkerReply {
    pub worker: usize,
    pub value: wire::Response,
    /// In-map thread-CPU seconds on the worker (the modeled-cluster
    /// clock; see `telemetry`).
    pub secs: f64,
    /// Leader -> worker bytes for this request (0 in-process).
    pub bytes_tx: u64,
    /// Worker -> leader bytes for this reply (0 in-process).
    pub bytes_rx: u64,
    /// Full psi recomputations this request triggered on the worker
    /// (0 on a cache-hit gradient round; with the psi cache on, each
    /// evaluation costs exactly one per worker — see DESIGN.md §7).
    pub psi_fills: u32,
}

/// A Map-Reduce backend: broadcasts one request to a set of workers
/// and collects per-worker replies.
///
/// Every collection method returns **one slot per worker** (length ==
/// `workers()`): `None` means the worker was excluded from the round
/// *or* is dead/unreachable — the caller can tell which from its own
/// `include` mask, and must treat an unexpectedly-missing reply as the
/// paper's §5.2 dropped partial term, never as "fewer shards".
pub trait Backend {
    /// Total worker slots in the cluster (dead ones included).
    fn workers(&self) -> usize;

    /// Broadcast `req` to the workers with `include[k] == true`;
    /// barrier-collect their replies. Must not block indefinitely on a
    /// dead worker.
    fn map_subset(&mut self, include: &[bool], req: &wire::Request) -> Vec<Option<WorkerReply>>;

    /// Broadcast to every worker.
    fn map(&mut self, req: &wire::Request) -> Vec<Option<WorkerReply>> {
        let include = vec![true; self.workers()];
        self.map_subset(&include, req)
    }

    /// Send to a single worker.
    fn map_one(&mut self, k: usize, req: &wire::Request) -> Option<WorkerReply>;

    /// Probe liveness (cheap); returns the current alive mask.
    fn heartbeat(&mut self) -> Vec<bool>;

    /// Seconds since each worker was last heard from, `None` where the
    /// backend has no such notion (in-process workers) or the slot is
    /// dead. Feeds the trainer's per-worker heartbeat-age gauges
    /// (DESIGN.md §10).
    fn heartbeat_ages(&self) -> Vec<Option<f64>> {
        vec![None; self.workers()]
    }

    /// Politely stop the cluster (no-op for threads; sends `Shutdown`
    /// frames over TCP).
    fn shutdown(&mut self);
}
