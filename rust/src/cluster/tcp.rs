//! Leader-side TCP backend: drives worker daemons over the [`wire`]
//! protocol with explicit membership.
//!
//! Failure semantics (paper §5.2): any I/O error, protocol violation,
//! read timeout or missed heartbeat on a worker's socket marks that
//! worker **dead** — its slot returns `None` from then on, which the
//! trainer maps onto the drop-the-partial-term recovery path. Nothing
//! ever blocks indefinitely on a dead node: every read is bounded by
//! `timeout` (and `heartbeat_timeout` for pings).

use std::io;
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::wire::{self, Frame, Init, Request};
use super::{Backend, WorkerReply};
use crate::obs;

struct Conn {
    stream: TcpStream,
    /// Last time any frame was successfully received from this worker
    /// (feeds the per-worker heartbeat-age gauges, DESIGN.md §10).
    last_seen: Instant,
}

/// Multi-process Map-Reduce backend over localhost (or any) TCP.
pub struct TcpBackend {
    conns: Vec<Option<Conn>>,
    timeout: Duration,
    heartbeat_timeout: Duration,
    /// Total bytes sent / received since construction.
    pub total_tx: u64,
    pub total_rx: u64,
}

impl TcpBackend {
    /// Accept `inits.len()` workers on `listener`, handshake each and
    /// ship its shapes + shard. Worker ids are assigned in accept
    /// order. Bounded: a worker that never dials in (crashed before
    /// connecting) fails the whole construction after the backend
    /// timeout instead of hanging the leader in `accept` forever.
    pub fn accept(listener: &TcpListener, inits: Vec<Init>) -> Result<TcpBackend> {
        let mut backend = TcpBackend {
            conns: Vec::with_capacity(inits.len()),
            timeout: Duration::from_secs(60),
            heartbeat_timeout: Duration::from_secs(5),
            total_tx: 0,
            total_rx: 0,
        };
        listener
            .set_nonblocking(true)
            .context("setting listener non-blocking")?;
        let deadline = std::time::Instant::now() + backend.timeout;
        let expected = inits.len();
        for (k, init) in inits.into_iter().enumerate() {
            let stream = loop {
                match listener.accept() {
                    Ok((stream, _)) => break stream,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        if std::time::Instant::now() >= deadline {
                            anyhow::bail!(
                                "timed out waiting for worker {k} to connect \
                                 (accepted {k} of {expected} workers)"
                            );
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => {
                        return Err(e).with_context(|| format!("accepting worker {k}"));
                    }
                }
            };
            stream
                .set_nonblocking(false)
                .context("restoring blocking mode on worker socket")?;
            backend.handshake(k, stream, &init)?;
        }
        listener.set_nonblocking(false).ok();
        Ok(backend)
    }

    /// Dial workers that are already listening (`worker --listen`);
    /// `addrs[k]` becomes worker `k`.
    pub fn connect(addrs: &[String], inits: Vec<Init>) -> Result<TcpBackend> {
        anyhow::ensure!(
            addrs.len() == inits.len(),
            "need one init per worker address ({} vs {})",
            inits.len(),
            addrs.len()
        );
        let mut backend = TcpBackend {
            conns: Vec::with_capacity(inits.len()),
            timeout: Duration::from_secs(60),
            heartbeat_timeout: Duration::from_secs(5),
            total_tx: 0,
            total_rx: 0,
        };
        for (k, (addr, init)) in addrs.iter().zip(inits).enumerate() {
            let stream = TcpStream::connect(addr)
                .with_context(|| format!("connecting to worker {k} at {addr}"))?;
            backend.handshake(k, stream, &init)?;
        }
        Ok(backend)
    }

    fn handshake(&mut self, k: usize, stream: TcpStream, init: &Init) -> Result<()> {
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(self.timeout))
            .context("setting read timeout")?;
        // writes are bounded too: a wedged (but not dead) worker whose
        // receive buffer fills must not stall the leader in write_all
        stream
            .set_write_timeout(Some(self.timeout))
            .context("setting write timeout")?;
        let mut conn = Conn {
            stream,
            last_seen: Instant::now(),
        };
        let tx1 = wire::write_frame(
            &mut conn.stream,
            &Frame::Hello {
                worker_id: k as u32,
            },
        )?;
        let (ack, rx1) = wire::read_frame(&mut conn.stream)?
            .with_context(|| format!("worker {k} disconnected during handshake"))?;
        anyhow::ensure!(
            matches!(ack, Frame::HelloAck),
            "worker {k}: expected HelloAck, got {ack:?}"
        );
        let tx2 = wire::write_frame(&mut conn.stream, &Frame::Init(Box::new(init.clone())))?;
        let (ready, rx2) = wire::read_frame(&mut conn.stream)?
            .with_context(|| format!("worker {k} disconnected during init"))?;
        match ready {
            Frame::Response { resp, .. } => match *resp {
                wire::Response::Ok => {}
                wire::Response::Err(e) => anyhow::bail!("worker {k} failed to initialise: {e}"),
                r => anyhow::bail!("worker {k}: unexpected init reply {r:?}"),
            },
            f => anyhow::bail!("worker {k}: unexpected init frame {f:?}"),
        }
        self.total_tx += tx1 + tx2;
        self.total_rx += rx1 + rx2;
        self.conns.push(Some(conn));
        Ok(())
    }

    /// Bound every response read (and every frame write) by `timeout`
    /// — dead/wedged-node detection.
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
        for conn in self.conns.iter_mut().flatten() {
            conn.stream.set_read_timeout(Some(timeout)).ok();
            conn.stream.set_write_timeout(Some(timeout)).ok();
        }
    }

    pub fn set_heartbeat_timeout(&mut self, timeout: Duration) {
        self.heartbeat_timeout = timeout;
    }

    /// Workers still reachable.
    pub fn alive(&self) -> Vec<bool> {
        self.conns.iter().map(|c| c.is_some()).collect()
    }

    /// Seconds since the last frame was received from each worker
    /// (`None` for dead slots). Feeds the trainer's per-worker
    /// heartbeat-age gauges.
    pub fn last_seen_ages(&self) -> Vec<Option<f64>> {
        let now = Instant::now();
        self.conns
            .iter()
            .map(|c| {
                c.as_ref()
                    .map(|conn| now.duration_since(conn.last_seen).as_secs_f64())
            })
            .collect()
    }

    fn kill(&mut self, k: usize, why: &io::Error) {
        if self.conns[k].take().is_some() {
            eprintln!("[gparml-leader] worker {k} marked dead: {why}");
        }
    }

    /// Send `frame` to worker `k`; on failure the worker is dead.
    fn send(&mut self, k: usize, frame: &Frame) -> Option<u64> {
        let bytes = match wire::encode_frame(frame) {
            Ok(b) => b,
            Err(e) => {
                let err = io::Error::new(io::ErrorKind::InvalidData, format!("{e:#}"));
                self.kill(k, &err);
                return None;
            }
        };
        self.send_raw(k, &bytes)
    }

    /// Write pre-encoded frame bytes to worker `k` (lets a broadcast
    /// serialise the constant-size global message once, not per
    /// worker); on failure the worker is dead.
    fn send_raw(&mut self, k: usize, bytes: &[u8]) -> Option<u64> {
        use std::io::Write;
        let conn = self.conns[k].as_mut()?;
        match conn.stream.write_all(bytes).and_then(|()| conn.stream.flush()) {
            Ok(()) => {
                self.total_tx += bytes.len() as u64;
                Some(bytes.len() as u64)
            }
            Err(e) => {
                let err = io::Error::new(io::ErrorKind::BrokenPipe, format!("{e}"));
                self.kill(k, &err);
                None
            }
        }
    }

    /// Read one frame from worker `k`; on error/timeout/EOF the worker
    /// is dead.
    fn recv(&mut self, k: usize) -> Option<(Frame, u64)> {
        let conn = self.conns[k].as_mut()?;
        match wire::read_frame(&mut conn.stream) {
            Ok(Some((frame, n))) => {
                conn.last_seen = Instant::now();
                self.total_rx += n;
                Some((frame, n))
            }
            Ok(None) => {
                let err = io::Error::new(io::ErrorKind::UnexpectedEof, "connection closed");
                self.kill(k, &err);
                None
            }
            Err(e) => {
                let err = io::Error::new(io::ErrorKind::Other, format!("{e:#}"));
                self.kill(k, &err);
                None
            }
        }
    }

    /// Send a request and collect the typed response from one worker.
    /// The frame is stamped with the ambient trace id so worker-side
    /// spans line up with the leader's evaluation spans.
    fn round_one(&mut self, k: usize, req: &Request) -> Option<WorkerReply> {
        let frame = Frame::Request {
            trace_id: obs::trace::current(),
            req: Box::new(req.clone()),
        };
        let tx = self.send(k, &frame)?;
        match self.recv(k)? {
            (
                Frame::Response {
                    secs,
                    psi_fills,
                    resp,
                    ..
                },
                rx,
            ) => Some(WorkerReply {
                worker: k,
                value: *resp,
                secs,
                bytes_tx: tx,
                bytes_rx: rx,
                psi_fills,
            }),
            (f, _) => {
                let err = io::Error::new(io::ErrorKind::Other, format!("unexpected frame {f:?}"));
                self.kill(k, &err);
                None
            }
        }
    }
}

impl Backend for TcpBackend {
    fn workers(&self) -> usize {
        self.conns.len()
    }

    fn map_subset(&mut self, include: &[bool], req: &Request) -> Vec<Option<WorkerReply>> {
        assert_eq!(include.len(), self.conns.len());
        // phase 1: broadcast to all included live workers so the map
        // round actually runs in parallel across the processes; the
        // frame is serialised ONCE and the bytes shared across sends
        let frame = Frame::Request {
            trace_id: obs::trace::current(),
            req: Box::new(req.clone()),
        };
        let bytes = match wire::encode_frame(&frame) {
            Ok(b) => b,
            Err(_) => return vec![None; self.conns.len()],
        };
        let mut sent = vec![None; self.conns.len()];
        for k in 0..self.conns.len() {
            if include[k] {
                sent[k] = self.send_raw(k, &bytes);
            }
        }
        // phase 2: barrier-collect, worker order (deterministic reduce)
        let mut out: Vec<Option<WorkerReply>> = Vec::with_capacity(self.conns.len());
        for (k, tx) in sent.into_iter().enumerate() {
            let Some(tx) = tx else {
                out.push(None);
                continue;
            };
            let reply = match self.recv(k) {
                Some((
                    Frame::Response {
                        secs,
                        psi_fills,
                        resp,
                        ..
                    },
                    rx,
                )) => Some(WorkerReply {
                    worker: k,
                    value: *resp,
                    secs,
                    bytes_tx: tx,
                    bytes_rx: rx,
                    psi_fills,
                }),
                Some((f, _)) => {
                    let err = io::Error::new(io::ErrorKind::Other, format!("unexpected frame {f:?}"));
                    self.kill(k, &err);
                    None
                }
                None => None,
            };
            out.push(reply);
        }
        out
    }

    fn map_one(&mut self, k: usize, req: &Request) -> Option<WorkerReply> {
        self.round_one(k, req)
    }

    fn heartbeat_ages(&self) -> Vec<Option<f64>> {
        self.last_seen_ages()
    }

    fn heartbeat(&mut self) -> Vec<bool> {
        for conn in self.conns.iter_mut().flatten() {
            conn.stream
                .set_read_timeout(Some(self.heartbeat_timeout))
                .ok();
        }
        for k in 0..self.conns.len() {
            if self.send(k, &Frame::Ping).is_none() {
                continue;
            }
            match self.recv(k) {
                Some((Frame::Pong, _)) => {}
                Some((f, _)) => {
                    let err = io::Error::new(io::ErrorKind::Other, format!("expected Pong, got {f:?}"));
                    self.kill(k, &err);
                }
                None => {}
            }
        }
        for conn in self.conns.iter_mut().flatten() {
            conn.stream.set_read_timeout(Some(self.timeout)).ok();
        }
        self.alive()
    }

    fn shutdown(&mut self) {
        for k in 0..self.conns.len() {
            let _ = self.send(k, &Frame::Shutdown);
            self.conns[k] = None;
        }
    }
}

impl Drop for TcpBackend {
    fn drop(&mut self) {
        self.shutdown();
    }
}
