//! The cluster wire protocol: a versioned, length-prefixed binary
//! framing for everything the §3.2 Map-Reduce protocol sends between
//! the leader and a worker node.
//!
//! ## Frame layout
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "GPMR"
//! 4       2     wire version (u16 LE) — mismatch is rejected on read
//! 6       1     frame kind
//! 7       4     payload length (u32 LE), capped at MAX_PAYLOAD
//! 11      len   payload (kind-specific, see below)
//! ```
//!
//! All integers are little-endian; all floats are IEEE-754 f64
//! round-tripped via `to_le_bytes`/`from_le_bytes`, so a value crosses
//! the wire **bit-for-bit** — the TCP backend reproduces the
//! in-process backend's training trace exactly (tested in
//! `tests/cluster.rs`).
//!
//! Control frames: `Hello`/`HelloAck` (handshake + id assignment),
//! `Init` (shapes, model flags, psi-cache mode, the cluster's math
//! mode and the worker's data shard), `Ping`/`Pong` (heartbeat),
//! `Shutdown`. Data frames:
//! `Request` (a map-round broadcast: global parameters or adjoints,
//! tagged with the evaluation's parameter version — constant-size
//! messages, the paper's requirement 2/3) and `Response` (partial
//! statistics / gradients plus the worker's in-map compute seconds and
//! psi-recompute count).
//!
//! A truncated stream, a foreign magic, an unknown kind/tag, a
//! mismatched version or trailing payload bytes all fail decoding with
//! a descriptive error — the membership layer maps any such failure
//! onto the §5.2 drop-the-partial-term path.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::gp::params::{GlobalGrads, GlobalParams};
use crate::gp::{Adjoints, MathMode, Stats};
use crate::linalg::Matrix;
use crate::runtime::{ArtifactConfig, ShardData};

/// Frame magic: "GPMR".
pub const MAGIC: [u8; 4] = *b"GPMR";
/// Current wire version. Bump on any layout change.
///
/// History: v1 — initial protocol. v2 — the two map-round requests
/// (`Stats`, `Grads`) carry a u64 **parameter version** tag (keys the
/// workers' psi-scratch reuse across the two rounds of one
/// evaluation), `Response` frames carry a u32 psi-recompute count
/// (telemetry), and `Init` carries the `psi_cache` enable flag.
/// v3 — `Init` carries the cluster-wide `math_mode` execution policy
/// (u8: 0 strict, 1 fast); a worker pinned to the other mode rejects
/// the `Init`, so mixed-mode clusters fail at bring-up instead of
/// reducing numerically incomparable partial terms.
/// v4 — the serve-path messages of the train/serve split (DESIGN.md
/// §9): `Request::ModelInfo` / `Response::ModelInfo` (a client asks a
/// predict server — or a cluster worker — for its model shapes) and
/// `Request::ServePredict` (points-only prediction against the
/// server-held `TrainedModel`; answered with the existing
/// `Response::Predict`). Cluster workers hold no posterior weights and
/// answer `ServePredict` with an error.
/// v5 — the serving-subsystem messages (DESIGN.md §9):
/// `Response::ModelInfo` carries a u64 **model version** (bumped on
/// every hot reload, so clients can detect a swap), and three new
/// frames — `Request::ServeProject` (LVM latent projection: ship
/// observed outputs, get latent coordinates back, answered with
/// `Response::Project`), and `Request::Reload` (ask a predict server
/// to atomically reload its model artifact from disk). Cluster
/// workers answer `ModelInfo` with version 0 and reject the serve-only
/// frames with an error.
/// v6 — wire-propagated trace context (DESIGN.md §10): every
/// `Request` frame carries a u64 **trace/request id** (the leader
/// stamps map rounds with the evaluation version, serve clients stamp
/// each request with a fresh id) and every `Response` frame echoes it,
/// so one id follows a request across processes and into each peer's
/// span log. New control frames: `Request::ServeStats` (answered
/// inline, like `ModelInfo`) and `Response::StatsJson` (a JSON
/// snapshot of the peer's live metrics registry — the `gparml stats
/// --connect` payload).
/// v7 — `Init` carries `fill_threads` (u32, >= 1): the intra-worker
/// psi-fill parallelism every node of the cluster runs (DESIGN.md
/// §11). Purely physical — fills split over fixed row ranges computed
/// from shard size and thread count only, so any value is bit-identical
/// — but negotiated at bring-up like `math_mode` so a heterogeneous
/// cluster's per-round timing stays interpretable; workers pinned via
/// `--fill-threads` reject a mismatching `Init`.
/// v8 — the fleet control plane (DESIGN.md §12): serve replicas
/// register with a `gparml control` process over this same transport.
/// New requests — `Register` / `Deregister` / `ReplicaHeartbeat`
/// (replica -> control, all answered with [`Response::Ok`]) carrying
/// the replica's advertised serve address and current model version,
/// and `FleetInfo` (lb/operator -> control), answered with the new
/// `Response::FleetInfo`: the live replica set after staleness
/// eviction, each entry an address + model version + milliseconds
/// since the last heartbeat. Serve replicas and cluster workers
/// reject the control-plane frames with an error.
/// v9 — `Init` carries an optional [`ShardRef`] (DESIGN.md §13): a
/// path + expected checksum into the on-disk sharded dataset store.
/// When present, the `Init.shard` is empty and a worker co-located
/// with the store loads and checksum-verifies its own shard locally
/// instead of receiving the rows over the wire; a mismatching
/// checksum (or unreadable file) rejects bring-up loudly — the leader
/// never trains against rows it cannot vouch for.
pub const VERSION: u16 = 9;
/// Upper bound on a single frame payload (defends the decoder against
/// garbage length prefixes).
pub const MAX_PAYLOAD: usize = 1 << 30;

const HEADER_LEN: usize = 11;

/// A map-round broadcast from the leader.
///
/// The two per-iteration rounds carry a monotonically increasing
/// **parameter version**: both rounds of one bound/gradient evaluation
/// share a version, and every new evaluation (including each SCG
/// line-search trial point) gets a fresh one. Workers key their psi
/// scratch on it, so round 2 can reuse round 1's intermediates but can
/// never alias a cache filled at different parameters.
#[derive(Debug, Clone)]
pub enum Request {
    /// Round 1: compute partial statistics at these global parameters.
    Stats { params: GlobalParams, version: u64 },
    /// Round 2: chain-rule the adjoints into partial global gradients;
    /// optionally apply the local q(X) ascent step first (paper step 4).
    Grads {
        params: GlobalParams,
        adj: Adjoints,
        update_locals: bool,
        version: u64,
    },
    /// Return (and optionally drop) the worker's shard — the leader's
    /// replica read during decommission/re-sharding.
    FetchShard { clear: bool },
    /// Append rows to the worker's shard (re-sharding a dead node's
    /// data onto a survivor); local optimiser state is rebuilt.
    AppendShard { part: ShardData },
    /// Return the worker's local variational parameters (Xmu, Xvar).
    GatherLocals,
    /// Serve a prediction through this worker's executor.
    Predict {
        params: GlobalParams,
        xt_mu: Matrix,
        xt_var: Matrix,
        w1: Matrix,
        wv: Matrix,
    },
    /// Serve-path prediction (v4): the peer holds the trained model, the
    /// client ships only test points. Answered with
    /// [`Response::Predict`] by `gparml serve`; cluster workers reply
    /// with an error (they hold no posterior weights).
    ServePredict { xt_mu: Matrix, xt_var: Matrix },
    /// Ask the peer for its model/executor shapes (v4) — lets a predict
    /// client generate well-shaped test points without the model file.
    ModelInfo,
    /// Serve-path latent projection (v5): ship observed outputs `y`
    /// [t x d], get back latent coordinates answered from the served
    /// model's inducing posterior ([`Response::Project`]). Serve-only;
    /// cluster workers reply with an error.
    ServeProject { y: Matrix },
    /// Ask a predict server to atomically reload its model artifact
    /// from the path it was started with (v5) — the SIGHUP-equivalent
    /// control frame. Answered with the reloaded [`Response::ModelInfo`]
    /// (new version) or [`Response::Err`]. In-flight requests finish on
    /// the old model. Serve-only.
    Reload,
    /// Ask the peer for a snapshot of its live metrics registry (v6),
    /// answered inline with [`Response::StatsJson`] — counters, gauges
    /// and latency-histogram percentiles (DESIGN.md §10).
    ServeStats,
    /// Replica -> control (v8): join the fleet. `addr` is the serve
    /// address the replica advertises to the front door;
    /// `model_version` is its current hot-reload counter. Answered
    /// with [`Response::Ok`]. Re-registering an address upserts it.
    Register { addr: String, model_version: u64 },
    /// Replica -> control (v8): leave the fleet cleanly (sent on
    /// shutdown). Answered with [`Response::Ok`]; unknown addresses
    /// are ignored (deregistration is idempotent).
    Deregister { addr: String },
    /// Replica -> control (v8): liveness + current model version.
    /// A heartbeat for an unknown address is an implicit re-register,
    /// so a replica that reconnects after a control restart rejoins
    /// without special-casing. Answered with [`Response::Ok`].
    ReplicaHeartbeat { addr: String, model_version: u64 },
    /// lb/operator -> control (v8): ask for the live replica set
    /// (stale entries evicted first). Answered with
    /// [`Response::FleetInfo`].
    FleetInfo,
}

/// One fleet member as reported by the control plane (v8).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaInfo {
    /// The serve address the replica registered under.
    pub addr: String,
    /// The replica's model hot-reload counter at its last heartbeat —
    /// the version-skew signal the lb watches.
    pub model_version: u64,
    /// Milliseconds since the control plane last heard from it.
    pub age_ms: u64,
}

/// A worker's reply to a [`Request`].
#[derive(Debug, Clone)]
pub enum Response {
    Stats(Stats),
    Grads(GlobalGrads),
    Shard(ShardData),
    Locals { xmu: Matrix, xvar: Matrix },
    Predict { mean: Matrix, var: Vec<f64> },
    /// Reply to [`Request::ModelInfo`] (v4): inducing points, latent
    /// dimensionality and output dimensionality of the served model.
    /// `version` (v5) identifies the loaded model instance — a predict
    /// server bumps it on every hot reload; cluster workers report 0.
    ModelInfo { m: u32, q: u32, d: u32, version: u64 },
    /// Reply to [`Request::ServeProject`] (v5): latent coordinates
    /// [t x q] plus a per-point confidence in (0, 1] (the winning
    /// inducing point's responsibility).
    Project { xmu: Matrix, conf: Vec<f64> },
    Ok,
    /// The worker failed to execute the request (shape mismatch, ...).
    Err(String),
    /// Reply to [`Request::ServeStats`] (v6): the peer's metrics
    /// registry rendered as a JSON document (`obs::Registry::
    /// snapshot_json` — deterministic key order, so equal registries
    /// produce equal payloads).
    StatsJson(String),
    /// Reply to [`Request::FleetInfo`] (v8): the control plane's live
    /// replica set after staleness eviction, sorted by address
    /// (deterministic for equal registries).
    FleetInfo { replicas: Vec<ReplicaInfo> },
}

/// Everything a worker needs to build its node state: executor shapes,
/// model flags and the data shard (sent once after the handshake).
#[derive(Debug, Clone)]
pub struct Init {
    pub artifact: ArtifactConfig,
    pub lvm: bool,
    pub local_lr: f64,
    pub min_xvar: f64,
    /// Reuse psi intermediates across the two map rounds of one
    /// evaluation (false forces a fresh recompute every round — the
    /// trace-equality reference mode).
    pub psi_cache: bool,
    /// Execution policy every node of this cluster must run: partial
    /// statistics computed under different modes are not numerically
    /// comparable, so the mode is negotiated once at bring-up (v3).
    pub math_mode: MathMode,
    /// Intra-worker psi-fill parallelism (v7, >= 1). Deterministic by
    /// construction (fixed row-range splits; DESIGN.md §11), negotiated
    /// at bring-up like `math_mode`.
    pub fill_threads: u32,
    pub shard: ShardData,
    /// v9: worker-local shard load. When `Some`, `shard` is empty and
    /// the worker reads its rows from this store shard file instead,
    /// verifying the checksum before accepting them (DESIGN.md §13).
    pub shard_ref: Option<ShardRef>,
}

/// A reference into the on-disk dataset store (wire v9): a worker
/// co-located with the store loads this shard file itself instead of
/// receiving the rows over the wire. Regression-only — the first
/// `x_cols` store columns become `Xmu` (with `Xvar = 0`, the delta
/// q(X) of observed inputs), the rest become `Y`. The checksum is the
/// manifest-recorded XXH64 of the whole shard file; any disagreement
/// with what the worker reads rejects bring-up.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardRef {
    /// Shard file path as seen from the worker process.
    pub path: String,
    /// Expected XXH64 of the entire shard file (manifest record).
    pub checksum: u64,
    /// Expected row count (cross-checked against the decoded shard).
    pub rows: u32,
    /// Leading input columns; must equal the artifact's `q`.
    pub x_cols: u32,
    /// KL annealing weight for the shard (mirrors `ShardData`).
    pub kl_weight: f64,
}

/// One wire frame.
#[derive(Debug, Clone)]
pub enum Frame {
    /// Leader -> worker: you are worker `worker_id`.
    Hello { worker_id: u32 },
    /// Worker -> leader: handshake acknowledged.
    HelloAck,
    Init(Box<Init>),
    /// Leader/client -> worker/server: a request stamped with the u64
    /// trace/request id the peer must echo (v6). The leader stamps map
    /// rounds with the evaluation version; serve clients stamp each
    /// request with a fresh id (`obs::next_trace_id`).
    Request { trace_id: u64, req: Box<Request> },
    /// Worker -> leader: result plus the echoed trace id (v6), in-map
    /// thread-CPU seconds and the number of full psi recomputations
    /// the request triggered (0 on a cache-hit gradient round — the
    /// telemetry signal that scratch reuse actually happened on the
    /// worker).
    Response {
        trace_id: u64,
        secs: f64,
        psi_fills: u32,
        resp: Box<Response>,
    },
    Ping,
    Pong,
    Shutdown,
}

// ---------------------------------------------------------------------------
// payload encoder / decoder
// ---------------------------------------------------------------------------

/// Append-only payload encoder.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(u8::from(v));
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn vec_f64(&mut self, v: &[f64]) {
        self.u32(v.len() as u32);
        for x in v {
            self.f64(*x);
        }
    }

    pub fn mat(&mut self, m: &Matrix) {
        self.u32(m.rows() as u32);
        self.u32(m.cols() as u32);
        for x in m.data() {
            self.f64(*x);
        }
    }

    pub fn params(&mut self, p: &GlobalParams) {
        self.mat(&p.z);
        self.vec_f64(&p.log_ls);
        self.f64(p.log_sf2);
        self.f64(p.log_beta);
    }

    pub fn stats(&mut self, s: &Stats) {
        self.f64(s.a);
        self.f64(s.psi0);
        self.mat(&s.c);
        self.mat(&s.d);
        self.f64(s.kl);
        self.f64(s.n);
    }

    pub fn grads(&mut self, g: &GlobalGrads) {
        self.mat(&g.d_z);
        self.vec_f64(&g.d_log_ls);
        self.f64(g.d_log_sf2);
        self.f64(g.d_log_beta);
    }

    pub fn adjoints(&mut self, a: &Adjoints) {
        self.f64(a.d_psi0);
        self.mat(&a.d_c);
        self.mat(&a.d_d);
        self.f64(a.d_kl);
        self.mat(&a.d_kmm);
        self.f64(a.d_log_beta);
    }

    pub fn shard(&mut self, s: &ShardData) {
        self.mat(&s.xmu);
        self.mat(&s.xvar);
        self.mat(&s.y);
        self.f64(s.kl_weight);
    }

    pub fn artifact(&mut self, a: &ArtifactConfig) {
        self.str(&a.name);
        self.u32(a.m as u32);
        self.u32(a.q as u32);
        self.u32(a.d as u32);
        self.u32(a.cap as u32);
        self.u32(a.block_n as u32);
        self.u32(a.entries.len() as u32);
        for (k, v) in &a.entries {
            self.str(k);
            self.str(v);
        }
    }
}

/// Bounds-checked payload decoder.
pub struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    pub fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.i + n <= self.b.len(),
            "truncated frame payload (need {} bytes at offset {}, have {})",
            n,
            self.i,
            self.b.len()
        );
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    pub fn finish(&self) -> Result<()> {
        ensure!(
            self.i == self.b.len(),
            "frame payload has {} trailing bytes",
            self.b.len() - self.i
        );
        Ok(())
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        Ok(std::str::from_utf8(s)
            .context("invalid utf-8 string in frame")?
            .to_string())
    }

    pub fn vec_f64(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        ensure!(
            n.saturating_mul(8) <= self.b.len(),
            "vector length {n} exceeds payload"
        );
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f64()?);
        }
        Ok(v)
    }

    pub fn mat(&mut self) -> Result<Matrix> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        ensure!(
            rows.saturating_mul(cols).saturating_mul(8) <= self.b.len(),
            "matrix {rows}x{cols} exceeds payload"
        );
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(self.f64()?);
        }
        Ok(Matrix::from_vec(rows, cols, data))
    }

    pub fn params(&mut self) -> Result<GlobalParams> {
        Ok(GlobalParams {
            z: self.mat()?,
            log_ls: self.vec_f64()?,
            log_sf2: self.f64()?,
            log_beta: self.f64()?,
        })
    }

    pub fn stats(&mut self) -> Result<Stats> {
        Ok(Stats {
            a: self.f64()?,
            psi0: self.f64()?,
            c: self.mat()?,
            d: self.mat()?,
            kl: self.f64()?,
            n: self.f64()?,
        })
    }

    pub fn grads(&mut self) -> Result<GlobalGrads> {
        Ok(GlobalGrads {
            d_z: self.mat()?,
            d_log_ls: self.vec_f64()?,
            d_log_sf2: self.f64()?,
            d_log_beta: self.f64()?,
        })
    }

    pub fn adjoints(&mut self) -> Result<Adjoints> {
        Ok(Adjoints {
            d_psi0: self.f64()?,
            d_c: self.mat()?,
            d_d: self.mat()?,
            d_kl: self.f64()?,
            d_kmm: self.mat()?,
            d_log_beta: self.f64()?,
        })
    }

    pub fn shard(&mut self) -> Result<ShardData> {
        Ok(ShardData {
            xmu: self.mat()?,
            xvar: self.mat()?,
            y: self.mat()?,
            kl_weight: self.f64()?,
        })
    }

    pub fn artifact(&mut self) -> Result<ArtifactConfig> {
        let name = self.str()?;
        let m = self.u32()? as usize;
        let q = self.u32()? as usize;
        let d = self.u32()? as usize;
        let cap = self.u32()? as usize;
        let block_n = self.u32()? as usize;
        let n_entries = self.u32()? as usize;
        let mut entries = std::collections::BTreeMap::new();
        for _ in 0..n_entries {
            let k = self.str()?;
            let v = self.str()?;
            entries.insert(k, v);
        }
        Ok(ArtifactConfig {
            name,
            m,
            q,
            d,
            cap,
            block_n,
            entries,
        })
    }
}

// ---------------------------------------------------------------------------
// frame codec
// ---------------------------------------------------------------------------

impl Request {
    fn encode(&self, e: &mut Enc) {
        match self {
            Request::Stats { params, version } => {
                e.u8(1);
                e.params(params);
                e.u64(*version);
            }
            Request::Grads {
                params,
                adj,
                update_locals,
                version,
            } => {
                e.u8(2);
                e.params(params);
                e.adjoints(adj);
                e.bool(*update_locals);
                e.u64(*version);
            }
            Request::FetchShard { clear } => {
                e.u8(3);
                e.bool(*clear);
            }
            Request::AppendShard { part } => {
                e.u8(4);
                e.shard(part);
            }
            Request::GatherLocals => e.u8(5),
            Request::Predict {
                params,
                xt_mu,
                xt_var,
                w1,
                wv,
            } => {
                e.u8(6);
                e.params(params);
                e.mat(xt_mu);
                e.mat(xt_var);
                e.mat(w1);
                e.mat(wv);
            }
            Request::ServePredict { xt_mu, xt_var } => {
                e.u8(7);
                e.mat(xt_mu);
                e.mat(xt_var);
            }
            Request::ModelInfo => e.u8(8),
            Request::ServeProject { y } => {
                e.u8(9);
                e.mat(y);
            }
            Request::Reload => e.u8(10),
            Request::ServeStats => e.u8(11),
            Request::Register {
                addr,
                model_version,
            } => {
                e.u8(12);
                e.str(addr);
                e.u64(*model_version);
            }
            Request::Deregister { addr } => {
                e.u8(13);
                e.str(addr);
            }
            Request::ReplicaHeartbeat {
                addr,
                model_version,
            } => {
                e.u8(14);
                e.str(addr);
                e.u64(*model_version);
            }
            Request::FleetInfo => e.u8(15),
        }
    }

    fn decode(d: &mut Dec) -> Result<Request> {
        Ok(match d.u8()? {
            1 => Request::Stats {
                params: d.params()?,
                version: d.u64()?,
            },
            2 => Request::Grads {
                params: d.params()?,
                adj: d.adjoints()?,
                update_locals: d.bool()?,
                version: d.u64()?,
            },
            3 => Request::FetchShard { clear: d.bool()? },
            4 => Request::AppendShard { part: d.shard()? },
            5 => Request::GatherLocals,
            6 => Request::Predict {
                params: d.params()?,
                xt_mu: d.mat()?,
                xt_var: d.mat()?,
                w1: d.mat()?,
                wv: d.mat()?,
            },
            7 => Request::ServePredict {
                xt_mu: d.mat()?,
                xt_var: d.mat()?,
            },
            8 => Request::ModelInfo,
            9 => Request::ServeProject { y: d.mat()? },
            10 => Request::Reload,
            11 => Request::ServeStats,
            12 => Request::Register {
                addr: d.str()?,
                model_version: d.u64()?,
            },
            13 => Request::Deregister { addr: d.str()? },
            14 => Request::ReplicaHeartbeat {
                addr: d.str()?,
                model_version: d.u64()?,
            },
            15 => Request::FleetInfo,
            t => bail!("unknown request tag {t}"),
        })
    }
}

impl Response {
    fn encode(&self, e: &mut Enc) {
        match self {
            Response::Stats(s) => {
                e.u8(1);
                e.stats(s);
            }
            Response::Grads(g) => {
                e.u8(2);
                e.grads(g);
            }
            Response::Shard(s) => {
                e.u8(3);
                e.shard(s);
            }
            Response::Locals { xmu, xvar } => {
                e.u8(4);
                e.mat(xmu);
                e.mat(xvar);
            }
            Response::Predict { mean, var } => {
                e.u8(5);
                e.mat(mean);
                e.vec_f64(var);
            }
            Response::Ok => e.u8(6),
            Response::Err(msg) => {
                e.u8(7);
                e.str(msg);
            }
            Response::ModelInfo { m, q, d, version } => {
                e.u8(8);
                e.u32(*m);
                e.u32(*q);
                e.u32(*d);
                e.u64(*version);
            }
            Response::Project { xmu, conf } => {
                e.u8(9);
                e.mat(xmu);
                e.vec_f64(conf);
            }
            Response::StatsJson(json) => {
                e.u8(10);
                e.str(json);
            }
            Response::FleetInfo { replicas } => {
                e.u8(11);
                e.u32(replicas.len() as u32);
                for r in replicas {
                    e.str(&r.addr);
                    e.u64(r.model_version);
                    e.u64(r.age_ms);
                }
            }
        }
    }

    fn decode(d: &mut Dec) -> Result<Response> {
        Ok(match d.u8()? {
            1 => Response::Stats(d.stats()?),
            2 => Response::Grads(d.grads()?),
            3 => Response::Shard(d.shard()?),
            4 => Response::Locals {
                xmu: d.mat()?,
                xvar: d.mat()?,
            },
            5 => Response::Predict {
                mean: d.mat()?,
                var: d.vec_f64()?,
            },
            6 => Response::Ok,
            7 => Response::Err(d.str()?),
            8 => Response::ModelInfo {
                m: d.u32()?,
                q: d.u32()?,
                d: d.u32()?,
                version: d.u64()?,
            },
            9 => Response::Project {
                xmu: d.mat()?,
                conf: d.vec_f64()?,
            },
            10 => Response::StatsJson(d.str()?),
            11 => {
                let n = d.u32()? as usize;
                ensure!(
                    n <= MAX_PAYLOAD / 17,
                    "fleet info claims {n} replicas, exceeds payload cap"
                );
                let mut replicas = Vec::with_capacity(n);
                for _ in 0..n {
                    replicas.push(ReplicaInfo {
                        addr: d.str()?,
                        model_version: d.u64()?,
                        age_ms: d.u64()?,
                    });
                }
                Response::FleetInfo { replicas }
            }
            t => bail!("unknown response tag {t}"),
        })
    }
}

impl Frame {
    fn kind(&self) -> u8 {
        match self {
            Frame::Hello { .. } => 1,
            Frame::HelloAck => 2,
            Frame::Init(_) => 3,
            Frame::Request { .. } => 4,
            Frame::Response { .. } => 5,
            Frame::Ping => 6,
            Frame::Pong => 7,
            Frame::Shutdown => 8,
        }
    }

    fn encode_payload(&self, e: &mut Enc) {
        match self {
            Frame::Hello { worker_id } => e.u32(*worker_id),
            Frame::HelloAck | Frame::Ping | Frame::Pong | Frame::Shutdown => {}
            Frame::Init(init) => {
                e.artifact(&init.artifact);
                e.bool(init.lvm);
                e.f64(init.local_lr);
                e.f64(init.min_xvar);
                e.bool(init.psi_cache);
                e.u8(init.math_mode.code());
                e.u32(init.fill_threads);
                e.shard(&init.shard);
                match &init.shard_ref {
                    None => e.bool(false),
                    Some(r) => {
                        e.bool(true);
                        e.str(&r.path);
                        e.u64(r.checksum);
                        e.u32(r.rows);
                        e.u32(r.x_cols);
                        e.f64(r.kl_weight);
                    }
                }
            }
            Frame::Request { trace_id, req } => {
                e.u64(*trace_id);
                req.encode(e);
            }
            Frame::Response {
                trace_id,
                secs,
                psi_fills,
                resp,
            } => {
                e.u64(*trace_id);
                e.f64(*secs);
                e.u32(*psi_fills);
                resp.encode(e);
            }
        }
    }

    fn decode_payload(kind: u8, d: &mut Dec) -> Result<Frame> {
        Ok(match kind {
            1 => Frame::Hello {
                worker_id: d.u32()?,
            },
            2 => Frame::HelloAck,
            3 => Frame::Init(Box::new(Init {
                artifact: d.artifact()?,
                lvm: d.bool()?,
                local_lr: d.f64()?,
                min_xvar: d.f64()?,
                psi_cache: d.bool()?,
                math_mode: {
                    let code = d.u8()?;
                    match MathMode::from_code(code) {
                        Some(m) => m,
                        None => bail!("unknown math mode code {code} in Init frame"),
                    }
                },
                fill_threads: {
                    let t = d.u32()?;
                    if t == 0 {
                        bail!("fill_threads 0 in Init frame (must be >= 1)");
                    }
                    t
                },
                shard: d.shard()?,
                shard_ref: if d.bool()? {
                    let r = ShardRef {
                        path: d.str()?,
                        checksum: d.u64()?,
                        rows: d.u32()?,
                        x_cols: d.u32()?,
                        kl_weight: d.f64()?,
                    };
                    if r.rows == 0 {
                        bail!("shard_ref with 0 rows in Init frame");
                    }
                    if r.x_cols == 0 {
                        bail!("shard_ref with 0 input columns in Init frame");
                    }
                    Some(r)
                } else {
                    None
                },
            })),
            4 => Frame::Request {
                trace_id: d.u64()?,
                req: Box::new(Request::decode(d)?),
            },
            5 => Frame::Response {
                trace_id: d.u64()?,
                secs: d.f64()?,
                psi_fills: d.u32()?,
                resp: Box::new(Response::decode(d)?),
            },
            6 => Frame::Ping,
            7 => Frame::Pong,
            8 => Frame::Shutdown,
            k => bail!("unknown frame kind {k}"),
        })
    }
}

/// Prefix `payload` with the frame header for `kind`.
fn assemble_frame(kind: u8, payload: Vec<u8>) -> Result<Vec<u8>> {
    ensure!(
        payload.len() <= MAX_PAYLOAD,
        "frame payload of {} bytes exceeds MAX_PAYLOAD",
        payload.len()
    );
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(kind);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Serialise a frame to bytes (header + payload).
pub fn encode_frame(f: &Frame) -> Result<Vec<u8>> {
    let mut e = Enc::new();
    f.encode_payload(&mut e);
    assemble_frame(f.kind(), e.into_bytes())
}

/// Encode a `Frame::Response { Response::Predict }` directly from
/// **borrowed** buffers: the row window `[r0, r1)` of `mean` and the
/// matching slice of `var`. Byte-identical to encoding an owned
/// `Response::Predict` holding copies of that window (tested) — the
/// serve hot path answers each client of a coalesced micro-batch
/// without cloning the batch output into a per-request `Response`.
pub fn encode_predict_response(
    trace_id: u64,
    secs: f64,
    mean: &Matrix,
    r0: usize,
    r1: usize,
    var: &[f64],
) -> Result<Vec<u8>> {
    assert!(r0 <= r1 && r1 <= mean.rows(), "predict reply row window out of range");
    assert_eq!(var.len(), r1 - r0, "predict reply var/mean row mismatch");
    let mut e = Enc::new();
    e.u64(trace_id);
    e.f64(secs);
    e.u32(0); // psi_fills: serve-path replies do not report recomputes
    e.u8(5); // Response::Predict tag
    e.u32((r1 - r0) as u32);
    e.u32(mean.cols() as u32);
    for x in &mean.data()[r0 * mean.cols()..r1 * mean.cols()] {
        e.f64(*x);
    }
    e.vec_f64(var);
    assemble_frame(5, e.into_bytes()) // Frame::Response kind
}

/// Encode a `Frame::Response { Response::Project }` from borrowed
/// buffers — the [`encode_predict_response`] sibling for the LVM
/// latent-projection path.
pub fn encode_project_response(
    trace_id: u64,
    secs: f64,
    xmu: &Matrix,
    r0: usize,
    r1: usize,
    conf: &[f64],
) -> Result<Vec<u8>> {
    assert!(r0 <= r1 && r1 <= xmu.rows(), "project reply row window out of range");
    assert_eq!(conf.len(), r1 - r0, "project reply conf/xmu row mismatch");
    let mut e = Enc::new();
    e.u64(trace_id);
    e.f64(secs);
    e.u32(0);
    e.u8(9); // Response::Project tag
    e.u32((r1 - r0) as u32);
    e.u32(xmu.cols() as u32);
    for x in &xmu.data()[r0 * xmu.cols()..r1 * xmu.cols()] {
        e.f64(*x);
    }
    e.vec_f64(conf);
    assemble_frame(5, e.into_bytes())
}

/// Write one frame; returns the bytes put on the wire.
pub fn write_frame(w: &mut impl Write, f: &Frame) -> Result<u64> {
    let bytes = encode_frame(f)?;
    w.write_all(&bytes).context("writing frame")?;
    w.flush().context("flushing frame")?;
    Ok(bytes.len() as u64)
}

/// Read one frame; returns `(frame, bytes read)`. `Ok(None)` means the
/// peer closed the connection cleanly *between* frames; EOF inside a
/// frame is a hard "truncated frame" error.
pub fn read_frame(r: &mut impl Read) -> Result<Option<(Frame, u64)>> {
    let mut header = [0u8; HEADER_LEN];
    // distinguish clean EOF (0 bytes) from a mid-header cut
    let mut got = 0;
    while got < HEADER_LEN {
        let n = r.read(&mut header[got..]).context("reading frame header")?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("truncated frame header ({got} of {HEADER_LEN} bytes)");
        }
        got += n;
    }
    ensure!(
        header[..4] == MAGIC,
        "bad frame magic {:02x?} (expected GPMR)",
        &header[..4]
    );
    let version = u16::from_le_bytes([header[4], header[5]]);
    ensure!(
        version == VERSION,
        "wire version mismatch: peer speaks v{version}, this build speaks v{VERSION}"
    );
    let kind = header[6];
    let len = u32::from_le_bytes([header[7], header[8], header[9], header[10]]) as usize;
    ensure!(
        len <= MAX_PAYLOAD,
        "frame payload length {len} exceeds MAX_PAYLOAD"
    );
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .with_context(|| format!("truncated frame payload (expected {len} bytes)"))?;
    let mut d = Dec::new(&payload);
    let frame = Frame::decode_payload(kind, &mut d)?;
    d.finish()?;
    Ok(Some((frame, (HEADER_LEN + len) as u64)))
}

/// Decode a frame from a byte slice (testing convenience).
pub fn decode_frame(mut bytes: &[u8]) -> Result<(Frame, u64)> {
    read_frame(&mut bytes)?.context("empty buffer")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        testing::random_matrix(rng, r, c, 1.0)
    }

    fn rand_params(rng: &mut Rng, m: usize, q: usize) -> GlobalParams {
        GlobalParams {
            z: rand_mat(rng, m, q),
            log_ls: (0..q).map(|_| rng.normal()).collect(),
            log_sf2: rng.normal(),
            log_beta: rng.normal(),
        }
    }

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = encode_frame(f).unwrap();
        let (back, n) = decode_frame(&bytes).unwrap();
        assert_eq!(n as usize, bytes.len());
        back
    }

    fn assert_mat_eq(a: &Matrix, b: &Matrix) {
        assert_eq!(a.rows(), b.rows());
        assert_eq!(a.cols(), b.cols());
        // bit-for-bit, not approximate
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn prop_params_roundtrip_bitwise() {
        testing::check("wire params roundtrip", 30, |rng| {
            let m = testing::dim(rng, 1, 12);
            let q = testing::dim(rng, 1, 8);
            let p = rand_params(rng, m, q);
            let v = rng.below(1 << 30) as u64;
            let f = Frame::Request {
                trace_id: 0,
                req: Box::new(Request::Stats {
                    params: p.clone(),
                    version: v,
                }),
            };
            match roundtrip(&f) {
                Frame::Request { req: r, .. } => match *r {
                    Request::Stats { params, version } => {
                        assert_mat_eq(&params.z, &p.z);
                        assert_eq!(params.log_ls, p.log_ls);
                        assert_eq!(params.log_sf2.to_bits(), p.log_sf2.to_bits());
                        assert_eq!(params.log_beta.to_bits(), p.log_beta.to_bits());
                        assert_eq!(version, v, "parameter version tag");
                        Ok(())
                    }
                    _ => Err("wrong request variant".into()),
                },
                _ => Err("wrong frame kind".into()),
            }
        });
    }

    #[test]
    fn prop_stats_and_grads_roundtrip_bitwise() {
        testing::check("wire stats/grads roundtrip", 30, |rng| {
            let m = testing::dim(rng, 1, 10);
            let d = testing::dim(rng, 1, 6);
            let q = testing::dim(rng, 1, 5);
            let st = Stats {
                a: rng.normal(),
                psi0: rng.normal(),
                c: rand_mat(rng, m, d),
                d: rand_mat(rng, m, m),
                kl: rng.normal(),
                n: rng.below(1000) as f64,
            };
            let g = GlobalGrads {
                d_z: rand_mat(rng, m, q),
                d_log_ls: (0..q).map(|_| rng.normal()).collect(),
                d_log_sf2: rng.normal(),
                d_log_beta: rng.normal(),
            };
            let fills = rng.below(100) as u32;
            let fs = Frame::Response {
                trace_id: 0,
                secs: rng.uniform(),
                psi_fills: fills,
                resp: Box::new(Response::Stats(st.clone())),
            };
            match roundtrip(&fs) {
                Frame::Response {
                    psi_fills,
                    resp,
                    ..
                } => match *resp {
                    Response::Stats(s2) => {
                        assert_eq!(psi_fills, fills, "psi fill count");
                        assert_eq!(s2.a.to_bits(), st.a.to_bits());
                        assert_eq!(s2.psi0.to_bits(), st.psi0.to_bits());
                        assert_mat_eq(&s2.c, &st.c);
                        assert_mat_eq(&s2.d, &st.d);
                        assert_eq!(s2.kl.to_bits(), st.kl.to_bits());
                        assert_eq!(s2.n, st.n);
                    }
                    _ => return Err("wrong response variant".into()),
                },
                _ => return Err("wrong frame kind".into()),
            }
            let fg = Frame::Response {
                trace_id: 0,
                secs: 0.0,
                psi_fills: 0,
                resp: Box::new(Response::Grads(g.clone())),
            };
            match roundtrip(&fg) {
                Frame::Response { resp, .. } => match *resp {
                    Response::Grads(g2) => {
                        assert_mat_eq(&g2.d_z, &g.d_z);
                        assert_eq!(g2.d_log_ls, g.d_log_ls);
                        Ok(())
                    }
                    _ => Err("wrong response variant".into()),
                },
                _ => Err("wrong frame kind".into()),
            }
        });
    }

    #[test]
    fn prop_adjoints_and_shard_roundtrip() {
        testing::check("wire adjoints/shard roundtrip", 20, |rng| {
            let m = testing::dim(rng, 1, 8);
            let q = testing::dim(rng, 1, 4);
            let d = testing::dim(rng, 1, 5);
            let b = testing::dim(rng, 0, 20);
            let adj = Adjoints {
                d_psi0: rng.normal(),
                d_c: rand_mat(rng, m, d),
                d_d: rand_mat(rng, m, m),
                d_kl: rng.normal(),
                d_kmm: rand_mat(rng, m, m),
                d_log_beta: rng.normal(),
            };
            let p = rand_params(rng, m, q);
            let shard = ShardData {
                xmu: rand_mat(rng, b, q),
                xvar: rand_mat(rng, b, q),
                y: rand_mat(rng, b, d),
                kl_weight: rng.uniform(),
            };
            let v = rng.below(1 << 20) as u64;
            let f = Frame::Request {
                trace_id: 0,
                req: Box::new(Request::Grads {
                    params: p,
                    adj: adj.clone(),
                    update_locals: rng.flip(0.5),
                    version: v,
                }),
            };
            match roundtrip(&f) {
                Frame::Request { req: r, .. } => match *r {
                    Request::Grads {
                        adj: a2,
                        version,
                        ..
                    } => {
                        assert_mat_eq(&a2.d_c, &adj.d_c);
                        assert_mat_eq(&a2.d_d, &adj.d_d);
                        assert_mat_eq(&a2.d_kmm, &adj.d_kmm);
                        assert_eq!(a2.d_log_beta.to_bits(), adj.d_log_beta.to_bits());
                        assert_eq!(version, v, "parameter version tag");
                    }
                    _ => return Err("wrong request variant".into()),
                },
                _ => return Err("wrong frame kind".into()),
            }
            let f2 = Frame::Request {
                trace_id: 0,
                req: Box::new(Request::AppendShard {
                    part: shard.clone(),
                }),
            };
            match roundtrip(&f2) {
                Frame::Request { req: r, .. } => match *r {
                    Request::AppendShard { part } => {
                        assert_mat_eq(&part.xmu, &shard.xmu);
                        assert_mat_eq(&part.xvar, &shard.xvar);
                        assert_mat_eq(&part.y, &shard.y);
                        Ok(())
                    }
                    _ => Err("wrong request variant".into()),
                },
                _ => Err("wrong frame kind".into()),
            }
        });
    }

    #[test]
    fn init_and_control_frames_roundtrip() {
        let mut rng = Rng::new(3);
        let art = ArtifactConfig {
            name: "test".into(),
            m: 8,
            q: 2,
            d: 3,
            cap: 32,
            block_n: 8,
            entries: [("shard_stats".to_string(), "s.hlo.txt".to_string())]
                .into_iter()
                .collect(),
        };
        let init = Init {
            artifact: art.clone(),
            lvm: true,
            local_lr: 0.05,
            min_xvar: 1e-6,
            psi_cache: false,
            math_mode: MathMode::Strict,
            fill_threads: 3,
            shard: ShardData {
                xmu: rand_mat(&mut rng, 4, 2),
                xvar: rand_mat(&mut rng, 4, 2),
                y: rand_mat(&mut rng, 4, 3),
                kl_weight: 1.0,
            },
            shard_ref: Some(ShardRef {
                path: "store/shard_00002.gpds".into(),
                checksum: 0xDEAD_BEEF_CAFE_F00D,
                rows: 4,
                x_cols: 2,
                kl_weight: 0.25,
            }),
        };
        let want_ref = init.shard_ref.clone();
        match roundtrip(&Frame::Init(Box::new(init))) {
            Frame::Init(i2) => {
                assert_eq!(i2.artifact.name, art.name);
                assert_eq!(i2.artifact.entries, art.entries);
                assert!(i2.lvm);
                assert!(!i2.psi_cache, "psi_cache flag must round-trip");
                assert_eq!(i2.math_mode, MathMode::Strict);
                assert_eq!(i2.fill_threads, 3, "fill_threads must round-trip");
                assert_eq!(i2.shard.len(), 4);
                assert_eq!(i2.shard_ref, want_ref, "shard_ref must round-trip");
            }
            f => panic!("wrong frame {f:?}"),
        }
        for f in [
            Frame::Hello { worker_id: 7 },
            Frame::HelloAck,
            Frame::Ping,
            Frame::Pong,
            Frame::Shutdown,
        ] {
            let back = roundtrip(&f);
            assert_eq!(back.kind(), f.kind());
        }
    }

    /// Wire v3/v7: random `Init` frames round-trip the `math_mode` and
    /// `fill_threads` fields exactly, unknown mode codes and a
    /// zero thread count fail decoding, and the `Init` is rejected by a
    /// peer speaking any other wire version.
    #[test]
    fn prop_init_math_mode_roundtrip_and_version_rejection() {
        testing::check("wire v7 Init.math_mode/fill_threads", 30, |rng| {
            let q = testing::dim(rng, 1, 4);
            let b = testing::dim(rng, 0, 12);
            let mode = if rng.flip(0.5) {
                MathMode::Fast
            } else {
                MathMode::Strict
            };
            let threads = testing::dim(rng, 1, 8) as u32;
            let init = Init {
                artifact: ArtifactConfig {
                    name: "prop".into(),
                    m: testing::dim(rng, 1, 8),
                    q,
                    d: testing::dim(rng, 1, 5),
                    cap: 32,
                    block_n: 8,
                    entries: std::collections::BTreeMap::new(),
                },
                lvm: rng.flip(0.5),
                local_lr: rng.uniform(),
                min_xvar: 1e-6,
                psi_cache: rng.flip(0.5),
                math_mode: mode,
                fill_threads: threads,
                shard: ShardData {
                    xmu: rand_mat(rng, b, q),
                    xvar: rand_mat(rng, b, q),
                    y: rand_mat(rng, b, 2),
                    kl_weight: rng.uniform(),
                },
                shard_ref: if rng.flip(0.5) {
                    Some(ShardRef {
                        path: "s.gpds".into(),
                        checksum: rng.next_u64(),
                        rows: 1 + testing::dim(rng, 1, 7) as u32,
                        x_cols: q as u32,
                        kl_weight: rng.uniform(),
                    })
                } else {
                    None
                },
            };
            let psi_cache = init.psi_cache;
            let want_ref = init.shard_ref.clone();
            let bytes = encode_frame(&Frame::Init(Box::new(init))).unwrap();
            match decode_frame(&bytes) {
                Ok((Frame::Init(i2), _)) => {
                    if i2.math_mode != mode {
                        return Err(format!("math_mode {} != {}", i2.math_mode, mode));
                    }
                    if i2.psi_cache != psi_cache {
                        return Err("psi_cache flag corrupted".into());
                    }
                    if i2.fill_threads != threads {
                        return Err(format!("fill_threads {} != {threads}", i2.fill_threads));
                    }
                    if i2.shard_ref != want_ref {
                        return Err("shard_ref corrupted in roundtrip (v9)".into());
                    }
                }
                other => return Err(format!("bad decode: {other:?}")),
            }
            // any other wire version must be rejected before payload
            // decoding (a v2 peer cannot parse the math_mode byte)
            let mut old = bytes.clone();
            let bad_version = (VERSION - 1).to_le_bytes();
            old[4] = bad_version[0];
            old[5] = bad_version[1];
            let msg = format!("{:#}", decode_frame(&old).unwrap_err());
            if !msg.contains("version") {
                return Err(format!("unhelpful version error: {msg}"));
            }
            Ok(())
        });
        // unknown math-mode codes are a decode error, not a default
        assert!(MathMode::from_code(2).is_none());
        assert!(MathMode::from_code(255).is_none());
        // fill_threads 0 is a decode error, not a silent clamp (v7)
        let zero = Init {
            artifact: ArtifactConfig {
                name: "zero".into(),
                m: 2,
                q: 1,
                d: 1,
                cap: 32,
                block_n: 8,
                entries: std::collections::BTreeMap::new(),
            },
            lvm: false,
            local_lr: 0.05,
            min_xvar: 1e-6,
            psi_cache: true,
            math_mode: MathMode::Strict,
            fill_threads: 0,
            shard: ShardData {
                xmu: Matrix::zeros(0, 1),
                xvar: Matrix::zeros(0, 1),
                y: Matrix::zeros(0, 1),
                kl_weight: 1.0,
            },
            shard_ref: None,
        };
        let bytes = encode_frame(&Frame::Init(Box::new(zero))).unwrap();
        let msg = format!("{:#}", decode_frame(&bytes).unwrap_err());
        assert!(msg.contains("fill_threads"), "unhelpful error: {msg}");
    }

    #[test]
    fn truncated_frames_are_rejected_at_every_cut() {
        let bytes = encode_frame(&Frame::Request {
            trace_id: 0xDEAD_BEEF,
            req: Box::new(Request::FetchShard { clear: true }),
        })
        .unwrap();
        assert!(bytes.len() > HEADER_LEN);
        for cut in 1..bytes.len() {
            let err = decode_frame(&bytes[..cut]).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated") || msg.contains("header"),
                "cut at {cut}: unhelpful error {msg}"
            );
        }
        // clean EOF between frames is not an error
        assert!(read_frame(&mut &[][..]).unwrap().is_none());
    }

    /// Wire v6/v8: the `ShardRef`-bearing `Init` and the fleet
    /// `Register` frame reject truncation at every cut, and an
    /// over-length payload (header claiming more bytes than the fields
    /// consume) fails on the unread tail instead of being ignored.
    #[test]
    fn shard_ref_and_register_frames_reject_truncation_and_overlength() {
        let mut rng = Rng::new(11);
        let init = Init {
            artifact: ArtifactConfig {
                name: "cut".into(),
                m: 4,
                q: 2,
                d: 2,
                cap: 16,
                block_n: 4,
                entries: [("shard_stats".to_string(), "s.hlo.txt".to_string())]
                    .into_iter()
                    .collect(),
            },
            lvm: false,
            local_lr: 0.01,
            min_xvar: 1e-6,
            psi_cache: true,
            math_mode: MathMode::Strict,
            fill_threads: 1,
            shard: ShardData {
                xmu: rand_mat(&mut rng, 3, 2),
                xvar: rand_mat(&mut rng, 3, 2),
                y: rand_mat(&mut rng, 3, 2),
                kl_weight: 1.0,
            },
            shard_ref: Some(ShardRef {
                path: "store/shard_00007.gpds".into(),
                checksum: 0x0123_4567_89AB_CDEF,
                rows: 3,
                x_cols: 2,
                kl_weight: 0.5,
            }),
        };
        let register = Frame::Request {
            trace_id: 42,
            req: Box::new(Request::Register {
                addr: "10.0.0.7:9100".into(),
                model_version: 3,
            }),
        };
        for frame in [Frame::Init(Box::new(init)), register] {
            let bytes = encode_frame(&frame).unwrap();
            assert!(bytes.len() > HEADER_LEN);
            for cut in 1..bytes.len() {
                let err = decode_frame(&bytes[..cut]).unwrap_err();
                let msg = format!("{err:#}");
                assert!(
                    msg.contains("truncated") || msg.contains("header"),
                    "cut at {cut}: unhelpful error {msg}"
                );
            }
            // over-length: claim and supply 3 extra payload bytes
            let mut long = bytes.clone();
            let claimed = (long.len() - HEADER_LEN + 3) as u32;
            long[7..11].copy_from_slice(&claimed.to_le_bytes());
            long.extend_from_slice(&[0xAB, 0xCD, 0xEF]);
            let msg = format!("{:#}", decode_frame(&long).unwrap_err());
            assert!(msg.contains("trailing"), "{msg}");
        }
    }

    #[test]
    fn version_mismatch_and_bad_magic_are_rejected() {
        let mut bytes = encode_frame(&Frame::Ping).unwrap();
        bytes[4] = 0xFF; // corrupt version
        bytes[5] = 0x00;
        let msg = format!("{:#}", decode_frame(&bytes).unwrap_err());
        assert!(msg.contains("version"), "{msg}");

        let mut bytes = encode_frame(&Frame::Ping).unwrap();
        bytes[0] = b'X';
        let msg = format!("{:#}", decode_frame(&bytes).unwrap_err());
        assert!(msg.contains("magic"), "{msg}");
    }

    /// Wire v4: the serve-path frames (points-only prediction against a
    /// server-held model, and the shape handshake) round-trip bitwise.
    #[test]
    fn prop_serve_frames_roundtrip_bitwise() {
        testing::check("wire v4 serve frames", 20, |rng| {
            let t = testing::dim(rng, 0, 16);
            let q = testing::dim(rng, 1, 6);
            let xt_mu = rand_mat(rng, t, q);
            let xt_var = rand_mat(rng, t, q);
            let f = Frame::Request {
                trace_id: 0,
                req: Box::new(Request::ServePredict {
                    xt_mu: xt_mu.clone(),
                    xt_var: xt_var.clone(),
                }),
            };
            match roundtrip(&f) {
                Frame::Request { req: r, .. } => match *r {
                    Request::ServePredict {
                        xt_mu: m2,
                        xt_var: v2,
                    } => {
                        assert_mat_eq(&m2, &xt_mu);
                        assert_mat_eq(&v2, &xt_var);
                    }
                    _ => return Err("wrong request variant".into()),
                },
                _ => return Err("wrong frame kind".into()),
            }
            match roundtrip(&Frame::Request {
                trace_id: 0,
                req: Box::new(Request::ModelInfo),
            }) {
                Frame::Request { req: r, .. } => {
                    if !matches!(*r, Request::ModelInfo) {
                        return Err("ModelInfo request corrupted".into());
                    }
                }
                _ => return Err("wrong frame kind".into()),
            }
            let (m, qq, d) = (
                rng.below(1000) as u32,
                rng.below(100) as u32,
                rng.below(1000) as u32,
            );
            let version = rng.below(1 << 30) as u64;
            let f = Frame::Response {
                trace_id: 0,
                secs: 0.0,
                psi_fills: 0,
                resp: Box::new(Response::ModelInfo { m, q: qq, d, version }),
            };
            match roundtrip(&f) {
                Frame::Response { resp, .. } => match *resp {
                    Response::ModelInfo {
                        m: m2,
                        q: q2,
                        d: d2,
                        version: v2,
                    } => {
                        if (m2, q2, d2, v2) != (m, qq, d, version) {
                            return Err("ModelInfo shapes/version corrupted".into());
                        }
                        Ok(())
                    }
                    _ => Err("wrong response variant".into()),
                },
                _ => Err("wrong frame kind".into()),
            }
        });
    }

    /// Wire v5: the serving-subsystem frames — latent projection,
    /// hot-reload control — round-trip bitwise.
    #[test]
    fn prop_v5_project_and_reload_frames_roundtrip() {
        testing::check("wire v5 project/reload frames", 20, |rng| {
            let t = testing::dim(rng, 0, 12);
            let d = testing::dim(rng, 1, 6);
            let q = testing::dim(rng, 1, 4);
            let y = rand_mat(rng, t, d);
            match roundtrip(&Frame::Request {
                trace_id: 0,
                req: Box::new(Request::ServeProject { y: y.clone() }),
            }) {
                Frame::Request { req: r, .. } => match *r {
                    Request::ServeProject { y: y2 } => assert_mat_eq(&y2, &y),
                    _ => return Err("wrong request variant".into()),
                },
                _ => return Err("wrong frame kind".into()),
            }
            match roundtrip(&Frame::Request {
                trace_id: 0,
                req: Box::new(Request::Reload),
            }) {
                Frame::Request { req: r, .. } => {
                    if !matches!(*r, Request::Reload) {
                        return Err("Reload request corrupted".into());
                    }
                }
                _ => return Err("wrong frame kind".into()),
            }
            let xmu = rand_mat(rng, t, q);
            let conf: Vec<f64> = (0..t).map(|_| rng.uniform()).collect();
            let f = Frame::Response {
                trace_id: 0,
                secs: rng.uniform(),
                psi_fills: 0,
                resp: Box::new(Response::Project {
                    xmu: xmu.clone(),
                    conf: conf.clone(),
                }),
            };
            match roundtrip(&f) {
                Frame::Response { resp, .. } => match *resp {
                    Response::Project { xmu: x2, conf: c2 } => {
                        assert_mat_eq(&x2, &xmu);
                        if c2.iter().zip(&conf).any(|(a, b)| a.to_bits() != b.to_bits()) {
                            return Err("Project conf corrupted".into());
                        }
                        Ok(())
                    }
                    _ => Err("wrong response variant".into()),
                },
                _ => Err("wrong frame kind".into()),
            }
        });
    }

    /// The borrowed-buffer reply encoders produce byte-for-byte the
    /// same frames as the owned `Response` path — the contract that
    /// lets the serve hot loop skip the per-request clone.
    #[test]
    fn prop_borrowed_reply_encoders_match_owned_encoding() {
        testing::check("wire borrowed reply encoders", 20, |rng| {
            let t = testing::dim(rng, 1, 10);
            let cols = testing::dim(rng, 1, 5);
            let big = rand_mat(rng, t + 4, cols);
            let var: Vec<f64> = (0..t + 4).map(|_| rng.normal()).collect();
            let r0 = testing::dim(rng, 0, 2);
            let r1 = r0 + t;
            let secs = rng.uniform();
            let trace_id = rng.below(1 << 30) as u64;

            // owned: clone the window into a fresh Response
            let window = Matrix::from_fn(r1 - r0, cols, |i, j| big[(r0 + i, j)]);
            let owned = encode_frame(&Frame::Response {
                trace_id,
                secs,
                psi_fills: 0,
                resp: Box::new(Response::Predict {
                    mean: window.clone(),
                    var: var[r0..r1].to_vec(),
                }),
            })
            .unwrap();
            let borrowed =
                encode_predict_response(trace_id, secs, &big, r0, r1, &var[r0..r1]).unwrap();
            if owned != borrowed {
                return Err("predict reply bytes diverged".into());
            }

            let owned = encode_frame(&Frame::Response {
                trace_id,
                secs,
                psi_fills: 0,
                resp: Box::new(Response::Project {
                    xmu: window,
                    conf: var[r0..r1].to_vec(),
                }),
            })
            .unwrap();
            let borrowed =
                encode_project_response(trace_id, secs, &big, r0, r1, &var[r0..r1]).unwrap();
            if owned != borrowed {
                return Err("project reply bytes diverged".into());
            }
            Ok(())
        });
    }

    /// Wire v6: the trace/request id round-trips bitwise on every
    /// `Request` and `Response` frame, and the new stats frames
    /// (`ServeStats` / `StatsJson`) round-trip their payloads exactly.
    #[test]
    fn prop_v6_trace_ids_and_stats_frames_roundtrip() {
        testing::check("wire v6 trace ids / stats frames", 30, |rng| {
            // adversarial ids: full 64-bit range, not just small ints
            let id = ((rng.below(1 << 31) as u64) << 33)
                | ((rng.below(1 << 31) as u64) << 2)
                | (rng.below(4) as u64);
            let f = Frame::Request {
                trace_id: id,
                req: Box::new(Request::ServeStats),
            };
            match roundtrip(&f) {
                Frame::Request { trace_id, req } => {
                    if trace_id != id {
                        return Err(format!("request trace id {trace_id:#x} != {id:#x}"));
                    }
                    if !matches!(*req, Request::ServeStats) {
                        return Err("ServeStats request corrupted".into());
                    }
                }
                _ => return Err("wrong frame kind".into()),
            }
            let json = format!("{{\"counters\":{{\"requests\":{}}}}}", rng.below(1 << 20));
            let f = Frame::Response {
                trace_id: id,
                secs: rng.uniform(),
                psi_fills: 0,
                resp: Box::new(Response::StatsJson(json.clone())),
            };
            match roundtrip(&f) {
                Frame::Response { trace_id, resp, .. } => {
                    if trace_id != id {
                        return Err(format!("response trace id {trace_id:#x} != {id:#x}"));
                    }
                    match *resp {
                        Response::StatsJson(j2) => {
                            if j2 != json {
                                return Err("StatsJson payload corrupted".into());
                            }
                        }
                        _ => return Err("wrong response variant".into()),
                    }
                }
                _ => return Err("wrong frame kind".into()),
            }
            // ids survive on data frames too (the leader stamps map
            // rounds with the evaluation version)
            let f = Frame::Request {
                trace_id: id,
                req: Box::new(Request::GatherLocals),
            };
            match roundtrip(&f) {
                Frame::Request { trace_id, .. } if trace_id == id => Ok(()),
                other => Err(format!("data-frame trace id lost: {other:?}")),
            }
        });
    }

    #[test]
    fn trailing_payload_bytes_are_rejected() {
        let mut bytes = encode_frame(&Frame::Ping).unwrap();
        // claim one payload byte and provide it
        bytes[7] = 1;
        bytes.push(0xAB);
        let msg = format!("{:#}", decode_frame(&bytes).unwrap_err());
        assert!(msg.contains("trailing"), "{msg}");
    }

    /// Wire v8: the fleet control-plane frames round-trip exactly, and
    /// a truncated/mutilated fleet payload is a decode error.
    #[test]
    fn prop_v8_fleet_frames_roundtrip_and_reject() {
        testing::check("wire v8 fleet frames", 30, |rng| {
            let id = ((rng.below(1 << 30) as u64) << 32) | rng.below(1 << 30) as u64;
            let addr = format!("10.0.0.{}:{}", rng.below(255), 1024 + rng.below(60000));
            let mv = rng.below(1 << 20) as u64;
            for req in [
                Request::Register {
                    addr: addr.clone(),
                    model_version: mv,
                },
                Request::Deregister { addr: addr.clone() },
                Request::ReplicaHeartbeat {
                    addr: addr.clone(),
                    model_version: mv,
                },
                Request::FleetInfo,
            ] {
                let f = Frame::Request {
                    trace_id: id,
                    req: Box::new(req.clone()),
                };
                match roundtrip(&f) {
                    Frame::Request { trace_id, req: r } => {
                        if trace_id != id {
                            return Err(format!("trace id {trace_id:#x} != {id:#x}"));
                        }
                        let same = match (&req, &*r) {
                            (
                                Request::Register {
                                    addr: a,
                                    model_version: v,
                                },
                                Request::Register {
                                    addr: b,
                                    model_version: w,
                                },
                            ) => a == b && v == w,
                            (Request::Deregister { addr: a }, Request::Deregister { addr: b }) => {
                                a == b
                            }
                            (
                                Request::ReplicaHeartbeat {
                                    addr: a,
                                    model_version: v,
                                },
                                Request::ReplicaHeartbeat {
                                    addr: b,
                                    model_version: w,
                                },
                            ) => a == b && v == w,
                            (Request::FleetInfo, Request::FleetInfo) => true,
                            _ => false,
                        };
                        if !same {
                            return Err(format!("control request corrupted: {r:?}"));
                        }
                    }
                    _ => return Err("wrong frame kind".into()),
                }
            }
            // the registry snapshot reply: n replicas, any order/ages
            let n = testing::dim(rng, 0, 6);
            let replicas: Vec<ReplicaInfo> = (0..n)
                .map(|i| ReplicaInfo {
                    addr: format!("replica-{i}.local:{}", 7000 + i),
                    model_version: rng.below(1 << 16) as u64,
                    age_ms: rng.below(1 << 16) as u64,
                })
                .collect();
            let f = Frame::Response {
                trace_id: id,
                secs: 0.0,
                psi_fills: 0,
                resp: Box::new(Response::FleetInfo {
                    replicas: replicas.clone(),
                }),
            };
            let bytes = encode_frame(&f).unwrap();
            match decode_frame(&bytes) {
                Ok((Frame::Response { trace_id, resp, .. }, _)) => {
                    if trace_id != id {
                        return Err("fleet-info trace id lost".into());
                    }
                    match *resp {
                        Response::FleetInfo { replicas: r2 } => {
                            if r2 != replicas {
                                return Err(format!("fleet info corrupted: {r2:?}"));
                            }
                        }
                        _ => return Err("wrong response variant".into()),
                    }
                }
                other => return Err(format!("bad decode: {other:?}")),
            }
            // every truncation of the fleet payload is an error, never a
            // silently shorter replica list
            for cut in 1..bytes.len() {
                if decode_frame(&bytes[..cut]).is_ok() {
                    return Err(format!("truncation at {cut} accepted"));
                }
            }
            // a pre-fleet peer (v7) is rejected before payload decode
            let mut old = bytes.clone();
            let bad = (VERSION - 1).to_le_bytes();
            old[4] = bad[0];
            old[5] = bad[1];
            let msg = format!("{:#}", decode_frame(&old).unwrap_err());
            if !msg.contains("version") {
                return Err(format!("unhelpful version error: {msg}"));
            }
            Ok(())
        });
        // an absurd replica count is rejected by the cap, not allocated
        let mut e = Enc::new();
        e.u8(11);
        e.u32(u32::MAX);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        let msg = format!("{:#}", Response::decode(&mut d).unwrap_err());
        assert!(msg.contains("replicas"), "{msg}");
    }
}
