//! A small typed Map-Reduce runtime over OS threads — the substrate the
//! paper's inference runs on (Dean & Ghemawat-style, scoped to one box,
//! matching the original GParML multicore setting).
//!
//! Each worker thread owns non-`Send` state `W` (for us: a PJRT client,
//! compiled executables and the data shard), built *on* the thread by a
//! factory. A map round broadcasts a closure to every worker and collects
//! `(worker_id, result, compute_seconds)`; per-worker timings feed the
//! load-distribution telemetry (paper Fig. 5) and the simulated-cluster
//! clock (DESIGN.md §5: this container has 1 core, so parallel wall-clock
//! is *modeled* as `max_k t_k` + central time, exactly the paper's
//! "time spent in the computations alone" accounting).

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

type Job<W> = Box<dyn FnOnce(&mut W) + Send>;

/// One result of a map round.
#[derive(Debug, Clone)]
pub struct MapResult<R> {
    pub worker: usize,
    pub value: R,
    /// Thread-CPU seconds the worker spent inside the map function
    /// (robust to time-slicing when workers outnumber physical cores).
    pub secs: f64,
}

/// A pool of worker threads, each owning a `W`.
pub struct Pool<W> {
    senders: Vec<Sender<Job<W>>>,
    handles: Vec<JoinHandle<()>>,
}

impl<W: 'static> Pool<W> {
    /// Spawn `n` workers. `factory(k)` runs on worker `k`'s own thread to
    /// build its state (PJRT clients are not `Send`, so this is the only
    /// sound construction order). Fails if any factory fails.
    pub fn new<F>(n: usize, factory: F) -> Result<Pool<W>>
    where
        F: Fn(usize) -> Result<W> + Send + Sync + 'static,
    {
        let factory = Arc::new(factory);
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        for k in 0..n {
            let (tx, rx) = channel::<Job<W>>();
            senders.push(tx);
            let factory = Arc::clone(&factory);
            let ready = ready_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("gparml-worker-{k}"))
                    .spawn(move || {
                        let mut state = match factory(k) {
                            Ok(s) => {
                                let _ = ready.send(Ok(()));
                                s
                            }
                            Err(e) => {
                                let _ = ready.send(Err(e));
                                return;
                            }
                        };
                        while let Ok(job) = rx.recv() {
                            job(&mut state);
                        }
                    })?,
            );
        }
        drop(ready_tx);
        for _ in 0..n {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("worker thread died during startup"))??;
        }
        Ok(Pool { senders, handles })
    }

    pub fn len(&self) -> usize {
        self.senders.len()
    }

    pub fn is_empty(&self) -> bool {
        self.senders.is_empty()
    }

    /// One map round: run `f` on every worker, collect all results
    /// (ordered by worker id). This is a barrier — the reduce step can
    /// only start when the slowest map finishes, which is what the
    /// paper's Fig. 5 measures.
    pub fn map<R, F>(&self, f: F) -> Vec<MapResult<R>>
    where
        R: Send + 'static,
        F: Fn(usize, &mut W) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = channel::<MapResult<R>>();
        for (k, sender) in self.senders.iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            let job: Job<W> = Box::new(move |state: &mut W| {
                let c0 = crate::util::timer::thread_cpu_secs();
                let value = f(k, state);
                let secs = crate::util::timer::thread_cpu_secs() - c0;
                let _ = tx.send(MapResult {
                    worker: k,
                    value,
                    secs,
                });
            });
            // a worker that exited drops its receiver; treat as crashed node
            let _ = sender.send(job);
        }
        drop(tx);
        let mut out: Vec<MapResult<R>> = rx.iter().collect();
        out.sort_by_key(|r| r.worker);
        out
    }

    /// Map round over a subset of workers (`include[k]`): failed nodes
    /// are simply not scheduled, which is the paper's §5.2 recovery
    /// strategy — drop the partial term and accept a noisy gradient for
    /// one iteration instead of stalling on a reload.
    pub fn map_subset<R, F>(&self, include: &[bool], f: F) -> Vec<MapResult<R>>
    where
        R: Send + 'static,
        F: Fn(usize, &mut W) -> R + Send + Sync + 'static,
    {
        assert_eq!(include.len(), self.senders.len());
        let f = Arc::new(f);
        let (tx, rx) = channel::<MapResult<R>>();
        let mut expected = 0;
        for (k, sender) in self.senders.iter().enumerate() {
            if !include[k] {
                continue;
            }
            expected += 1;
            let f = Arc::clone(&f);
            let tx = tx.clone();
            let job: Job<W> = Box::new(move |state: &mut W| {
                let c0 = crate::util::timer::thread_cpu_secs();
                let value = f(k, state);
                let secs = crate::util::timer::thread_cpu_secs() - c0;
                let _ = tx.send(MapResult {
                    worker: k,
                    value,
                    secs,
                });
            });
            let _ = sender.send(job);
        }
        drop(tx);
        let mut out: Vec<MapResult<R>> = rx.iter().take(expected).collect();
        out.sort_by_key(|r| r.worker);
        out
    }

    /// Map on a single worker (used for targeted updates).
    pub fn map_one<R, F>(&self, k: usize, f: F) -> Option<MapResult<R>>
    where
        R: Send + 'static,
        F: FnOnce(usize, &mut W) -> R + Send + 'static,
    {
        let (tx, rx) = channel::<MapResult<R>>();
        let job: Job<W> = Box::new(move |state: &mut W| {
            let c0 = crate::util::timer::thread_cpu_secs();
            let value = f(k, state);
            let secs = crate::util::timer::thread_cpu_secs() - c0;
            let _ = tx.send(MapResult {
                worker: k,
                value,
                secs,
            });
        });
        self.senders[k].send(job).ok()?;
        rx.recv().ok()
    }
}

impl<W> Drop for Pool<W> {
    fn drop(&mut self) {
        self.senders.clear(); // closes the channels; workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Reduce helper: fold map results in worker order (deterministic — the
/// accumulation order does not depend on thread timing, keeping runs
/// bit-reproducible for a fixed seed).
pub fn reduce<R, A>(results: &[MapResult<R>], init: A, mut f: impl FnMut(A, &R) -> A) -> A {
    let mut acc = init;
    for r in results {
        acc = f(acc, &r.value);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_runs_on_every_worker() {
        let pool = Pool::new(4, |k| Ok(k * 10)).unwrap();
        let results = pool.map(|k, state| {
            assert_eq!(*state, k * 10);
            k + 1
        });
        assert_eq!(results.len(), 4);
        let vals: Vec<usize> = results.iter().map(|r| r.value).collect();
        assert_eq!(vals, vec![1, 2, 3, 4]);
        assert!(results.iter().all(|r| r.secs >= 0.0));
    }

    #[test]
    fn state_persists_across_rounds() {
        let pool = Pool::new(3, |_| Ok(0u64)).unwrap();
        for _ in 0..5 {
            pool.map(|_, state| {
                *state += 1;
            });
        }
        let counts = pool.map(|_, state| *state);
        assert!(counts.iter().all(|r| r.value == 5));
    }

    #[test]
    fn map_one_targets_single_worker() {
        let pool = Pool::new(3, |_| Ok(Vec::<usize>::new())).unwrap();
        pool.map_one(1, |_, state| state.push(42)).unwrap();
        let lens = pool.map(|_, state| state.len());
        assert_eq!(
            lens.iter().map(|r| r.value).collect::<Vec<_>>(),
            vec![0, 1, 0]
        );
    }

    #[test]
    fn factory_failure_propagates() {
        let res = Pool::new(2, |k| {
            if k == 1 {
                anyhow::bail!("boom")
            } else {
                Ok(())
            }
        });
        assert!(res.is_err());
    }

    #[test]
    fn reduce_is_worker_ordered() {
        let pool = Pool::new(4, Ok).unwrap();
        let results = pool.map(|k, _| k);
        let order = reduce(&results, Vec::new(), |mut acc, v| {
            acc.push(*v);
            acc
        });
        assert_eq!(order, vec![0, 1, 2, 3]);
    }
}
