//! A small typed Map-Reduce runtime over OS threads — the in-process
//! substrate the paper's inference runs on (Dean & Ghemawat-style,
//! scoped to one box, matching the original GParML multicore setting).
//! The multi-process equivalent lives in `cluster::TcpBackend`; both
//! are driven through the `cluster::Backend` trait.
//!
//! Each worker thread owns non-`Send` state `W` (for us: a shard
//! executor and the data shard), built *on* the thread by a factory. A
//! map round broadcasts a closure to every worker and collects
//! `(worker_id, result, compute_seconds)`; per-worker timings feed the
//! load-distribution telemetry (paper Fig. 5) and the simulated-cluster
//! clock (DESIGN.md §5: this container has 1 core, so parallel wall-clock
//! is *modeled* as `max_k t_k` + central time, exactly the paper's
//! "time spent in the computations alone" accounting).
//!
//! Every map method returns **one slot per worker**: `None` marks a
//! worker that was excluded from the round or whose thread has died.
//! A dead worker can therefore never silently shrink the result set
//! and mis-weight the reduce — the caller sees exactly which partial
//! terms are missing (the paper's §5.2 failure accounting).

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

type Job<W> = Box<dyn FnOnce(&mut W) + Send>;

/// One result of a map round.
#[derive(Debug, Clone)]
pub struct MapResult<R> {
    pub worker: usize,
    pub value: R,
    /// Thread-CPU seconds the worker spent inside the map function
    /// (robust to time-slicing when workers outnumber physical cores).
    pub secs: f64,
}

/// A pool of worker threads, each owning a `W`.
pub struct Pool<W> {
    senders: Vec<Sender<Job<W>>>,
    handles: Vec<JoinHandle<()>>,
}

impl<W: 'static> Pool<W> {
    /// Spawn `n` workers. `factory(k)` runs on worker `k`'s own thread to
    /// build its state (PJRT clients are not `Send`, so this is the only
    /// sound construction order). Fails if any factory fails.
    pub fn new<F>(n: usize, factory: F) -> Result<Pool<W>>
    where
        F: Fn(usize) -> Result<W> + Send + Sync + 'static,
    {
        let factory = Arc::new(factory);
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        for k in 0..n {
            let (tx, rx) = channel::<Job<W>>();
            senders.push(tx);
            let factory = Arc::clone(&factory);
            let ready = ready_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("gparml-worker-{k}"))
                    .spawn(move || {
                        let mut state = match factory(k) {
                            Ok(s) => {
                                let _ = ready.send(Ok(()));
                                s
                            }
                            Err(e) => {
                                let _ = ready.send(Err(e));
                                return;
                            }
                        };
                        while let Ok(job) = rx.recv() {
                            job(&mut state);
                        }
                    })?,
            );
        }
        drop(ready_tx);
        for _ in 0..n {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("worker thread died during startup"))??;
        }
        Ok(Pool { senders, handles })
    }

    pub fn len(&self) -> usize {
        self.senders.len()
    }

    pub fn is_empty(&self) -> bool {
        self.senders.is_empty()
    }

    /// Which worker threads are still accepting jobs (probed with a
    /// no-op job — a worker that exited has dropped its receiver).
    pub fn alive(&self) -> Vec<bool> {
        self.senders
            .iter()
            .map(|s| {
                let noop: Job<W> = Box::new(|_| {});
                s.send(noop).is_ok()
            })
            .collect()
    }

    /// One map round: run `f` on every worker; slot `k` of the result
    /// is `None` iff worker `k`'s thread has died. This is a barrier —
    /// the reduce step can only start when the slowest map finishes,
    /// which is what the paper's Fig. 5 measures.
    pub fn map<R, F>(&self, f: F) -> Vec<Option<MapResult<R>>>
    where
        R: Send + 'static,
        F: Fn(usize, &mut W) -> R + Send + Sync + 'static,
    {
        let include = vec![true; self.senders.len()];
        self.map_subset(&include, f)
    }

    /// Map round over a subset of workers (`include[k]`): failed nodes
    /// are simply not scheduled, which is the paper's §5.2 recovery
    /// strategy — drop the partial term and accept a noisy gradient for
    /// one iteration instead of stalling on a reload. Excluded and dead
    /// workers both yield `None` in their slot (callers distinguish via
    /// their own `include` mask).
    pub fn map_subset<R, F>(&self, include: &[bool], f: F) -> Vec<Option<MapResult<R>>>
    where
        R: Send + 'static,
        F: Fn(usize, &mut W) -> R + Send + Sync + 'static,
    {
        assert_eq!(include.len(), self.senders.len());
        let f = Arc::new(f);
        let (tx, rx) = channel::<MapResult<R>>();
        for (k, sender) in self.senders.iter().enumerate() {
            if !include[k] {
                continue;
            }
            let f = Arc::clone(&f);
            let tx = tx.clone();
            let job: Job<W> = Box::new(move |state: &mut W| {
                let c0 = crate::util::timer::thread_cpu_secs();
                let value = f(k, state);
                let secs = crate::util::timer::thread_cpu_secs() - c0;
                let _ = tx.send(MapResult {
                    worker: k,
                    value,
                    secs,
                });
            });
            // a worker that exited drops its receiver; its job (and tx
            // clone) is dropped with it, so the collect loop below still
            // terminates and the slot stays None
            let _ = sender.send(job);
        }
        drop(tx);
        let mut out: Vec<Option<MapResult<R>>> = (0..self.senders.len()).map(|_| None).collect();
        for r in rx {
            let k = r.worker;
            out[k] = Some(r);
        }
        out
    }

    /// Map on a single worker (used for targeted updates). `None` if
    /// the worker's thread has died.
    pub fn map_one<R, F>(&self, k: usize, f: F) -> Option<MapResult<R>>
    where
        R: Send + 'static,
        F: FnOnce(usize, &mut W) -> R + Send + 'static,
    {
        let (tx, rx) = channel::<MapResult<R>>();
        let job: Job<W> = Box::new(move |state: &mut W| {
            let c0 = crate::util::timer::thread_cpu_secs();
            let value = f(k, state);
            let secs = crate::util::timer::thread_cpu_secs() - c0;
            let _ = tx.send(MapResult {
                worker: k,
                value,
                secs,
            });
        });
        self.senders[k].send(job).ok()?;
        rx.recv().ok()
    }
}

impl<W> Drop for Pool<W> {
    fn drop(&mut self) {
        self.senders.clear(); // closes the channels; workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Reduce helper: fold the present map results in worker order
/// (deterministic — the accumulation order does not depend on thread
/// timing, keeping runs bit-reproducible for a fixed seed). Missing
/// slots are skipped; the caller accounts for them explicitly.
pub fn reduce<R, A>(results: &[Option<MapResult<R>>], init: A, mut f: impl FnMut(A, &R) -> A) -> A {
    let mut acc = init;
    for r in results.iter().flatten() {
        acc = f(acc, &r.value);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_runs_on_every_worker() {
        let pool = Pool::new(4, |k| Ok(k * 10)).unwrap();
        let results = pool.map(|k, state| {
            assert_eq!(*state, k * 10);
            k + 1
        });
        assert_eq!(results.len(), 4);
        let vals: Vec<usize> = results.iter().map(|r| r.as_ref().unwrap().value).collect();
        assert_eq!(vals, vec![1, 2, 3, 4]);
        assert!(results.iter().all(|r| r.as_ref().unwrap().secs >= 0.0));
    }

    #[test]
    fn state_persists_across_rounds() {
        let pool = Pool::new(3, |_| Ok(0u64)).unwrap();
        for _ in 0..5 {
            pool.map(|_, state| {
                *state += 1;
            });
        }
        let counts = pool.map(|_, state| *state);
        assert!(counts.iter().all(|r| r.as_ref().unwrap().value == 5));
    }

    #[test]
    fn map_one_targets_single_worker() {
        let pool = Pool::new(3, |_| Ok(Vec::<usize>::new())).unwrap();
        pool.map_one(1, |_, state| state.push(42)).unwrap();
        let lens = pool.map(|_, state| state.len());
        assert_eq!(
            lens.iter()
                .map(|r| r.as_ref().unwrap().value)
                .collect::<Vec<_>>(),
            vec![0, 1, 0]
        );
    }

    #[test]
    fn excluded_workers_yield_none_slots() {
        let pool = Pool::new(4, |_| Ok(())).unwrap();
        let out = pool.map_subset(&[true, false, true, false], |k, _| k);
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].as_ref().unwrap().value, 0);
        assert!(out[1].is_none());
        assert_eq!(out[2].as_ref().unwrap().value, 2);
        assert!(out[3].is_none());
    }

    #[test]
    fn dead_worker_yields_none_not_fewer_results() {
        let pool = Pool::new(3, |_| Ok(())).unwrap();
        // kill worker 1 by panicking inside its job (unwinds the thread)
        let _ = pool.map(|k, _| {
            if k == 1 {
                panic!("injected worker death");
            }
        });
        // the dying thread drops its receiver during unwinding; give the
        // liveness probe a moment to observe it
        let mut alive = pool.alive();
        for _ in 0..200 {
            if alive == vec![true, false, true] {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
            alive = pool.alive();
        }
        assert_eq!(alive, vec![true, false, true]);
        // the next full round still reports a slot per worker
        let out = pool.map(|k, _| k * 2);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].as_ref().unwrap().value, 0);
        assert!(out[1].is_none(), "dead worker must be explicit, not absent");
        assert_eq!(out[2].as_ref().unwrap().value, 4);
        // and map_one on the dead worker reports failure
        assert!(pool.map_one(1, |_, _| ()).is_none());
    }

    #[test]
    fn factory_failure_propagates() {
        let res = Pool::new(2, |k| {
            if k == 1 {
                anyhow::bail!("boom")
            } else {
                Ok(())
            }
        });
        assert!(res.is_err());
    }

    #[test]
    fn reduce_is_worker_ordered_and_skips_missing() {
        let pool = Pool::new(4, Ok).unwrap();
        let results = pool.map_subset(&[true, true, false, true], |k, _| k);
        let order = reduce(&results, Vec::new(), |mut acc, v| {
            acc.push(*v);
            acc
        });
        assert_eq!(order, vec![0, 1, 3]);
    }
}
