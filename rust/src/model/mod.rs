//! The train/serve split: a serializable model artifact and a
//! cluster-free predictor (DESIGN.md §9).
//!
//! Training is the expensive, distributed part of the paper's
//! algorithm; its *product* is tiny — the global parameters G and the
//! posterior weights over the m inducing points. This module makes
//! that product a first-class artifact:
//!
//! * [`TrainedModel`] — a versioned, checksummed, length-prefixed
//!   binary file (the same encoding primitives as the cluster wire
//!   protocol) holding `GlobalParams` + `PosteriorWeights` + shapes,
//!   jitter, the training `MathMode` and provenance (artifact name,
//!   iterations, final bound, seed). Produced by
//!   `Trainer::export_model` / `gparml export`; corrupt, truncated or
//!   mismatched files fail loudly on load, never mispredict.
//! * [`Checkpoint`] — the same codec for mid-training global-parameter
//!   snapshots (`Trainer::save_checkpoint` / `restore_checkpoint`).
//! * [`Predictor`] — a read-only, `Send + Sync` serving handle built
//!   from a `TrainedModel`: batched predictions with **no cluster**
//!   and no allocation in the per-batch hot loop
//!   ([`Predictor::predict_into`] + [`PredictScratch`]).
//! * [`serve`] — the multi-client TCP serving subsystem over the
//!   cluster wire framing (`gparml serve` / `gparml predict --connect`
//!   / `gparml reload`): reader threads feed a shared queue, a worker
//!   pool drains it with cross-client micro-batching (bit-identical to
//!   per-request evaluation), plus LVM latent-projection serving and
//!   atomic model hot-reload.
//! * [`bench`] — `gparml bench predict`, the standalone-predictor
//!   throughput benchmark (`BENCH_predict.json`), including the
//!   multi-client batched-vs-unbatched serving series.

pub mod artifact;
pub mod bench;
pub mod predictor;
pub mod serve;

pub use artifact::{Checkpoint, ModelMeta, TrainedModel};
pub use predictor::{PredictScratch, Predictor};
pub use serve::{ConnectOpts, ServeClient, ServeOptions, ServeState, ServeStats, ServedModelInfo};
