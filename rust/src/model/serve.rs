//! The serving subsystem (`gparml serve` / `gparml predict --connect`
//! / `gparml reload`): the end of the train → export → serve story,
//! speaking the cluster wire framing (DESIGN.md §9).
//!
//! ## Architecture
//!
//! ```text
//! accept loop ──► connection threads ──► shared job queue ──► worker pool
//!   (retries        (read frames,          (Mutex+Condvar)      (N threads,
//!    transient       answer control                              micro-batch
//!    errors)         frames inline,                              + reply)
//!                    enqueue + await
//!                    compute frames)
//! ```
//!
//! * **Connection threads** are cheap: they block on the socket, decode
//!   frames, answer `Ping`/`ModelInfo`/`Reload` inline, and for the two
//!   compute requests (`ServePredict`, `ServeProject`) enqueue a job and
//!   wait for its encoded reply bytes. One connection has at most one
//!   request in flight, so per-client reply order is trivially FIFO.
//! * **Worker threads** (a small fixed pool, [`ServeOptions::workers`])
//!   drain the queue with **adaptive cross-client micro-batching**:
//!   whatever compatible jobs are queued at wake-up (same request kind,
//!   same column count, up to [`ServeOptions::max_batch_rows`] total
//!   rows) are coalesced into ONE `predict_into`/`project_into` call and
//!   the outputs are split back per client. Both kernels are strictly
//!   per-row computations (tested), so a micro-batched reply is
//!   **bit-identical** to per-request evaluation — batching changes
//!   throughput, never bytes. Under light load a worker wakes to a
//!   single queued job and serves it unbatched; under heavy multi-client
//!   load batches grow automatically (that is the "adaptive" part — no
//!   timers, no artificial latency).
//! * **Replies** are encoded straight from the worker's batch output via
//!   the borrowed-buffer encoders ([`wire::encode_predict_response`]),
//!   so the hot path never clones `mean`/`var` into a per-request
//!   `Response`. Worker scratch and concat buffers are reused across
//!   batches: the steady-state hot loop is allocation-free apart from
//!   the reply byte buffers that go on the wire.
//! * **Hot reload**: the live model is an `Arc<ModelSlot>` behind a
//!   `RwLock` ([`ServeState`]). `Request::Reload` re-reads the artifact
//!   from the path the server was started with, validates it, and swaps
//!   the Arc; each worker batch snapshots the Arc once, so in-flight
//!   requests finish on the model they started with. Every swap bumps a
//!   **model version** reported in `ModelInfo`, so clients can detect
//!   it.
//!
//! ## Robustness contract
//!
//! Transient `accept()` failures (`ECONNABORTED`, EMFILE under load)
//! are logged and retried, never fatal. A misbehaving client — garbage
//! bytes, instant disconnect, death mid-request — costs exactly its own
//! connection thread; everyone else keeps being served. The
//! `--clients N` exit condition counts only connections that completed
//! at least one valid request-bearing frame (`Ping` or any `Request`),
//! so port scans and failed handshakes cannot consume a slot.

use std::collections::VecDeque;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::artifact::TrainedModel;
use super::predictor::{PredictScratch, Predictor};
use crate::cluster::wire::{self, Frame, Request, Response};
use crate::linalg::Matrix;
use crate::obs;
use crate::util::cli::Args;
use crate::util::timer::thread_cpu_secs;

/// How the server behaves; independent of the model it serves.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Stop accepting after this many counted clients (0 = forever).
    /// Only connections that completed ≥ 1 valid request-bearing frame
    /// count; in-flight clients are drained before returning.
    pub max_clients: u64,
    /// Worker-pool threads draining the shared queue (min 1).
    pub workers: usize,
    /// Micro-batching cap: total rows coalesced into one kernel call.
    /// 0 disables coalescing (every job runs alone — the reference
    /// behaviour micro-batched replies are tested against).
    pub max_batch_rows: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            max_clients: 0,
            workers: 2,
            max_batch_rows: 4096,
        }
    }
}

impl ServeOptions {
    /// The single parse site for the server-behaviour flags
    /// (`--clients N`, `--threads N`, `--batch-rows N`), shared by
    /// `gparml serve` and the `gparml lb` front door.
    pub fn from_args(args: &Args) -> Result<ServeOptions> {
        let d = ServeOptions::default();
        Ok(ServeOptions {
            max_clients: args.get_usize("clients", d.max_clients as usize)? as u64,
            workers: args.get_usize("threads", d.workers)?.max(1),
            max_batch_rows: args.get_usize("batch-rows", d.max_batch_rows)?,
        })
    }
}

/// One loaded model instance: the immutable predictor plus the version
/// `ModelInfo` reports for it.
pub struct ModelSlot {
    pub predictor: Predictor,
    pub version: u64,
}

/// The hot-swappable model state shared by every serving thread.
///
/// Readers take a cheap `Arc` snapshot ([`ServeState::current`]);
/// [`ServeState::reload`] / [`ServeState::install`] atomically replace
/// the slot and bump the version. Snapshots taken before a swap keep
/// the old model alive until their requests finish — the reload
/// contract.
pub struct ServeState {
    slot: RwLock<Arc<ModelSlot>>,
    /// Artifact path `Reload` re-reads; `None` rejects reloads.
    path: Option<PathBuf>,
}

impl ServeState {
    /// Serve `predictor` with no reload source (`Reload` is rejected).
    pub fn new(predictor: Predictor) -> ServeState {
        ServeState {
            slot: RwLock::new(Arc::new(ModelSlot {
                predictor,
                version: 1,
            })),
            path: None,
        }
    }

    /// Serve `predictor`, re-reading `path` on every `Reload` frame.
    pub fn with_path(predictor: Predictor, path: PathBuf) -> ServeState {
        ServeState {
            path: Some(path),
            ..ServeState::new(predictor)
        }
    }

    /// Snapshot the live model (cheap: one Arc clone under a read lock).
    pub fn current(&self) -> Arc<ModelSlot> {
        // a poisoned slot still holds a coherent Arc (the swap in
        // `install` is a single assignment) — recover, don't panic
        self.slot
            .read()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .clone()
    }

    /// Atomically swap in a new predictor; returns the new version.
    pub fn install(&self, predictor: Predictor) -> u64 {
        let mut slot = self
            .slot
            .write()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let version = slot.version + 1;
        *slot = Arc::new(ModelSlot { predictor, version });
        version
    }

    /// Re-read the artifact from the configured path, validate it and
    /// swap it in; in-flight requests finish on the old model. Returns
    /// the new version. A failed load leaves the old model serving.
    /// The serving configuration of the live predictor — today its
    /// `fill_threads` batch parallelism — carries over to the reloaded
    /// one: a reload swaps the model, not the server's capacity plan.
    pub fn reload(&self) -> Result<u64> {
        let path = self
            .path
            .as_ref()
            .context("this server was not started from a model file — nothing to reload")?;
        let model = TrainedModel::load(path)?;
        let mut predictor = Predictor::new(&model)?;
        predictor.set_fill_threads(self.current().predictor.fill_threads());
        Ok(self.install(predictor))
    }
}

/// What `serve` did, for callers and tests.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    /// Connections that completed ≥ 1 valid request-bearing frame.
    pub clients: u64,
    /// Requests answered (compute + control, across all clients).
    pub requests: u64,
    /// Kernel calls the worker pool made for compute requests.
    pub batches: u64,
    /// Compute jobs that shared a kernel call with ≥ 1 other job.
    pub coalesced_jobs: u64,
}

// ---------------------------------------------------------------------------
// job queue
// ---------------------------------------------------------------------------

/// A compute request detached from its connection.
enum Work {
    Predict { xt_mu: Matrix, xt_var: Matrix },
    Project { y: Matrix },
}

impl Work {
    /// Coalescing key half 1: jobs of different kinds never share a call.
    fn kind(&self) -> u8 {
        match self {
            Work::Predict { .. } => 0,
            Work::Project { .. } => 1,
        }
    }

    /// Coalescing key half 2: only equal column counts concatenate.
    fn cols(&self) -> usize {
        match self {
            Work::Predict { xt_mu, .. } => xt_mu.cols(),
            Work::Project { y } => y.cols(),
        }
    }

    fn rows(&self) -> usize {
        match self {
            Work::Predict { xt_mu, .. } => xt_mu.rows(),
            Work::Project { y } => y.rows(),
        }
    }
}

/// One queued request: the work plus the channel its encoded reply
/// frame goes back through, tagged with the client's wire trace id
/// (echoed on the reply and stamped on every span it touches).
struct Job {
    work: Work,
    reply: mpsc::Sender<Vec<u8>>,
    trace_id: u64,
    enqueued: Instant,
}

/// The shared FIFO the connection threads feed and the worker pool
/// drains. `pop_batch` hands a worker the longest coalescible run
/// queued at wake-up — the adaptive micro-batch.
struct Queue {
    inner: Mutex<(VecDeque<Job>, bool)>,
    cv: Condvar,
    /// Live queue depth (`serve.queue_depth` in the stats snapshot).
    depth: Arc<obs::Gauge>,
}

impl Queue {
    fn new(depth: Arc<obs::Gauge>) -> Queue {
        Queue {
            inner: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
            depth,
        }
    }

    /// Returns false if the queue is already closed (server shutting
    /// down) — the job is dropped and the caller must not wait for a
    /// reply.
    #[must_use]
    fn push(&self, job: Job) -> bool {
        // queue state (jobs, closed flag) stays coherent even if a
        // worker panicked mid-drain; recover rather than poison-cascade
        let mut g = self
            .inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        if g.1 {
            return false;
        }
        g.0.push_back(job);
        self.depth.set(g.0.len() as u64);
        drop(g);
        self.cv.notify_one();
        true
    }

    /// Block until at least one job is queued (or the queue is closed
    /// and drained), then take the front job plus every immediately
    /// following job that can share its kernel call (same kind, same
    /// column count, ≤ `max_rows` total rows, ≤ `max_jobs` jobs;
    /// `max_rows == 0` disables coalescing entirely). Jobs that cannot
    /// coalesce stay queued — and another worker is woken for them, so
    /// an incompatible backlog spreads across the pool instead of
    /// serialising behind one worker. Empty result = shut down.
    fn pop_batch(&self, max_jobs: usize, max_rows: usize) -> Vec<Job> {
        let mut g = self
            .inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        loop {
            if let Some(first) = g.0.pop_front() {
                let (kind, cols) = (first.work.kind(), first.work.cols());
                let mut rows = first.work.rows();
                let mut out = vec![first];
                if max_rows > 0 {
                    while out.len() < max_jobs.max(1) {
                        let fits = g.0.front().is_some_and(|next| {
                            next.work.kind() == kind
                                && next.work.cols() == cols
                                && rows + next.work.rows() <= max_rows
                        });
                        if !fits {
                            break;
                        }
                        let next = match g.0.pop_front() {
                            Some(next) => next,
                            None => break,
                        };
                        rows += next.work.rows();
                        out.push(next);
                    }
                }
                self.depth.set(g.0.len() as u64);
                if !g.0.is_empty() {
                    // leftovers (incompatible or over-cap): hand them to
                    // another worker (a notify sent while none waited
                    // is lost, so re-notify here)
                    self.cv.notify_one();
                }
                return out;
            }
            if g.1 {
                return Vec::new();
            }
            g = self
                .cv
                .wait(g)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    fn close(&self) {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .1 = true;
        self.cv.notify_all();
    }
}

/// Jobs a worker drains per wake-up, independent of the row cap.
const MAX_BATCH_JOBS: usize = 64;

/// How long the shutdown drain waits for lingering connections before
/// force-closing their sockets (an idle-but-connected client must not
/// wedge a `--clients N` exit forever).
const DRAIN_GRACE_MS: u64 = 10_000;

#[derive(Default)]
struct Counters {
    clients: AtomicU64,
    requests: AtomicU64,
    batches: AtomicU64,
    coalesced_jobs: AtomicU64,
    /// Connection threads currently running (shutdown barrier).
    active_conns: AtomicU64,
}

/// Cached handles into the serve [`obs::Registry`], so the hot path
/// never touches the registry's name map. The registry itself answers
/// `ServeStats` frames (DESIGN.md §10).
struct ServeMetrics {
    registry: obs::Registry,
    queue_depth: Arc<obs::Gauge>,
    in_flight_batches: Arc<obs::Gauge>,
    model_version: Arc<obs::Gauge>,
    clients: Arc<obs::Counter>,
    req_predict: Arc<obs::Counter>,
    req_project: Arc<obs::Counter>,
    req_model_info: Arc<obs::Counter>,
    req_reload: Arc<obs::Counter>,
    req_ping: Arc<obs::Counter>,
    req_stats: Arc<obs::Counter>,
    req_rejected: Arc<obs::Counter>,
    reloads: Arc<obs::Counter>,
    batches: Arc<obs::Counter>,
    coalesced_jobs: Arc<obs::Counter>,
    /// Enqueue -> reply-ready latency per compute job.
    request_ns: Arc<obs::Histogram>,
    /// Thread-CPU time per kernel call (one batch = one call).
    kernel_ns: Arc<obs::Histogram>,
}

impl ServeMetrics {
    fn new() -> ServeMetrics {
        let registry = obs::Registry::new();
        ServeMetrics {
            queue_depth: registry.gauge("serve.queue_depth"),
            in_flight_batches: registry.gauge("serve.in_flight_batches"),
            model_version: registry.gauge("serve.model_version"),
            clients: registry.counter("serve.clients"),
            req_predict: registry.counter("serve.requests.predict"),
            req_project: registry.counter("serve.requests.project"),
            req_model_info: registry.counter("serve.requests.model_info"),
            req_reload: registry.counter("serve.requests.reload"),
            req_ping: registry.counter("serve.requests.ping"),
            req_stats: registry.counter("serve.requests.stats"),
            req_rejected: registry.counter("serve.requests.rejected"),
            reloads: registry.counter("serve.reloads"),
            batches: registry.counter("serve.batches"),
            coalesced_jobs: registry.counter("serve.coalesced_jobs"),
            request_ns: registry.histogram("serve.request_ns"),
            kernel_ns: registry.histogram("serve.kernel_ns"),
            registry,
        }
    }
}

// ---------------------------------------------------------------------------
// server
// ---------------------------------------------------------------------------

/// Run the serving subsystem on `listener` until
/// [`ServeOptions::max_clients`] counted clients have been served
/// (0 = forever). Blocks; returns the run's [`ServeStats`].
pub fn serve(
    listener: &TcpListener,
    state: &ServeState,
    opts: &ServeOptions,
) -> Result<ServeStats> {
    // Nonblocking accept lets the loop observe the client-count exit
    // condition (reached inside connection threads) without a wake-up
    // connection; restored on exit.
    listener
        .set_nonblocking(true)
        .context("setting the serve listener nonblocking")?;
    let metrics = ServeMetrics::new();
    metrics.model_version.set(state.current().version);
    let queue = Queue::new(metrics.queue_depth.clone());
    let counters = Counters::default();
    // socket handles of live connections, so the shutdown drain can
    // force-close stragglers (handlers deregister on exit)
    let registry: Mutex<std::collections::HashMap<u64, TcpStream>> =
        Mutex::new(std::collections::HashMap::new());
    let mut next_conn = 0u64;

    std::thread::scope(|s| {
        for _ in 0..opts.workers.max(1) {
            s.spawn(|| worker_loop(&queue, state, opts, &counters, &metrics));
        }
        loop {
            let served = counters.clients.load(Ordering::Acquire);
            if opts.max_clients != 0 && served >= opts.max_clients {
                break;
            }
            match listener.accept() {
                Ok((stream, peer)) => {
                    counters.active_conns.fetch_add(1, Ordering::AcqRel);
                    let conn_id = next_conn;
                    next_conn += 1;
                    if let Ok(clone) = stream.try_clone() {
                        registry
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner)
                            .insert(conn_id, clone);
                    }
                    let (queue, state, counters, registry, metrics) =
                        (&queue, state, &counters, &registry, &metrics);
                    s.spawn(move || {
                        let client = serve_client(stream, state, queue, counters, metrics);
                        match client {
                            Ok(requests) => eprintln!(
                                "[gparml-serve] client {peer}: {requests} request(s)"
                            ),
                            Err(e) => {
                                eprintln!("[gparml-serve] client {peer} failed: {e:#}")
                            }
                        }
                        registry
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner)
                            .remove(&conn_id);
                        counters.active_conns.fetch_sub(1, Ordering::AcqRel);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                // transient under load (ECONNABORTED, EMFILE, ...):
                // log, back off briefly, keep serving — never fatal
                Err(e) => {
                    eprintln!("[gparml-serve] accept failed (retrying): {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
        // drain in-flight connections, then release the worker pool; a
        // connection that neither finishes nor hangs up within the
        // grace window is force-closed so `--clients N` always exits
        let mut waited_ms = 0u64;
        while counters.active_conns.load(Ordering::Acquire) > 0 {
            std::thread::sleep(Duration::from_millis(5));
            waited_ms += 5;
            if waited_ms == DRAIN_GRACE_MS {
                // the guard is deliberately live across shutdown() (a
                // non-blocking fd call) so handlers cannot deregister
                // mid-sweep; justified in analyze-allowlist.toml
                let conns = registry
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                if !conns.is_empty() {
                    eprintln!(
                        "[gparml-serve] force-closing {} lingering connection(s) after the \
                         {DRAIN_GRACE_MS}ms drain grace",
                        conns.len()
                    );
                    for conn in conns.values() {
                        let _ = conn.shutdown(std::net::Shutdown::Both);
                    }
                }
            }
        }
        queue.close();
    });
    listener.set_nonblocking(false).ok();

    Ok(ServeStats {
        clients: counters.clients.load(Ordering::Acquire),
        requests: counters.requests.load(Ordering::Acquire),
        batches: counters.batches.load(Ordering::Acquire),
        coalesced_jobs: counters.coalesced_jobs.load(Ordering::Acquire),
    })
}

/// Serve one client connection until `Shutdown`, EOF or an error.
/// Returns the number of requests answered.
fn serve_client(
    mut stream: TcpStream,
    state: &ServeState,
    queue: &Queue,
    counters: &Counters,
    metrics: &ServeMetrics,
) -> Result<u64> {
    // the listener is nonblocking (accept-loop polling); the accepted
    // socket must not inherit that (it does on some BSDs)
    stream.set_nonblocking(false).ok();
    stream.set_nodelay(true).ok();
    let (reply_tx, reply_rx) = mpsc::channel::<Vec<u8>>();
    let mut served = 0u64;
    let mut counted = false;
    loop {
        let (trace_id, req) = match wire::read_frame(&mut stream)? {
            None | Some((Frame::Shutdown, _)) => return Ok(served),
            Some((Frame::Ping, _)) => {
                count_client(&mut counted, counters, metrics);
                metrics.req_ping.inc();
                wire::write_frame(&mut stream, &Frame::Pong)?;
                served += 1;
                counters.requests.fetch_add(1, Ordering::AcqRel);
                continue;
            }
            Some((Frame::Request { trace_id, req }, _)) => {
                count_client(&mut counted, counters, metrics);
                (trace_id, req)
            }
            Some((f, _)) => bail!("unexpected frame {f:?} from predict client"),
        };
        match *req {
            Request::ModelInfo => {
                metrics.req_model_info.inc();
                let slot = state.current();
                respond(&mut stream, trace_id, model_info(&slot))?;
            }
            // the live metrics snapshot is answered inline, like
            // ModelInfo: it must stay readable even when the worker
            // pool is saturated (that is when you want it most)
            Request::ServeStats => {
                metrics.req_stats.inc();
                let json = metrics.registry.snapshot_json().to_string();
                respond(&mut stream, trace_id, Response::StatsJson(json))?;
            }
            Request::Reload => match state.reload() {
                Ok(_) => {
                    let slot = state.current();
                    eprintln!("[gparml-serve] reloaded model (version {})", slot.version);
                    metrics.req_reload.inc();
                    metrics.reloads.inc();
                    metrics.model_version.set(slot.version);
                    obs::trace::event("serve_reload", trace_id, slot.version);
                    respond(&mut stream, trace_id, model_info(&slot))?;
                }
                Err(e) => {
                    eprintln!("[gparml-serve] reload failed, keeping old model: {e:#}");
                    metrics.req_reload.inc();
                    respond(
                        &mut stream,
                        trace_id,
                        Response::Err(format!("reload failed: {e:#}")),
                    )?;
                }
            },
            // malformed shapes are rejected HERE, before the queue:
            // the batch concatenation relies on xt_mu/xt_var agreeing,
            // and a bad request must cost its sender an error reply,
            // never a worker thread
            Request::ServePredict { xt_mu, xt_var }
                if xt_mu.rows() != xt_var.rows() || xt_mu.cols() != xt_var.cols() =>
            {
                metrics.req_rejected.inc();
                respond(
                    &mut stream,
                    trace_id,
                    Response::Err(format!(
                        "ServePredict shapes disagree: xt_mu is {}x{} but xt_var is {}x{}",
                        xt_mu.rows(),
                        xt_mu.cols(),
                        xt_var.rows(),
                        xt_var.cols()
                    )),
                )?;
            }
            Request::ServePredict { xt_mu, xt_var } => {
                metrics.req_predict.inc();
                compute_request(
                    &mut stream,
                    queue,
                    metrics,
                    (&reply_tx, &reply_rx),
                    trace_id,
                    Work::Predict { xt_mu, xt_var },
                )?;
            }
            Request::ServeProject { y } => {
                metrics.req_project.inc();
                compute_request(
                    &mut stream,
                    queue,
                    metrics,
                    (&reply_tx, &reply_rx),
                    trace_id,
                    Work::Project { y },
                )?;
            }
            ref other => {
                metrics.req_rejected.inc();
                respond(
                    &mut stream,
                    trace_id,
                    Response::Err(format!(
                        "predict server only answers ServePredict/ServeProject/ModelInfo/\
                         Reload/ServeStats, got {other:?}"
                    )),
                )?;
            }
        }
        served += 1;
        counters.requests.fetch_add(1, Ordering::AcqRel);
    }
}

/// Enqueue one compute request and block until its encoded reply
/// frame comes back from the worker pool, then put it on the wire —
/// the single path both `ServePredict` and `ServeProject` take.
fn compute_request(
    stream: &mut TcpStream,
    queue: &Queue,
    metrics: &ServeMetrics,
    (reply_tx, reply_rx): (&mpsc::Sender<Vec<u8>>, &mpsc::Receiver<Vec<u8>>),
    trace_id: u64,
    work: Work,
) -> Result<()> {
    let enqueued = Instant::now();
    obs::trace::event("serve_enqueue", trace_id, work.rows() as u64);
    let queued = queue.push(Job {
        work,
        reply: reply_tx.clone(),
        trace_id,
        enqueued,
    });
    if !queued {
        bail!("server is shutting down");
    }
    let bytes = reply_rx
        .recv()
        .context("serve worker pool hung up mid-request")?;
    let waited_ns = enqueued.elapsed().as_nanos() as u64;
    metrics.request_ns.record(waited_ns);
    stream.write_all(&bytes).context("writing compute reply")?;
    obs::trace::event("serve_reply", trace_id, waited_ns);
    Ok(())
}

/// Count this connection toward `--clients` on its first valid
/// request-bearing frame (never at accept time).
fn count_client(counted: &mut bool, counters: &Counters, metrics: &ServeMetrics) {
    if !*counted {
        *counted = true;
        counters.clients.fetch_add(1, Ordering::AcqRel);
        metrics.clients.inc();
    }
}

fn model_info(slot: &ModelSlot) -> Response {
    Response::ModelInfo {
        m: slot.predictor.m() as u32,
        q: slot.predictor.q() as u32,
        d: slot.predictor.dout() as u32,
        version: slot.version,
    }
}

/// Write a control-path response frame (owned encoding — cold path),
/// echoing the request's trace id.
fn respond(stream: &mut TcpStream, trace_id: u64, resp: Response) -> Result<()> {
    wire::write_frame(
        stream,
        &Frame::Response {
            trace_id,
            secs: 0.0,
            psi_fills: 0,
            resp: Box::new(resp),
        },
    )?;
    Ok(())
}

// ---------------------------------------------------------------------------
// worker pool
// ---------------------------------------------------------------------------

/// Per-worker reusable buffers: kernel scratch, concatenated batch
/// inputs, batch outputs. Steady-state compute allocates nothing.
struct WorkerBufs {
    scratch: PredictScratch,
    cat_a: Matrix,
    cat_b: Matrix,
    out_mat: Matrix,
    out_vec: Vec<f64>,
}

fn worker_loop(
    queue: &Queue,
    state: &ServeState,
    opts: &ServeOptions,
    counters: &Counters,
    metrics: &ServeMetrics,
) {
    let mut bufs = WorkerBufs {
        scratch: PredictScratch::new(),
        cat_a: Matrix::zeros(0, 0),
        cat_b: Matrix::zeros(0, 0),
        out_mat: Matrix::zeros(0, 0),
        out_vec: Vec::new(),
    };
    loop {
        let jobs = queue.pop_batch(MAX_BATCH_JOBS, opts.max_batch_rows);
        if jobs.is_empty() {
            return; // queue closed and drained
        }
        // batch-coalescing attribution: the batch span carries the
        // lead job's trace id; every rider records which batch (by
        // lead id) it shared a kernel call with
        let mut batch_span = obs::trace::span("serve_batch", jobs[0].trace_id);
        batch_span.set_count(jobs.len() as u64);
        if obs::trace::enabled() {
            for jb in &jobs {
                let waited = jb.enqueued.elapsed().as_nanos() as u64;
                obs::trace::event("serve_dequeue", jb.trace_id, waited);
            }
            for jb in &jobs[1..] {
                obs::trace::event("serve_coalesce", jb.trace_id, jobs[0].trace_id);
            }
        }
        metrics.in_flight_batches.add(1);
        // every batch snapshots the model once: requests already
        // dequeued keep this model even if a reload lands mid-compute
        let slot = state.current();
        run_group(&jobs, &slot.predictor, &mut bufs, metrics);
        metrics.in_flight_batches.sub(1);
        drop(batch_span);
        counters.batches.fetch_add(1, Ordering::AcqRel);
        metrics.batches.inc();
        if jobs.len() > 1 {
            counters
                .coalesced_jobs
                .fetch_add(jobs.len() as u64, Ordering::AcqRel);
            metrics.coalesced_jobs.add(jobs.len() as u64);
        }
    }
}

/// Evaluate one coalesced group (all same kind + column count) with a
/// single kernel call and split the outputs back per job. Row windows
/// of the batch output are encoded borrowed — no per-request clone.
fn run_group(group: &[Job], predictor: &Predictor, bufs: &mut WorkerBufs, metrics: &ServeMetrics) {
    let mut kernel_span = obs::trace::span("serve_kernel", group[0].trace_id);
    kernel_span.set_count(group.iter().map(|jb| jb.work.rows() as u64).sum());
    let c0 = thread_cpu_secs();
    let cols = group[0].work.cols();
    let result = match &group[0].work {
        Work::Predict { xt_mu, xt_var } => {
            let (mu, var): (&Matrix, &Matrix) = if group.len() == 1 {
                (xt_mu, xt_var)
            } else {
                let rows: usize = group.iter().map(|jb| jb.work.rows()).sum();
                bufs.cat_a.reset(rows, cols, 0.0);
                bufs.cat_b.reset(rows, cols, 0.0);
                let mut at = 0;
                for jb in group {
                    if let Work::Predict { xt_mu, xt_var } = &jb.work {
                        let n = xt_mu.data().len();
                        bufs.cat_a.data_mut()[at..at + n].copy_from_slice(xt_mu.data());
                        bufs.cat_b.data_mut()[at..at + n].copy_from_slice(xt_var.data());
                        at += n;
                    }
                }
                (&bufs.cat_a, &bufs.cat_b)
            };
            predictor.predict_into(
                mu,
                var,
                &mut bufs.scratch,
                &mut bufs.out_mat,
                &mut bufs.out_vec,
            )
        }
        Work::Project { y } => {
            let y: &Matrix = if group.len() == 1 {
                y
            } else {
                let rows: usize = group.iter().map(|jb| jb.work.rows()).sum();
                bufs.cat_a.reset(rows, cols, 0.0);
                let mut at = 0;
                for jb in group {
                    if let Work::Project { y } = &jb.work {
                        let n = y.data().len();
                        bufs.cat_a.data_mut()[at..at + n].copy_from_slice(y.data());
                        at += n;
                    }
                }
                &bufs.cat_a
            };
            predictor.project_into(y, &mut bufs.scratch, &mut bufs.out_mat, &mut bufs.out_vec)
        }
    };
    let secs = thread_cpu_secs() - c0;
    drop(kernel_span);
    metrics.kernel_ns.record((secs * 1e9) as u64);

    match result {
        Ok(()) => {
            let mut r0 = 0;
            for jb in group {
                let t = jb.work.rows();
                let encoded = match jb.work {
                    Work::Predict { .. } => wire::encode_predict_response(
                        jb.trace_id,
                        secs,
                        &bufs.out_mat,
                        r0,
                        r0 + t,
                        &bufs.out_vec[r0..r0 + t],
                    ),
                    Work::Project { .. } => wire::encode_project_response(
                        jb.trace_id,
                        secs,
                        &bufs.out_mat,
                        r0,
                        r0 + t,
                        &bufs.out_vec[r0..r0 + t],
                    ),
                };
                send_reply(jb, encoded, secs);
                r0 += t;
            }
        }
        // the whole group shares one shape, so one failure is every
        // job's failure (shape mismatch against the model, typically)
        Err(e) => {
            for jb in group {
                let frame = Frame::Response {
                    trace_id: jb.trace_id,
                    secs,
                    psi_fills: 0,
                    resp: Box::new(Response::Err(format!("{e:#}"))),
                };
                send_reply(jb, wire::encode_frame(&frame), secs);
            }
        }
    }
}

/// Ship encoded reply bytes back to the job's connection thread; a
/// vanished client (dropped receiver) is not an error here.
fn send_reply(job: &Job, encoded: Result<Vec<u8>>, secs: f64) {
    match encoded {
        Ok(bytes) => {
            let _ = job.reply.send(bytes);
        }
        Err(e) => {
            let frame = Frame::Response {
                trace_id: job.trace_id,
                secs,
                psi_fills: 0,
                resp: Box::new(Response::Err(format!("encoding reply failed: {e:#}"))),
            };
            if let Ok(bytes) = wire::encode_frame(&frame) {
                let _ = job.reply.send(bytes);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// client side: ServeClient
// ---------------------------------------------------------------------------

/// Shapes + version a predict server reported for its live model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServedModelInfo {
    pub m: usize,
    pub q: usize,
    pub d: usize,
    /// Bumped on every hot reload; compare across calls to detect a swap.
    pub version: u64,
}

/// Connection/deadline/retry policy for a [`ServeClient`].
#[derive(Debug, Clone)]
pub struct ConnectOpts {
    /// Dial deadline per address (applied to every reconnect too).
    pub connect_timeout: Duration,
    /// Per-read deadline on the established stream; `None` blocks
    /// until the server answers (compute requests can be slow on
    /// purpose — only set a deadline where a stall must be an error).
    pub read_timeout: Option<Duration>,
    /// Extra attempts after a transport error, each on a freshly
    /// dialed connection. Every verb is one self-contained
    /// request/response frame, so a retry re-sends the same request;
    /// semantic failures (`Response::Err`) are never retried.
    pub retries: u32,
}

impl Default for ConnectOpts {
    fn default() -> ConnectOpts {
        ConnectOpts {
            connect_timeout: Duration::from_millis(5_000),
            read_timeout: None,
            retries: 1,
        }
    }
}

impl ConnectOpts {
    /// The single parse site for the client-policy flags
    /// (`--connect-timeout-ms`, `--read-timeout-ms` — 0 means block —
    /// and `--retries`), shared by `predict`/`reload`/`stats`/`lb`.
    pub fn from_args(args: &Args) -> Result<ConnectOpts> {
        let d = ConnectOpts::default();
        let connect_timeout = Duration::from_millis(
            args.get_usize("connect-timeout-ms", d.connect_timeout.as_millis() as usize)? as u64,
        );
        let read_timeout = match args.get_usize("read-timeout-ms", 0)? {
            0 => None,
            ms => Some(Duration::from_millis(ms as u64)),
        };
        let retries = args.get_usize("retries", d.retries as usize)? as u32;
        Ok(ConnectOpts {
            connect_timeout,
            read_timeout,
            retries,
        })
    }

    /// This policy with internal retries disabled — for callers that
    /// own failover themselves (the lb retries on a *sibling* replica
    /// instead of the same backend).
    pub fn no_retry(mut self) -> ConnectOpts {
        self.retries = 0;
        self
    }
}

/// A typed client for the predict-server wire: ONE connection reused
/// across calls, every `remote_*` verb as a method, connect/read
/// deadlines, and transparent reconnect-and-retry on transport errors
/// ([`ConnectOpts::retries`]). Any IO/desync error drops the
/// connection entirely, so the next call (or retry) starts on a fresh
/// stream instead of a half-read one. Dropping the client hangs up
/// politely (`Frame::Shutdown`).
///
/// This is the single client implementation behind the
/// `predict`/`reload`/`stats` CLI, the lb's backend pool and the
/// replica→control registration path (DESIGN.md §12).
pub struct ServeClient {
    addr: String,
    opts: ConnectOpts,
    stream: Option<TcpStream>,
}

impl ServeClient {
    /// Dial `addr` with the default policy ([`ConnectOpts::default`]).
    pub fn connect(addr: &str) -> Result<ServeClient> {
        ServeClient::with_opts(addr, ConnectOpts::default())
    }

    /// Dial `addr` with an explicit policy. The first connection is
    /// established eagerly, so a dead address fails here rather than
    /// on the first request.
    pub fn with_opts(addr: &str, opts: ConnectOpts) -> Result<ServeClient> {
        let mut client = ServeClient {
            addr: addr.to_string(),
            opts,
            stream: None,
        };
        client.dial()?;
        Ok(client)
    }

    /// The address this client dials.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// True if a connection is currently established (it may still be
    /// dead underneath — the next request finds out).
    pub fn is_connected(&self) -> bool {
        self.stream.is_some()
    }

    /// (Re)establish the connection if none is live.
    fn dial(&mut self) -> Result<()> {
        if self.stream.is_some() {
            return Ok(());
        }
        let addrs = self
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolving predict server address {:?}", self.addr))?;
        let mut last: Option<std::io::Error> = None;
        for sa in addrs {
            match TcpStream::connect_timeout(&sa, self.opts.connect_timeout) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    stream.set_read_timeout(self.opts.read_timeout).ok();
                    self.stream = Some(stream);
                    return Ok(());
                }
                Err(e) => last = Some(e),
            }
        }
        match last {
            Some(e) => {
                Err(anyhow::Error::from(e)
                    .context(format!("connecting to predict server at {}", self.addr)))
            }
            None => bail!("predict server address {:?} resolved to nothing", self.addr),
        }
    }

    /// Send `req` stamped with the caller's `trace_id` and collect the
    /// response — ONE attempt, no id minting, no retry. This is the
    /// lb's forwarding primitive: the front door passes the
    /// downstream client's id through unchanged, so one trace id
    /// follows a request across every hop, and owns failover itself.
    /// On any transport error the connection is dropped; the next
    /// call re-dials.
    pub fn request_with_id(&mut self, trace_id: u64, req: &Request) -> Result<Response> {
        self.dial()?;
        let stream = match self.stream.as_mut() {
            Some(stream) => stream,
            None => bail!("no connection to {} after dial", self.addr),
        };
        let result = raw_request(stream, trace_id, req);
        if result.is_err() {
            // half-written or desynced stream: never reuse it
            self.stream = None;
        }
        result
    }

    /// Send `req` stamped with a fresh trace/request id, retrying up
    /// to [`ConnectOpts::retries`] extra times on transport errors
    /// (each attempt gets its own id and a fresh connection). Returns
    /// the response plus the id it was answered under, for
    /// cross-process trace correlation (`gparml predict --connect`).
    pub fn request(&mut self, req: &Request) -> Result<(Response, u64)> {
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..=self.opts.retries {
            let trace_id = obs::next_trace_id();
            match self.request_with_id(trace_id, req) {
                Ok(resp) => return Ok((resp, trace_id)),
                Err(e) => {
                    if attempt < self.opts.retries {
                        eprintln!(
                            "[gparml-client] request to {} failed (attempt {} of {}), \
                             reconnecting: {e:#}",
                            self.addr,
                            attempt + 1,
                            self.opts.retries + 1
                        );
                    }
                    last = Some(e);
                }
            }
        }
        match last {
            Some(e) => Err(e.context(format!("request to predict server at {}", self.addr))),
            None => bail!("request to {} made no attempts", self.addr),
        }
    }

    /// Ask the server for its model shapes and version.
    pub fn model_info(&mut self) -> Result<ServedModelInfo> {
        let (resp, _) = self.request(&Request::ModelInfo)?;
        expect_model_info(resp)
    }

    /// Ask the server to hot-reload its model artifact from disk;
    /// returns the reloaded model's info (version bumped).
    pub fn reload(&mut self) -> Result<ServedModelInfo> {
        let (resp, _) = self.request(&Request::Reload)?;
        expect_model_info(resp)
    }

    /// Fetch the server's live metrics snapshot as a JSON document
    /// (the `gparml stats --connect` payload; schema in DESIGN.md §10).
    pub fn stats(&mut self) -> Result<String> {
        match self.request(&Request::ServeStats)?.0 {
            Response::StatsJson(json) => Ok(json),
            Response::Err(e) => bail!("predict server: {e}"),
            other => bail!("unexpected ServeStats reply {other:?}"),
        }
    }

    /// Predict a batch remotely. Every f64 crosses the wire
    /// bit-for-bit, so the reply equals a local [`Predictor::predict`]
    /// exactly — whether or not the server micro-batched it with other
    /// clients, and whichever fleet replica answered it.
    pub fn predict(&mut self, xt_mu: &Matrix, xt_var: &Matrix) -> Result<(Matrix, Vec<f64>)> {
        self.predict_traced(xt_mu, xt_var)
            .map(|(mean, var, _)| (mean, var))
    }

    /// [`ServeClient::predict`] that also returns the request id the
    /// call was answered under, so a caller can quote it against the
    /// server's `--trace-out` spans and `gparml stats` counters.
    pub fn predict_traced(
        &mut self,
        xt_mu: &Matrix,
        xt_var: &Matrix,
    ) -> Result<(Matrix, Vec<f64>, u64)> {
        let req = Request::ServePredict {
            xt_mu: xt_mu.clone(),
            xt_var: xt_var.clone(),
        };
        let (resp, trace_id) = self.request(&req)?;
        match resp {
            Response::Predict { mean, var } => Ok((mean, var, trace_id)),
            Response::Err(e) => bail!("predict server: {e}"),
            other => bail!("unexpected predict reply {other:?}"),
        }
    }

    /// Project observations into the served model's latent space
    /// remotely; bit-identical to a local [`Predictor::project`].
    pub fn project(&mut self, y: &Matrix) -> Result<(Matrix, Vec<f64>)> {
        let (resp, _) = self.request(&Request::ServeProject { y: y.clone() })?;
        match resp {
            Response::Project { xmu, conf } => Ok((xmu, conf)),
            Response::Err(e) => bail!("predict server: {e}"),
            other => bail!("unexpected project reply {other:?}"),
        }
    }

    /// Politely hang up now. Dropping the client does the same; this
    /// just makes the intent explicit at call sites.
    pub fn hangup(self) {}
}

impl Drop for ServeClient {
    fn drop(&mut self) {
        if let Some(stream) = self.stream.as_mut() {
            // best effort: the server treats EOF the same way
            let _ = wire::write_frame(stream, &Frame::Shutdown);
        }
    }
}

/// One request/response exchange on an established stream, verifying
/// the server echoed the caller's id (a mismatch means a desynced
/// stream — fail loudly, not with wrong data).
fn raw_request(stream: &mut TcpStream, trace_id: u64, req: &Request) -> Result<Response> {
    wire::write_frame(
        stream,
        &Frame::Request {
            trace_id,
            req: Box::new(req.clone()),
        },
    )?;
    match wire::read_frame(stream)? {
        Some((
            Frame::Response {
                trace_id: echoed,
                resp,
                ..
            },
            _,
        )) => {
            anyhow::ensure!(
                echoed == trace_id,
                "predict server echoed request id {echoed:#018x}, expected {trace_id:#018x} \
                 (desynced stream?)"
            );
            Ok(*resp)
        }
        Some((f, _)) => bail!("expected a Response frame, got {f:?}"),
        None => bail!("predict server closed the connection mid-request"),
    }
}

fn expect_model_info(resp: Response) -> Result<ServedModelInfo> {
    match resp {
        Response::ModelInfo { m, q, d, version } => Ok(ServedModelInfo {
            m: m as usize,
            q: q as usize,
            d: d as usize,
            version,
        }),
        Response::Err(e) => bail!("predict server: {e}"),
        other => bail!("unexpected ModelInfo reply {other:?}"),
    }
}
