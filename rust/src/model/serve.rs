//! The TCP predict server (`gparml serve`) and its client helpers
//! (`gparml predict --connect`): the end of the train → export → serve
//! story, speaking the cluster wire framing (DESIGN.md §9).
//!
//! The server loads one [`TrainedModel`], builds one [`Predictor`] and
//! serves any number of concurrent clients — one OS thread per
//! connection, all sharing the same `&Predictor` (it is `Sync`; each
//! thread owns its [`PredictScratch`], so batches are allocation-free
//! after warm-up). Requests/replies are ordinary wire v4 frames:
//! `ModelInfo` (shape handshake), `ServePredict` → `Predict`,
//! `Ping`/`Pong`, `Shutdown`/EOF to hang up. Zero training workers are
//! involved anywhere on this path.

use std::net::{TcpListener, TcpStream};

use anyhow::{bail, Context, Result};

use super::predictor::{PredictScratch, Predictor};
use crate::cluster::wire::{self, Frame, Request, Response};
use crate::linalg::Matrix;
use crate::util::timer::thread_cpu_secs;

/// Serve clients accepted on `listener` until `max_clients`
/// connections have been handled (0 = forever). Each connection gets
/// its own thread; all threads share `predictor`. Returns the number
/// of connections served.
pub fn serve(listener: &TcpListener, predictor: &Predictor, max_clients: u64) -> Result<u64> {
    std::thread::scope(|s| {
        let mut served = 0u64;
        while max_clients == 0 || served < max_clients {
            let (stream, peer) = listener.accept().context("accepting predict client")?;
            served += 1;
            let client = served;
            s.spawn(move || match serve_client(stream, predictor) {
                Ok(requests) => {
                    eprintln!("[gparml-serve] client {client} ({peer}): {requests} request(s)")
                }
                Err(e) => eprintln!("[gparml-serve] client {client} ({peer}) failed: {e:#}"),
            });
        }
        Ok(served)
    })
}

/// Serve one client connection until `Shutdown` or EOF. Returns the
/// number of predict/info requests answered.
fn serve_client(mut stream: TcpStream, predictor: &Predictor) -> Result<u64> {
    stream.set_nodelay(true).ok();
    let mut scratch = PredictScratch::new();
    let mut mean = Matrix::zeros(0, 0);
    let mut var = Vec::new();
    let mut served = 0u64;
    loop {
        let req = match wire::read_frame(&mut stream)? {
            None | Some((Frame::Shutdown, _)) => return Ok(served),
            Some((Frame::Ping, _)) => {
                wire::write_frame(&mut stream, &Frame::Pong)?;
                continue;
            }
            Some((Frame::Request(req), _)) => req,
            Some((f, _)) => bail!("unexpected frame {f:?} from predict client"),
        };
        let c0 = thread_cpu_secs();
        let resp = match &*req {
            Request::ModelInfo => Response::ModelInfo {
                m: predictor.m() as u32,
                q: predictor.q() as u32,
                d: predictor.dout() as u32,
            },
            Request::ServePredict { xt_mu, xt_var } => {
                match predictor.predict_into(xt_mu, xt_var, &mut scratch, &mut mean, &mut var) {
                    Ok(()) => Response::Predict {
                        mean: mean.clone(),
                        var: var.clone(),
                    },
                    Err(e) => Response::Err(format!("{e:#}")),
                }
            }
            other => Response::Err(format!(
                "predict server only answers ServePredict/ModelInfo, got {other:?}"
            )),
        };
        let secs = thread_cpu_secs() - c0;
        wire::write_frame(
            &mut stream,
            &Frame::Response {
                secs,
                psi_fills: 0,
                resp: Box::new(resp),
            },
        )?;
        served += 1;
    }
}

// ---------------------------------------------------------------------------
// client side
// ---------------------------------------------------------------------------

/// Dial a predict server.
pub fn connect(addr: &str) -> Result<TcpStream> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to predict server at {addr}"))?;
    stream.set_nodelay(true).ok();
    Ok(stream)
}

fn request(stream: &mut TcpStream, req: Request) -> Result<Response> {
    wire::write_frame(stream, &Frame::Request(Box::new(req)))?;
    match wire::read_frame(stream)? {
        Some((Frame::Response { resp, .. }, _)) => Ok(*resp),
        Some((f, _)) => bail!("expected a Response frame, got {f:?}"),
        None => bail!("predict server closed the connection mid-request"),
    }
}

/// Ask the server for its model shapes (m, q, d).
pub fn remote_model_info(stream: &mut TcpStream) -> Result<(usize, usize, usize)> {
    match request(stream, Request::ModelInfo)? {
        Response::ModelInfo { m, q, d } => Ok((m as usize, q as usize, d as usize)),
        Response::Err(e) => bail!("predict server: {e}"),
        other => bail!("unexpected ModelInfo reply {other:?}"),
    }
}

/// Predict a batch remotely. Every f64 crosses the wire bit-for-bit,
/// so the reply equals a local [`Predictor::predict`] exactly.
pub fn remote_predict(
    stream: &mut TcpStream,
    xt_mu: &Matrix,
    xt_var: &Matrix,
) -> Result<(Matrix, Vec<f64>)> {
    let resp = request(
        stream,
        Request::ServePredict {
            xt_mu: xt_mu.clone(),
            xt_var: xt_var.clone(),
        },
    )?;
    match resp {
        Response::Predict { mean, var } => Ok((mean, var)),
        Response::Err(e) => bail!("predict server: {e}"),
        other => bail!("unexpected predict reply {other:?}"),
    }
}

/// Politely hang up (the server counts the connection as finished on
/// EOF too; this just makes the intent explicit).
pub fn hangup(stream: &mut TcpStream) {
    let _ = wire::write_frame(stream, &Frame::Shutdown);
}
