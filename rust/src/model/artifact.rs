//! The serializable model artifact: binary format, strict validation.
//!
//! ## File layout
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "GPMA"
//! 4       2     format version (u16 LE) — mismatch is rejected on load
//! 6       1     kind (1 = TrainedModel, 2 = Checkpoint)
//! 7       4     payload length (u32 LE)
//! 11      len   payload (kind-specific, wire `Enc`/`Dec` encoding)
//! 11+len  8     FNV-1a 64-bit checksum of the payload (u64 LE)
//! ```
//!
//! The payload reuses the cluster wire protocol's encoding primitives
//! ([`Enc`]/[`Dec`]): little-endian integers, f64 via
//! `to_le_bytes` — every parameter round-trips **bit-for-bit**, so a
//! saved model predicts bit-identically to the trainer that exported
//! it (tested in `tests/model.rs`). Loading validates, in order: file
//! length, magic, format version, kind, payload length, checksum,
//! exact payload consumption, then shapes and finiteness — a corrupt
//! or mismatched file fails with a descriptive error instead of ever
//! mispredicting.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::cluster::wire::{Dec, Enc};
use crate::gp::{GlobalParams, MathMode, PosteriorWeights};
use crate::linalg::Matrix;

/// Artifact file magic: "GPMA" (GParML Model Artifact).
pub const MAGIC: [u8; 4] = *b"GPMA";
/// Current artifact format version. Bump on any layout change.
pub const FORMAT_VERSION: u16 = 1;

const KIND_MODEL: u8 = 1;
const KIND_CHECKPOINT: u8 = 2;
const HEADER_LEN: usize = 11;
const CHECKSUM_LEN: usize = 8;

/// Training provenance carried inside a [`TrainedModel`].
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMeta {
    /// Artifact (shape) configuration the cluster trained under.
    pub artifact: String,
    /// Outer iterations the exporting trainer had completed.
    pub iterations: u64,
    /// Bound F at the last completed iteration (NaN if none ran).
    pub final_bound: f64,
    /// Training seed.
    pub seed: u64,
}

/// The self-contained product of training: everything the serving path
/// needs, nothing the cluster needs. O(m·(m + q + d)) scalars —
/// constant in the dataset size, exactly the paper's point that the
/// posterior lives on the m inducing points.
#[derive(Debug, Clone)]
pub struct TrainedModel {
    /// Global parameters G = (Z, log lengthscales, log sf2, log beta).
    pub params: GlobalParams,
    /// Posterior weights (w1, wv, q(u) moments) at those parameters.
    pub weights: PosteriorWeights,
    /// Output dimensionality d.
    pub dout: usize,
    /// Kmm jitter the trainer used (provenance; prediction consumes the
    /// already-factored weights and never refactors Kmm).
    pub jitter: f64,
    /// Execution policy training ran under. Serving always runs the
    /// strict kernels; the mode records how the weights were produced.
    pub math_mode: MathMode,
    pub meta: ModelMeta,
}

impl TrainedModel {
    pub fn m(&self) -> usize {
        self.params.m()
    }

    pub fn q(&self) -> usize {
        self.params.q()
    }

    /// Inducing inputs Z [m x q] — the latent-space anchors the
    /// posterior lives on.
    pub fn inducing_inputs(&self) -> &Matrix {
        &self.params.z
    }

    /// The inducing posterior q(u) moments: (mean [m x d], cov [m x m]).
    /// Everything the LVM latent-projection serving path consumes.
    pub fn latent_posterior(&self) -> (&Matrix, &Matrix) {
        (&self.weights.qu_mean, &self.weights.qu_cov)
    }

    /// Trained observation-noise precision beta = exp(log_beta).
    pub fn noise_precision(&self) -> f64 {
        self.params.log_beta.exp()
    }

    /// Strict structural validation: shapes consistent, every number
    /// finite (the provenance `final_bound` may be NaN — a model can be
    /// exported before any iteration ran).
    pub fn validate(&self) -> Result<()> {
        let (m, q, d) = (self.m(), self.q(), self.dout);
        ensure!(m > 0 && q > 0 && d > 0, "degenerate model shapes (m={m}, q={q}, d={d})");
        ensure!(
            self.params.log_ls.len() == q,
            "log lengthscales have length {} but Z has q={q} columns",
            self.params.log_ls.len()
        );
        let shape = |name: &str, mat: &crate::linalg::Matrix, rows: usize, cols: usize| {
            ensure!(
                mat.rows() == rows && mat.cols() == cols,
                "{name} is {}x{} but the model shapes (m={m}, q={q}, d={d}) require {rows}x{cols}",
                mat.rows(),
                mat.cols()
            );
            Ok(())
        };
        shape("w1", &self.weights.w1, m, d)?;
        shape("wv", &self.weights.wv, m, m)?;
        shape("qu_mean", &self.weights.qu_mean, m, d)?;
        shape("qu_cov", &self.weights.qu_cov, m, m)?;
        ensure!(
            self.jitter.is_finite() && self.jitter >= 0.0,
            "non-finite or negative jitter {}",
            self.jitter
        );
        let finite = |name: &str, vals: &[f64]| {
            ensure!(
                vals.iter().all(|v| v.is_finite()),
                "{name} contains a non-finite value — refusing to predict from it"
            );
            Ok(())
        };
        finite("Z", self.params.z.data())?;
        finite("log lengthscales", &self.params.log_ls)?;
        finite("log sf2 / log beta", &[self.params.log_sf2, self.params.log_beta])?;
        finite("w1", self.weights.w1.data())?;
        finite("wv", self.weights.wv.data())?;
        finite("qu_mean", self.weights.qu_mean.data())?;
        finite("qu_cov", self.weights.qu_cov.data())?;
        Ok(())
    }

    /// Serialise to bytes (header + payload + checksum).
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        self.validate()?;
        let mut e = Enc::new();
        e.params(&self.params);
        e.mat(&self.weights.w1);
        e.mat(&self.weights.wv);
        e.mat(&self.weights.qu_mean);
        e.mat(&self.weights.qu_cov);
        e.u32(self.dout as u32);
        e.f64(self.jitter);
        e.u8(self.math_mode.code());
        e.str(&self.meta.artifact);
        e.u64(self.meta.iterations);
        e.f64(self.meta.final_bound);
        e.u64(self.meta.seed);
        Ok(frame(KIND_MODEL, e.into_bytes()))
    }

    /// Deserialise from bytes with full validation.
    pub fn from_bytes(bytes: &[u8]) -> Result<TrainedModel> {
        let payload = unframe(bytes, KIND_MODEL)?;
        let mut d = Dec::new(payload);
        let params = d.params()?;
        let w1 = d.mat()?;
        let wv = d.mat()?;
        let qu_mean = d.mat()?;
        let qu_cov = d.mat()?;
        let dout = d.u32()? as usize;
        let jitter = d.f64()?;
        let mode_code = d.u8()?;
        let math_mode = MathMode::from_code(mode_code)
            .with_context(|| format!("unknown math mode code {mode_code} in model file"))?;
        let meta = ModelMeta {
            artifact: d.str()?,
            iterations: d.u64()?,
            final_bound: d.f64()?,
            seed: d.u64()?,
        };
        d.finish()?;
        let model = TrainedModel {
            params,
            weights: PosteriorWeights {
                w1,
                wv,
                qu_mean,
                qu_cov,
            },
            dout,
            jitter,
            math_mode,
            meta,
        };
        model.validate()?;
        Ok(model)
    }

    /// Write the artifact to `path` (atomically — see [`write_atomic`]).
    pub fn save(&self, path: &Path) -> Result<()> {
        let bytes = self.to_bytes()?;
        write_atomic(path, &bytes)
            .with_context(|| format!("writing model artifact {}", path.display()))
    }

    /// Load and validate an artifact from `path`.
    pub fn load(path: &Path) -> Result<TrainedModel> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading model artifact {}", path.display()))?;
        Self::from_bytes(&bytes)
            .with_context(|| format!("loading model artifact {}", path.display()))
    }
}

/// A mid-training snapshot of the global parameters — enough to resume
/// the outer SCG loop on a fresh cluster (the optimiser re-anchors;
/// worker-local q(X) state lives with the data shards, not here).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub params: GlobalParams,
    /// Outer iterations completed when the snapshot was taken.
    pub iterations: u64,
    /// Bound F at the last completed iteration (NaN if none ran).
    pub last_bound: f64,
    /// Artifact (shape) configuration of the saving trainer.
    pub artifact: String,
    pub math_mode: MathMode,
    pub seed: u64,
}

impl Checkpoint {
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        ensure!(
            self.params.z.data().iter().all(|v| v.is_finite())
                && self.params.log_ls.iter().all(|v| v.is_finite())
                && self.params.log_sf2.is_finite()
                && self.params.log_beta.is_finite(),
            "checkpoint parameters contain a non-finite value"
        );
        let mut e = Enc::new();
        e.params(&self.params);
        e.u64(self.iterations);
        e.f64(self.last_bound);
        e.str(&self.artifact);
        e.u8(self.math_mode.code());
        e.u64(self.seed);
        Ok(frame(KIND_CHECKPOINT, e.into_bytes()))
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        let payload = unframe(bytes, KIND_CHECKPOINT)?;
        let mut d = Dec::new(payload);
        let params = d.params()?;
        let iterations = d.u64()?;
        let last_bound = d.f64()?;
        let artifact = d.str()?;
        let mode_code = d.u8()?;
        let math_mode = MathMode::from_code(mode_code)
            .with_context(|| format!("unknown math mode code {mode_code} in checkpoint"))?;
        let seed = d.u64()?;
        d.finish()?;
        ensure!(
            params.m() > 0 && params.q() > 0 && params.log_ls.len() == params.q(),
            "checkpoint parameter shapes are inconsistent"
        );
        Ok(Checkpoint {
            params,
            iterations,
            last_bound,
            artifact,
            math_mode,
            seed,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.to_bytes()?)
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("loading checkpoint {}", path.display()))
    }
}

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

/// Write `bytes` to `path` via a same-directory temp file + rename, so
/// a crash mid-write can never truncate an existing artifact in place
/// — `train --checkpoint` rewrites the same file every iteration, and
/// the previous good snapshot must survive a kill at any instant.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(format!(".tmp.{}", std::process::id()));
    let tmp = std::path::PathBuf::from(tmp);
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| {
        let _ = std::fs::remove_file(&tmp);
        format!("renaming {} into place", tmp.display())
    })
}

/// FNV-1a 64-bit — catches byte-level corruption long before a wrong
/// number could reach a prediction.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in bytes {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn frame(kind: u8, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + CHECKSUM_LEN);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.push(kind);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    let sum = fnv1a64(&payload);
    out.extend_from_slice(&payload);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

fn unframe(bytes: &[u8], expect_kind: u8) -> Result<&[u8]> {
    ensure!(
        bytes.len() >= HEADER_LEN + CHECKSUM_LEN,
        "truncated artifact: {} bytes is smaller than the fixed framing",
        bytes.len()
    );
    ensure!(
        bytes[..4] == MAGIC,
        "bad artifact magic {:02x?} (expected GPMA — is this a gparml model file?)",
        &bytes[..4]
    );
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    ensure!(
        version == FORMAT_VERSION,
        "artifact format version mismatch: file is v{version}, this build reads v{FORMAT_VERSION}"
    );
    let kind = bytes[6];
    let kind_name = |k: u8| match k {
        KIND_MODEL => "TrainedModel",
        KIND_CHECKPOINT => "Checkpoint",
        _ => "unknown",
    };
    ensure!(
        kind == expect_kind,
        "artifact kind mismatch: file holds a {} (kind {kind}), expected a {} (kind {expect_kind})",
        kind_name(kind),
        kind_name(expect_kind)
    );
    let len = u32::from_le_bytes([bytes[7], bytes[8], bytes[9], bytes[10]]) as usize;
    ensure!(
        bytes.len() == HEADER_LEN + len + CHECKSUM_LEN,
        "truncated or oversized artifact: header claims a {len}-byte payload but the file \
         holds {} payload bytes",
        bytes.len().saturating_sub(HEADER_LEN + CHECKSUM_LEN)
    );
    let payload = &bytes[HEADER_LEN..HEADER_LEN + len];
    let stored = u64::from_le_bytes(bytes[HEADER_LEN + len..].try_into().unwrap());
    let actual = fnv1a64(payload);
    ensure!(
        stored == actual,
        "artifact checksum mismatch (stored {stored:#018x}, computed {actual:#018x}) — \
         the file is corrupt"
    );
    Ok(payload)
}

/// A structurally valid model with pseudo-random contents (unit-test
/// fixture shared by the `model` submodules).
#[cfg(test)]
pub(crate) fn sample_model(seed: u64, m: usize, q: usize, d: usize) -> TrainedModel {
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let params = GlobalParams {
        z: Matrix::from_fn(m, q, |_, _| rng.normal()),
        log_ls: (0..q).map(|_| 0.2 * rng.normal()).collect(),
        log_sf2: 0.1,
        log_beta: 1.3,
    };
    let sym = |rng: &mut Rng| Matrix::from_fn(m, m, |_, _| rng.normal()).symmetrize();
    TrainedModel {
        weights: PosteriorWeights {
            w1: Matrix::from_fn(m, d, |_, _| rng.normal()),
            wv: sym(&mut rng),
            qu_mean: Matrix::from_fn(m, d, |_, _| rng.normal()),
            qu_cov: sym(&mut rng),
        },
        params,
        dout: d,
        jitter: 1e-6,
        math_mode: MathMode::Strict,
        meta: ModelMeta {
            artifact: "test".into(),
            iterations: 17,
            final_bound: -123.456,
            seed,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn model_roundtrip_is_bitwise() {
        let m0 = sample_model(3, 6, 2, 3);
        let bytes = m0.to_bytes().unwrap();
        let m1 = TrainedModel::from_bytes(&bytes).unwrap();
        for (a, b) in m0.params.flatten().iter().zip(m1.params.flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (x, y) in [
            (&m0.weights.w1, &m1.weights.w1),
            (&m0.weights.wv, &m1.weights.wv),
            (&m0.weights.qu_mean, &m1.weights.qu_mean),
            (&m0.weights.qu_cov, &m1.weights.qu_cov),
        ] {
            assert_eq!(x.max_abs_diff(y), 0.0);
            for (a, b) in x.data().iter().zip(y.data()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        assert_eq!(m1.dout, 3);
        assert_eq!(m1.jitter, 1e-6);
        assert_eq!(m1.math_mode, MathMode::Strict);
        assert_eq!(m1.meta, m0.meta);
    }

    #[test]
    fn checkpoint_roundtrip_is_bitwise() {
        let model = sample_model(4, 5, 3, 2);
        let c0 = Checkpoint {
            params: model.params.clone(),
            iterations: 9,
            last_bound: -42.0,
            artifact: "small".into(),
            math_mode: MathMode::Fast,
            seed: 7,
        };
        let c1 = Checkpoint::from_bytes(&c0.to_bytes().unwrap()).unwrap();
        for (a, b) in c0.params.flatten().iter().zip(c1.params.flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(c1.iterations, 9);
        assert_eq!(c1.artifact, "small");
        assert_eq!(c1.math_mode, MathMode::Fast);
        assert_eq!(c1.seed, 7);
    }

    #[test]
    fn every_truncation_is_rejected() {
        let bytes = sample_model(5, 4, 2, 2).to_bytes().unwrap();
        for cut in 0..bytes.len() {
            let err = TrainedModel::from_bytes(&bytes[..cut]).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated") || msg.contains("magic"),
                "cut at {cut}: unhelpful error {msg}"
            );
        }
    }

    #[test]
    fn every_single_byte_corruption_is_rejected() {
        let bytes = sample_model(6, 4, 2, 2).to_bytes().unwrap();
        // flipping any single bit anywhere in the file must fail the
        // load — never silently change a prediction
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            assert!(
                TrainedModel::from_bytes(&bad).is_err(),
                "corruption at byte {i} was not detected"
            );
        }
    }

    #[test]
    fn wrong_version_kind_and_magic_are_rejected() {
        let bytes = sample_model(7, 3, 2, 2).to_bytes().unwrap();

        let mut v = bytes.clone();
        v[4] = 0xFF;
        let msg = format!("{:#}", TrainedModel::from_bytes(&v).unwrap_err());
        assert!(msg.contains("version"), "{msg}");

        let msg = format!("{:#}", Checkpoint::from_bytes(&bytes).unwrap_err());
        assert!(msg.contains("kind"), "{msg}");

        let mut g = bytes;
        g[0] = b'X';
        let msg = format!("{:#}", TrainedModel::from_bytes(&g).unwrap_err());
        assert!(msg.contains("magic"), "{msg}");
    }

    #[test]
    fn nonfinite_weights_are_rejected() {
        let mut m = sample_model(8, 3, 2, 2);
        m.weights.w1[(1, 0)] = f64::NAN;
        let msg = format!("{:#}", m.to_bytes().unwrap_err());
        assert!(msg.contains("non-finite"), "{msg}");
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let mut m = sample_model(9, 4, 2, 3);
        m.weights.w1 = Matrix::zeros(4, 2); // d says 3
        let msg = format!("{:#}", m.validate().unwrap_err());
        assert!(msg.contains("w1"), "{msg}");
    }
}
