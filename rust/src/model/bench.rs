//! `gparml bench predict` — machine-readable throughput benchmark of
//! the standalone [`Predictor`] serving path, single-threaded and
//! concurrent (`BENCH_predict.json`, same style as `BENCH_psi.json`).
//!
//! The concurrent series shares ONE `Predictor` across `--threads`
//! OS threads (each with its own [`PredictScratch`]), which is the
//! exact shape of the `gparml serve` hot path; per-thread times are
//! thread-CPU seconds, so the numbers are stable on the single-core
//! container (the modeled-cluster clock of DESIGN.md §5).

use anyhow::{Context, Result};

use super::artifact::{ModelMeta, TrainedModel};
use super::predictor::{PredictScratch, Predictor};
use crate::gp::{GlobalParams, MathMode, PosteriorWeights};
use crate::linalg::Matrix;
use crate::util::bench::bench;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats;

/// Run the predictor benchmark.
///
/// Flags: `--config` (artifact shape, default `perf`), `--points`
/// (batch size, default 512), `--reps`, `--threads` (default 4),
/// `--model PATH` (bench a real exported model instead of the
/// synthetic one), `--out` (default `BENCH_predict.json`),
/// `--artifacts DIR`.
pub fn run(args: &Args) -> Result<()> {
    let reps = args.get_usize("reps", 10)?.max(1);
    let threads = args.get_usize("threads", 4)?.max(1);
    let b = args.get_usize("points", 512)?.max(1);
    let out_path = args.get_str("out", "BENCH_predict.json");

    let (model, cfg_name) = match args.get("model") {
        Some(path) => (
            TrainedModel::load(std::path::Path::new(path))?,
            path.to_string(),
        ),
        None => {
            let cfg_name = args.get_str("config", "perf");
            let dir = args
                .get("artifacts")
                .map(std::path::PathBuf::from)
                .unwrap_or_else(crate::runtime::default_artifacts_dir);
            let manifest = crate::runtime::Manifest::load(&dir)?;
            let art = manifest.config(cfg_name)?;
            (synthetic_model(art.m, art.q, art.d, 42), cfg_name.to_string())
        }
    };
    let pred = Predictor::new(&model)?;
    let (m, q, d) = (pred.m(), pred.q(), pred.dout());

    let mut rng = Rng::new(7);
    let xt_mu = Matrix::from_fn(b, q, |_, _| rng.normal());
    let xt_var = Matrix::from_fn(b, q, |_, _| 0.1 * rng.uniform());

    println!("bench predict: {cfg_name} (b={b}, m={m}, q={q}, d={d}), {reps} reps, {threads} threads");

    // single-thread batched serving: one scratch, reused per batch
    let mut scratch = PredictScratch::new();
    let mut mean = Matrix::zeros(0, 0);
    let mut var = Vec::new();
    let single = bench("predict batched (1 thread)", 1, reps, || {
        pred.predict_into(&xt_mu, &xt_var, &mut scratch, &mut mean, &mut var)
            .unwrap();
    });

    // concurrent serving: the same Predictor shared by all threads —
    // the barrier model reports the slowest thread's median, i.e. what
    // a serve deployment would observe per batch under full load
    let per_thread: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let pred = &pred;
                let xt_mu = &xt_mu;
                let xt_var = &xt_var;
                s.spawn(move || {
                    let mut scratch = PredictScratch::new();
                    let mut mean = Matrix::zeros(0, 0);
                    let mut var = Vec::new();
                    let r = bench(&format!("predict batched (thread {t})"), 1, reps, || {
                        pred.predict_into(xt_mu, xt_var, &mut scratch, &mut mean, &mut var)
                            .unwrap();
                    });
                    r.median_s
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let concurrent_median = stats::max(&per_thread);

    let per_point = |median_s: f64| median_s * 1e9 / b as f64;
    println!(
        "standalone predictor: {:.0} ns/point batched, {:.0} ns/point under {threads}-way sharing",
        per_point(single.median_s),
        per_point(concurrent_median),
    );

    let json = format!(
        "{{\n  \"config\": \"{cfg_name}\",\n  \"points\": {b},\n  \"m\": {m},\n  \"q\": {q},\n  \
         \"d\": {d},\n  \"reps\": {reps},\n  \"threads\": {threads},\n  \
         \"predict_ns_per_point\": {:.1},\n  \"predict_concurrent_ns_per_point\": {:.1}\n}}\n",
        per_point(single.median_s),
        per_point(concurrent_median),
    );
    std::fs::write(out_path, json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// A structurally valid model at the given shapes with pseudo-random
/// weights — prediction cost does not depend on the values, only the
/// shapes, so the bench does not need a trained artifact on disk.
fn synthetic_model(m: usize, q: usize, d: usize, seed: u64) -> TrainedModel {
    let mut rng = Rng::new(seed);
    let params = GlobalParams {
        z: Matrix::from_fn(m, q, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0; q],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let sym = |rng: &mut Rng| Matrix::from_fn(m, m, |_, _| 0.1 * rng.normal()).symmetrize();
    TrainedModel {
        weights: PosteriorWeights {
            w1: Matrix::from_fn(m, d, |_, _| rng.normal()),
            wv: sym(&mut rng),
            qu_mean: Matrix::from_fn(m, d, |_, _| rng.normal()),
            qu_cov: sym(&mut rng),
        },
        params,
        dout: d,
        jitter: 1e-6,
        math_mode: MathMode::Strict,
        meta: ModelMeta {
            artifact: "synthetic-bench".into(),
            iterations: 0,
            final_bound: f64::NAN,
            seed,
        },
    }
}
