//! `gparml bench predict` — machine-readable throughput benchmark of
//! the standalone [`Predictor`] serving path, single-threaded,
//! concurrent, and end-to-end through the serving subsystem
//! (`BENCH_predict.json`, same style as `BENCH_psi.json`).
//!
//! The concurrent series shares ONE `Predictor` across `--threads`
//! OS threads (each with its own [`PredictScratch`]), which is the
//! exact shape of the serve worker pool; per-thread times are
//! thread-CPU seconds, so the numbers are stable on the single-core
//! container (the modeled-cluster clock of DESIGN.md §5).
//!
//! The multi-client serve series runs a real loopback server and
//! `--clients` concurrent TCP clients twice — micro-batching enabled
//! vs disabled — and reports per-request wall time (a request spans
//! threads, so the thread-CPU clock cannot see it; wall numbers are
//! noisier and deliberately NOT part of the `bench check` gate).
//! Every request latency is also recorded into an [`obs::Histogram`]
//! shared across the client threads, and the JSON reports the
//! histogram-derived p50/p99 ns/request for both serve series — the
//! same log-scale buckets `gparml stats` exposes from a live server.

use std::net::TcpListener;
use std::time::Instant;

use anyhow::{Context, Result};

use super::artifact::{ModelMeta, TrainedModel};
use super::predictor::{PredictScratch, Predictor};
use super::serve::{self, ServeClient, ServeOptions, ServeState, ServeStats};
use crate::fleet::{run_lb, LbOptions, LbStats, Upstream};
use crate::gp::{GlobalParams, MathMode, PosteriorWeights};
use crate::linalg::Matrix;
use crate::obs;
use crate::util::bench::bench;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats;

/// Run the predictor benchmark.
///
/// Flags: `--config` (artifact shape, default `perf`), `--points`
/// (batch size, default 512), `--reps`, `--threads` (default 4),
/// `--clients` (serve series, default 4), `--model PATH` (bench a
/// real exported model instead of the synthetic one), `--out`
/// (default `BENCH_predict.json`), `--artifacts DIR`.
pub fn run(args: &Args) -> Result<()> {
    let reps = args.get_usize("reps", 10)?.max(1);
    let threads = args.get_usize("threads", 4)?.max(1);
    let clients = args.get_usize("clients", 4)?.max(1);
    let b = args.get_usize("points", 512)?.max(1);
    let out_path = args.get_str("out", "BENCH_predict.json");

    let (model, cfg_name) = match args.get("model") {
        Some(path) => (
            TrainedModel::load(std::path::Path::new(path))?,
            path.to_string(),
        ),
        None => {
            let cfg_name = args.get_str("config", "perf");
            let dir = args
                .get("artifacts")
                .map(std::path::PathBuf::from)
                .unwrap_or_else(crate::runtime::default_artifacts_dir);
            let manifest = crate::runtime::Manifest::load(&dir)?;
            let art = manifest.config(cfg_name)?;
            (synthetic_model(art.m, art.q, art.d, 42), cfg_name.to_string())
        }
    };
    let pred = Predictor::new(&model)?;
    let (m, q, d) = (pred.m(), pred.q(), pred.dout());

    let mut rng = Rng::new(7);
    let xt_mu = Matrix::from_fn(b, q, |_, _| rng.normal());
    let xt_var = Matrix::from_fn(b, q, |_, _| 0.1 * rng.uniform());

    println!("bench predict: {cfg_name} (b={b}, m={m}, q={q}, d={d}), {reps} reps, {threads} threads");

    // single-thread batched serving: one scratch, reused per batch
    let mut scratch = PredictScratch::new();
    let mut mean = Matrix::zeros(0, 0);
    let mut var = Vec::new();
    let single = bench("predict batched (1 thread)", 1, reps, || {
        pred.predict_into(&xt_mu, &xt_var, &mut scratch, &mut mean, &mut var)
            .unwrap();
    });

    // concurrent serving: the same Predictor shared by all threads —
    // the barrier model reports the slowest thread's median, i.e. what
    // a serve deployment would observe per batch under full load
    let per_thread: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let pred = &pred;
                let xt_mu = &xt_mu;
                let xt_var = &xt_var;
                s.spawn(move || {
                    let mut scratch = PredictScratch::new();
                    let mut mean = Matrix::zeros(0, 0);
                    let mut var = Vec::new();
                    let r = bench(&format!("predict batched (thread {t})"), 1, reps, || {
                        pred.predict_into(xt_mu, xt_var, &mut scratch, &mut mean, &mut var)
                            .unwrap();
                    });
                    r.median_s
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let concurrent_median = stats::max(&per_thread);

    let per_point = |median_s: f64| median_s * 1e9 / b as f64;
    println!(
        "standalone predictor: {:.0} ns/point batched, {:.0} ns/point under {threads}-way sharing",
        per_point(single.median_s),
        per_point(concurrent_median),
    );

    // end-to-end through the serving subsystem: the same request load
    // from `clients` concurrent TCP clients, micro-batching on vs off
    let (batched_s, batched_stats, batched_hist) =
        serve_round(&model, &xt_mu, &xt_var, clients, reps, 4096)
            .context("bench serve round (batched)")?;
    let (unbatched_s, _, unbatched_hist) = serve_round(&model, &xt_mu, &xt_var, clients, reps, 0)
        .context("bench serve round (unbatched)")?;
    let pct = |h: &obs::Histogram, q: f64| h.percentile(q).unwrap_or(0);
    println!(
        "serve ({clients} clients x {b} points): {:.0} ns/point micro-batched \
         ({} kernel batches, {} coalesced jobs, p50 {} / p99 {} ns/request), \
         {:.0} ns/point unbatched (p50 {} / p99 {} ns/request)",
        per_point(batched_s),
        batched_stats.batches,
        batched_stats.coalesced_jobs,
        pct(&batched_hist, 0.50),
        pct(&batched_hist, 0.99),
        per_point(unbatched_s),
        pct(&unbatched_hist, 0.50),
        pct(&unbatched_hist, 0.99),
    );

    // the same load through the fleet front door: one replica behind a
    // static-upstream in-process lb, so the series isolates the lb's
    // per-request forwarding overhead (wall-clock and deliberately NOT
    // part of the `bench check` gate, like the other serve series)
    let (lb_s, lb_stats, lb_hist) =
        lb_round(&model, &xt_mu, &xt_var, clients, reps).context("bench lb round")?;
    println!(
        "lb ({clients} clients x {b} points, 1 replica): {:.0} ns/point through the \
         front door (p50 {} / p99 {} ns/request, {} failover(s))",
        per_point(lb_s),
        pct(&lb_hist, 0.50),
        pct(&lb_hist, 0.99),
        lb_stats.failovers,
    );

    let json = format!(
        "{{\n  \"config\": \"{cfg_name}\",\n  \"points\": {b},\n  \"m\": {m},\n  \"q\": {q},\n  \
         \"d\": {d},\n  \"reps\": {reps},\n  \"threads\": {threads},\n  \
         \"predict_ns_per_point\": {:.1},\n  \"predict_concurrent_ns_per_point\": {:.1},\n  \
         \"serve_clients\": {clients},\n  \"serve_batched_ns_per_point\": {:.1},\n  \
         \"serve_batched_kernel_batches\": {},\n  \"serve_batched_coalesced_jobs\": {},\n  \
         \"serve_batched_p50_ns_per_request\": {},\n  \
         \"serve_batched_p99_ns_per_request\": {},\n  \
         \"serve_unbatched_ns_per_point\": {:.1},\n  \
         \"serve_unbatched_p50_ns_per_request\": {},\n  \
         \"serve_unbatched_p99_ns_per_request\": {},\n  \
         \"lb_ns_per_point\": {:.1},\n  \
         \"lb_p50_ns_per_request\": {},\n  \
         \"lb_p99_ns_per_request\": {}\n}}\n",
        per_point(single.median_s),
        per_point(concurrent_median),
        per_point(batched_s),
        batched_stats.batches,
        batched_stats.coalesced_jobs,
        pct(&batched_hist, 0.50),
        pct(&batched_hist, 0.99),
        per_point(unbatched_s),
        pct(&unbatched_hist, 0.50),
        pct(&unbatched_hist, 0.99),
        per_point(lb_s),
        pct(&lb_hist, 0.50),
        pct(&lb_hist, 0.99),
    );
    std::fs::write(out_path, json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// One serve measurement: a loopback server (2 worker threads,
/// `batch_rows` micro-batch cap), `clients` concurrent TCP clients
/// each timing `reps` requests after one warm-up. Returns the slowest
/// client's median per-request wall seconds, the server's stats, and
/// the pooled per-request latency histogram (every timed request from
/// every client, for p50/p99 tail extraction).
fn serve_round(
    model: &TrainedModel,
    xt_mu: &Matrix,
    xt_var: &Matrix,
    clients: usize,
    reps: usize,
    batch_rows: usize,
) -> Result<(f64, ServeStats, obs::Histogram)> {
    let state = ServeState::new(Predictor::new(model)?);
    let opts = ServeOptions {
        max_clients: clients as u64,
        workers: 2,
        max_batch_rows: batch_rows,
    };
    let listener = TcpListener::bind("127.0.0.1:0").context("binding bench serve listener")?;
    let addr = listener.local_addr()?.to_string();
    let hist = obs::Histogram::new();

    std::thread::scope(|s| {
        let server = s.spawn(|| serve::serve(&listener, &state, &opts));
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let addr = &addr;
                let hist = &hist;
                s.spawn(move || -> Result<Vec<f64>> {
                    let mut client = ServeClient::connect(addr)?;
                    client.predict(xt_mu, xt_var)?; // warm-up
                    let mut times = Vec::with_capacity(reps);
                    for _ in 0..reps {
                        let t0 = Instant::now();
                        client.predict(xt_mu, xt_var)?;
                        let dt = t0.elapsed();
                        hist.record(dt.as_nanos() as u64);
                        times.push(dt.as_secs_f64());
                    }
                    client.hangup();
                    Ok(times)
                })
            })
            .collect();
        // join ALL clients before touching the server: an early `?`
        // here would leave the scope joining a server that still waits
        // for its Nth counted client — a hang instead of an error
        let mut medians = Vec::with_capacity(clients);
        let mut client_err = None;
        for h in handles {
            match h.join().expect("bench serve client panicked") {
                Ok(times) => medians.push(stats::median(&times)),
                Err(e) => client_err = Some(e),
            }
        }
        if client_err.is_some() {
            // failed clients may never have counted toward max_clients;
            // fire-and-forget Pings make up the count so the server can
            // exit (writing without reading cannot block)
            for _ in medians.len()..clients {
                if let Ok(mut s) = std::net::TcpStream::connect(addr.as_str()) {
                    let _ = crate::cluster::wire::write_frame(
                        &mut s,
                        &crate::cluster::wire::Frame::Ping,
                    );
                }
            }
        }
        let server_stats = server.join().expect("bench serve server panicked")?;
        match client_err {
            Some(e) => Err(e).context("bench serve client failed"),
            None => Ok((stats::max(&medians), server_stats)),
        }
    })
    .map(|(m, server_stats)| (m, server_stats, hist))
}

/// One lb measurement: a loopback replica behind a loopback
/// static-upstream `run_lb`, `clients` concurrent TCP clients each
/// timing `reps` requests through the front door after one warm-up.
/// Returns the slowest client's median per-request wall seconds, the
/// lb's stats, and the pooled latency histogram.
fn lb_round(
    model: &TrainedModel,
    xt_mu: &Matrix,
    xt_var: &Matrix,
    clients: usize,
    reps: usize,
) -> Result<(f64, LbStats, obs::Histogram)> {
    let state = ServeState::new(Predictor::new(model)?);
    // the lb holds one backend link per client connection plus one
    // cached health-probe connection — all count toward the replica's
    // client budget, which is how both servers exit without a kill
    let serve_opts = ServeOptions {
        max_clients: clients as u64 + 1,
        workers: 2,
        max_batch_rows: 4096,
    };
    let replica_listener = TcpListener::bind("127.0.0.1:0").context("binding bench replica")?;
    let replica_addr = replica_listener.local_addr()?.to_string();
    let lb_listener = TcpListener::bind("127.0.0.1:0").context("binding bench lb")?;
    let lb_addr = lb_listener.local_addr()?.to_string();
    let lb_opts = LbOptions {
        max_clients: clients as u64,
        refresh_ms: 50,
        ..LbOptions::default()
    };
    let upstream = Upstream::Static(vec![replica_addr.clone()]);
    let hist = obs::Histogram::new();

    std::thread::scope(|s| {
        let replica = s.spawn(|| serve::serve(&replica_listener, &state, &serve_opts));
        let front = s.spawn(|| run_lb(&lb_listener, &upstream, &lb_opts));
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let (addr, hist) = (&lb_addr, &hist);
                s.spawn(move || -> Result<Vec<f64>> {
                    let mut client = ServeClient::connect(addr)?;
                    client.predict(xt_mu, xt_var)?; // warm-up
                    let mut times = Vec::with_capacity(reps);
                    for _ in 0..reps {
                        let t0 = Instant::now();
                        client.predict(xt_mu, xt_var)?;
                        let dt = t0.elapsed();
                        hist.record(dt.as_nanos() as u64);
                        times.push(dt.as_secs_f64());
                    }
                    client.hangup();
                    Ok(times)
                })
            })
            .collect();
        // join ALL clients before touching either server (see
        // serve_round for why an early `?` would hang the scope)
        let mut medians = Vec::with_capacity(clients);
        let mut client_err = None;
        for h in handles {
            match h.join().expect("bench lb client panicked") {
                Ok(times) => medians.push(stats::median(&times)),
                Err(e) => client_err = Some(e),
            }
        }
        if client_err.is_some() {
            // make up BOTH exit counts with fire-and-forget Pings so
            // neither server waits forever (Pings count as clients;
            // overshooting a reached count is harmless)
            let ping = |addr: &str| {
                if let Ok(mut sck) = std::net::TcpStream::connect(addr) {
                    let _ = crate::cluster::wire::write_frame(
                        &mut sck,
                        &crate::cluster::wire::Frame::Ping,
                    );
                }
            };
            for _ in medians.len()..clients {
                ping(&lb_addr);
            }
            for _ in 0..clients + 1 {
                ping(&replica_addr);
            }
        }
        let lb_stats = front.join().expect("bench lb panicked")?;
        let _ = replica.join().expect("bench replica panicked")?;
        match client_err {
            Some(e) => Err(e).context("bench lb client failed"),
            None => Ok((stats::max(&medians), lb_stats)),
        }
    })
    .map(|(median, lb_stats)| (median, lb_stats, hist))
}

/// A structurally valid model at the given shapes with pseudo-random
/// weights — prediction cost does not depend on the values, only the
/// shapes, so the bench does not need a trained artifact on disk.
fn synthetic_model(m: usize, q: usize, d: usize, seed: u64) -> TrainedModel {
    let mut rng = Rng::new(seed);
    let params = GlobalParams {
        z: Matrix::from_fn(m, q, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0; q],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let sym = |rng: &mut Rng| Matrix::from_fn(m, m, |_, _| 0.1 * rng.normal()).symmetrize();
    TrainedModel {
        weights: PosteriorWeights {
            w1: Matrix::from_fn(m, d, |_, _| rng.normal()),
            wv: sym(&mut rng),
            qu_mean: Matrix::from_fn(m, d, |_, _| rng.normal()),
            qu_cov: sym(&mut rng),
        },
        params,
        dout: d,
        jitter: 1e-6,
        math_mode: MathMode::Strict,
        meta: ModelMeta {
            artifact: "synthetic-bench".into(),
            iterations: 0,
            final_bound: f64::NAN,
            seed,
        },
    }
}
