//! The cluster-free serving path: a read-only, `Send + Sync`
//! [`Predictor`] built once from a [`TrainedModel`].
//!
//! Prediction is O(t · m · (m + q + d)) for a batch of t points —
//! constant in the training-set size, no map rounds, no workers. The
//! hot loop is allocation-free: [`Predictor::predict_into`] runs the
//! strict `gp::kernel` psi fills into a caller-owned
//! [`PredictScratch`] and assembles the mean through the `linalg`
//! `_into` workspace APIs, so a serving thread owns one scratch and
//! reuses it for every batch. The computed values are **bit-identical**
//! to `Trainer::predict` at the same parameters and weights (the same
//! strict expressions in the same order — tested in `tests/model.rs`).
//!
//! ## Thread-safety contract
//!
//! `Predictor` is immutable after construction and shares nothing
//! mutable, so one instance can serve any number of threads
//! concurrently (`&Predictor` is enough — no locking, no `Arc`
//! required inside a scope). All per-batch mutable state lives in the
//! `PredictScratch` each thread owns. Enforced at compile time by the
//! `Send + Sync` assertion below and exercised by the concurrent
//! serving tests.

use anyhow::{ensure, Result};

use super::artifact::TrainedModel;
use crate::gp::{kernel, GlobalParams};
use crate::linalg::Matrix;

/// Per-thread workspace for [`Predictor::predict_into`]: every buffer
/// the per-batch hot loop touches, reused across batches (zero heap
/// allocation once grown to the model's shapes).
pub struct PredictScratch {
    /// squared lengthscales exp(2 log_ls), length q
    ls2: Vec<f64>,
    /// per-point Psi1 denominators, length q
    dn: Vec<f64>,
    /// per-point Psi2 denominators, length q
    dn2: Vec<f64>,
    /// Psi1 block [t x m]
    psi1: Matrix,
    /// one-point Psi2 block, length m*m
    psi2: Vec<f64>,
    /// per-point inducing responsibilities (projection path), length m
    resp: Vec<f64>,
}

impl PredictScratch {
    pub fn new() -> PredictScratch {
        PredictScratch {
            ls2: Vec::new(),
            dn: Vec::new(),
            dn2: Vec::new(),
            psi1: Matrix::zeros(0, 0),
            psi2: Vec::new(),
            resp: Vec::new(),
        }
    }
}

impl Default for PredictScratch {
    fn default() -> PredictScratch {
        PredictScratch::new()
    }
}

/// Read-only serving handle: global parameters plus the posterior
/// factors, precomputed once at construction.
pub struct Predictor {
    params: GlobalParams,
    /// mean weights beta Sigma^-1 C, m x d
    w1: Matrix,
    /// variance weights Kmm^-1 - Sigma^-1, m x m
    wv: Matrix,
    /// inducing posterior mean q(u), m x d — the data-space codebook
    /// the latent-projection path matches observations against
    qu_mean: Matrix,
    /// observation-noise precision exp(log_beta), precomputed
    beta: f64,
    /// signal variance exp(log_sf2), precomputed
    sf2: f64,
    dout: usize,
    /// intra-batch parallelism for [`Self::predict_into`]
    /// (`--fill-threads`, DESIGN.md §11): batch rows split over fixed
    /// ranges computed from `(rows, threads)` only, so every value is
    /// bit-identical to the sequential loop. 1 = sequential.
    fill_threads: usize,
}

// The whole point of the serving split: one Predictor, many threads.
// (Compile-time proof; the runtime half is the concurrent serve test.)
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Predictor>();
};

impl Predictor {
    /// Build from a validated model, precomputing the posterior factors
    /// the per-batch loop consumes.
    pub fn new(model: &TrainedModel) -> Result<Predictor> {
        model.validate()?;
        Ok(Predictor {
            params: model.params.clone(),
            w1: model.weights.w1.clone(),
            wv: model.weights.wv.clone(),
            qu_mean: model.weights.qu_mean.clone(),
            beta: model.noise_precision(),
            sf2: model.params.sf2(),
            dout: model.dout,
            fill_threads: 1,
        })
    }

    /// Set the intra-batch parallelism for [`Self::predict_into`]
    /// (clamped to >= 1). Deterministic: any value produces the same
    /// bytes (tested), it only changes how many cores a large coalesced
    /// batch uses.
    pub fn set_fill_threads(&mut self, threads: usize) {
        self.fill_threads = threads.max(1);
    }

    /// The configured intra-batch parallelism.
    pub fn fill_threads(&self) -> usize {
        self.fill_threads
    }

    pub fn m(&self) -> usize {
        self.params.m()
    }

    pub fn q(&self) -> usize {
        self.params.q()
    }

    pub fn dout(&self) -> usize {
        self.dout
    }

    pub fn params(&self) -> &GlobalParams {
        &self.params
    }

    /// Batched posterior prediction at (possibly uncertain) test
    /// inputs: mean [t x d] and per-point variance [t], without
    /// observation noise — the allocating convenience wrapper around
    /// [`Self::predict_into`].
    pub fn predict(&self, xt_mu: &Matrix, xt_var: &Matrix) -> Result<(Matrix, Vec<f64>)> {
        let mut scratch = PredictScratch::new();
        let mut mean = Matrix::zeros(0, 0);
        let mut var = Vec::new();
        self.predict_into(xt_mu, xt_var, &mut scratch, &mut mean, &mut var)?;
        Ok((mean, var))
    }

    /// Batched prediction into caller-owned outputs. After the first
    /// batch at a given size every buffer (scratch, `mean`, `var`) is
    /// reused — the per-batch hot loop performs no heap allocation.
    pub fn predict_into(
        &self,
        xt_mu: &Matrix,
        xt_var: &Matrix,
        scratch: &mut PredictScratch,
        mean: &mut Matrix,
        var: &mut Vec<f64>,
    ) -> Result<()> {
        let (m, q) = (self.m(), self.q());
        ensure!(
            xt_mu.cols() == q && xt_var.cols() == q && xt_mu.rows() == xt_var.rows(),
            "test points are {}x{} / {}x{} but the model expects q={q} input dimensions",
            xt_mu.rows(),
            xt_mu.cols(),
            xt_var.rows(),
            xt_var.cols()
        );
        let t = xt_mu.rows();

        scratch.ls2.resize(q, 0.0);
        for (l2, l) in scratch.ls2.iter_mut().zip(&self.params.log_ls) {
            *l2 = (2.0 * l).exp();
        }
        scratch.dn.resize(q, 0.0);
        scratch.dn2.resize(q, 0.0);
        scratch.psi2.resize(m * m, 0.0);

        // mean = Psi1 W1 — the same strict fill + matmul expressions the
        // cluster predict path runs, so the bits agree; rows split over
        // fill_threads fixed ranges (bit-identical at any thread count)
        kernel::psi1_into_threaded(
            &self.params,
            xt_mu,
            xt_var,
            &scratch.ls2,
            self.sf2,
            self.fill_threads,
            &mut scratch.dn,
            &mut scratch.psi1,
        );
        scratch.psi1.matmul_into(&self.w1, mean);

        // var_i = sf2 - <Wv, Psi2_i> — per-row independent, so the same
        // row-range split applies; each thread writes a disjoint window
        var.clear();
        var.resize(t, 0.0);
        let ranges = kernel::fill_ranges(t, self.fill_threads);
        if ranges.len() == 1 {
            for (i, v) in var.iter_mut().enumerate() {
                *v = self.var_at(
                    xt_mu,
                    xt_var,
                    &scratch.ls2,
                    &mut scratch.dn2,
                    &mut scratch.psi2,
                    i,
                );
            }
        } else {
            let ls2: &[f64] = &scratch.ls2;
            let mut rest: &mut [f64] = var.as_mut_slice();
            std::thread::scope(|s| {
                for &(lo, hi) in &ranges {
                    let (chunk, r) = std::mem::take(&mut rest).split_at_mut(hi - lo);
                    rest = r;
                    s.spawn(move || {
                        // per-thread workspaces: the shared scratch
                        // buffers stay with the sequential path
                        let mut dn2 = vec![0.0; q];
                        let mut psi2 = vec![0.0; m * m];
                        for (v, i) in chunk.iter_mut().zip(lo..hi) {
                            *v = self.var_at(xt_mu, xt_var, ls2, &mut dn2, &mut psi2, i);
                        }
                    });
                }
            });
        }
        Ok(())
    }

    /// One point's predictive variance `sf2 - <Wv, Psi2_i>` — the exact
    /// expression of the sequential loop, factored out so the threaded
    /// row ranges evaluate the same bytes.
    fn var_at(
        &self,
        xt_mu: &Matrix,
        xt_var: &Matrix,
        ls2: &[f64],
        dn2: &mut [f64],
        psi2: &mut [f64],
        i: usize,
    ) -> f64 {
        kernel::psi2_point_into(
            &self.params.z,
            ls2,
            self.sf2,
            xt_mu.row(i),
            xt_var.row(i),
            dn2,
            psi2,
        );
        let s: f64 = self
            .wv
            .data()
            .iter()
            .zip(psi2.iter())
            .map(|(a, b)| a * b)
            .sum();
        self.sf2 - s
    }

    /// Latent projection: map observed outputs `y` [t x d] into the
    /// model's latent space, answered entirely from the inducing
    /// posterior — the allocating convenience wrapper around
    /// [`Self::project_into`].
    pub fn project(&self, y: &Matrix) -> Result<(Matrix, Vec<f64>)> {
        let mut scratch = PredictScratch::new();
        let mut xmu = Matrix::zeros(0, 0);
        let mut conf = Vec::new();
        self.project_into(y, &mut scratch, &mut xmu, &mut conf)?;
        Ok((xmu, conf))
    }

    /// Amortised LVM latent projection into caller-owned outputs.
    ///
    /// The inducing posterior is a compressed codebook of the trained
    /// mapping: q(u) places mass `qu_mean[j]` (in data space) at the
    /// latent anchor `Z[j]`. A new observation `y_i` is projected by
    /// responsibility-weighted kernel regression over that codebook,
    /// with the trained noise precision beta as the bandwidth:
    ///
    /// ```text
    /// r_ij ∝ exp(-beta/2 ||y_i - qu_mean_j||^2)   (normalised over j)
    /// xmu_i = sum_j r_ij Z_j
    /// conf_i = max_j r_ij                          (in (0, 1])
    /// ```
    ///
    /// This is the standard cheap initialiser for latent inference on a
    /// trained GPLVM (nearest-posterior-mean regression) — it costs
    /// O(t·m·(d+q)), needs nothing beyond the artifact, and is fully
    /// deterministic per row, so micro-batched serving is bit-identical
    /// to per-request evaluation. It is *not* a variational
    /// optimisation over x*; `conf` flags points the codebook explains
    /// poorly (low max responsibility).
    pub fn project_into(
        &self,
        y: &Matrix,
        scratch: &mut PredictScratch,
        xmu: &mut Matrix,
        conf: &mut Vec<f64>,
    ) -> Result<()> {
        let (m, q, d) = (self.m(), self.q(), self.dout);
        ensure!(
            y.cols() == d,
            "observations are {}x{} but the model outputs d={d} dimensions",
            y.rows(),
            y.cols()
        );
        let t = y.rows();
        scratch.resp.resize(m, 0.0);
        xmu.reset(t, q, 0.0);
        conf.clear();
        conf.reserve(t);
        for i in 0..t {
            let yi = y.row(i);
            // log-responsibilities, max-shifted for stability
            let mut emax = f64::NEG_INFINITY;
            for j in 0..m {
                let uj = self.qu_mean.row(j);
                let mut sq = 0.0;
                for (a, b) in yi.iter().zip(uj) {
                    let diff = a - b;
                    sq += diff * diff;
                }
                let e = -0.5 * self.beta * sq;
                scratch.resp[j] = e;
                if e > emax {
                    emax = e;
                }
            }
            let mut sum = 0.0;
            let mut top = 0.0;
            for r in scratch.resp.iter_mut() {
                *r = (*r - emax).exp();
                sum += *r;
                if *r > top {
                    top = *r;
                }
            }
            let row = xmu.row_mut(i);
            for (j, r) in scratch.resp.iter().enumerate() {
                let w = r / sum;
                for (o, z) in row.iter_mut().zip(self.params.z.row(j)) {
                    *o += w * z;
                }
            }
            conf.push(top / sum);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::bound::predict_native;
    use crate::model::artifact::sample_model;
    use crate::util::rng::Rng;

    #[test]
    fn predictor_matches_predict_native_bitwise() {
        let model = sample_model(11, 6, 2, 3);
        let pred = Predictor::new(&model).unwrap();
        let mut rng = Rng::new(12);
        let xt_mu = Matrix::from_fn(9, 2, |_, _| rng.normal());
        let xt_var = Matrix::from_fn(9, 2, |_, _| 0.1 * rng.uniform());
        let (mean, var) = pred.predict(&xt_mu, &xt_var).unwrap();
        let (mean_n, var_n) = predict_native(&model.params, &model.weights, &xt_mu, &xt_var);
        assert_eq!((mean.rows(), mean.cols()), (9, 3));
        for (a, b) in mean.data().iter().zip(mean_n.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "predictor mean diverged");
        }
        for (a, b) in var.iter().zip(&var_n) {
            assert_eq!(a.to_bits(), b.to_bits(), "predictor variance diverged");
        }
    }

    #[test]
    fn scratch_reuse_is_bitwise_stable_across_batch_shapes() {
        let model = sample_model(13, 5, 3, 2);
        let pred = Predictor::new(&model).unwrap();
        let mut rng = Rng::new(14);
        let big_mu = Matrix::from_fn(12, 3, |_, _| rng.normal());
        let big_var = Matrix::from_fn(12, 3, |_, _| 0.2 * rng.uniform());
        let small_mu = Matrix::from_fn(4, 3, |_, _| rng.normal());
        let small_var = Matrix::from_fn(4, 3, |_, _| 0.2 * rng.uniform());

        // one scratch reused across differently-sized batches must give
        // the same bits as fresh allocating calls
        let mut scratch = PredictScratch::new();
        let mut mean = Matrix::zeros(0, 0);
        let mut var = Vec::new();
        for (mu, xv) in [(&big_mu, &big_var), (&small_mu, &small_var), (&big_mu, &big_var)] {
            pred.predict_into(mu, xv, &mut scratch, &mut mean, &mut var).unwrap();
            let (mean_f, var_f) = pred.predict(mu, xv).unwrap();
            assert_eq!(mean.max_abs_diff(&mean_f), 0.0);
            assert_eq!(var, var_f);
        }
    }

    /// Threaded batch serving is bit-identical to the sequential path
    /// at every thread count (including more threads than rows) — the
    /// DESIGN.md §11 determinism contract on the serving side.
    #[test]
    fn threaded_predict_matches_sequential_bitwise() {
        let model = sample_model(17, 6, 2, 3);
        let seq = Predictor::new(&model).unwrap();
        let mut rng = Rng::new(18);
        let xt_mu = Matrix::from_fn(11, 2, |_, _| rng.normal());
        let xt_var = Matrix::from_fn(11, 2, |_, _| 0.1 * rng.uniform());
        let (mean_ref, var_ref) = seq.predict(&xt_mu, &xt_var).unwrap();
        for threads in [2, 3, 4, 32] {
            let mut pred = Predictor::new(&model).unwrap();
            pred.set_fill_threads(threads);
            assert_eq!(pred.fill_threads(), threads);
            let (mean, var) = pred.predict(&xt_mu, &xt_var).unwrap();
            for (a, b) in mean.data().iter().zip(mean_ref.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "threaded mean diverged");
            }
            for (a, b) in var.iter().zip(&var_ref) {
                assert_eq!(a.to_bits(), b.to_bits(), "threaded variance diverged");
            }
            // the empty batch stays well-defined under threading
            let empty = Matrix::zeros(0, 2);
            let (mean0, var0) = pred.predict(&empty, &empty).unwrap();
            assert_eq!(mean0.rows(), 0);
            assert!(var0.is_empty());
        }
    }

    #[test]
    fn shape_mismatch_is_a_clear_error() {
        let model = sample_model(15, 4, 2, 2);
        let pred = Predictor::new(&model).unwrap();
        let bad = Matrix::zeros(3, 5);
        let msg = format!("{:#}", pred.predict(&bad, &bad).unwrap_err());
        assert!(msg.contains("q=2"), "{msg}");
        let msg = format!("{:#}", pred.project(&bad).unwrap_err());
        assert!(msg.contains("d=2"), "{msg}");
    }

    /// Projection is per-row independent: splitting a batch any way
    /// gives the same bits as projecting it whole — the property that
    /// makes cross-client micro-batching bit-identical.
    #[test]
    fn project_rows_are_batch_independent_and_confident() {
        let model = sample_model(21, 7, 3, 4);
        let pred = Predictor::new(&model).unwrap();
        let mut rng = Rng::new(22);
        let y = Matrix::from_fn(10, 4, |_, _| rng.normal());

        let (xmu_all, conf_all) = pred.project(&y).unwrap();
        assert_eq!((xmu_all.rows(), xmu_all.cols()), (10, 3));
        assert!(conf_all.iter().all(|c| *c > 0.0 && *c <= 1.0), "{conf_all:?}");

        // one reused scratch over per-row singleton batches
        let mut scratch = PredictScratch::new();
        let mut xmu = Matrix::zeros(0, 0);
        let mut conf = Vec::new();
        for i in 0..10 {
            let yi = Matrix::from_fn(1, 4, |_, j| y[(i, j)]);
            pred.project_into(&yi, &mut scratch, &mut xmu, &mut conf).unwrap();
            for j in 0..3 {
                assert_eq!(
                    xmu[(0, j)].to_bits(),
                    xmu_all[(i, j)].to_bits(),
                    "projection row {i} diverged when batched"
                );
            }
            assert_eq!(conf[0].to_bits(), conf_all[i].to_bits());
        }

        // an observation sitting exactly on a codebook entry is matched
        // with dominant confidence and projects near its latent anchor
        let hit = Matrix::from_fn(1, 4, |_, j| model.weights.qu_mean[(2, j)]);
        let (xmu_hit, conf_hit) = pred.project(&hit).unwrap();
        assert!(conf_hit[0] > 0.5, "weak match: {}", conf_hit[0]);
        let anchor = model.params.z.row(2);
        let off: f64 = (0..3).map(|j| (xmu_hit[(0, j)] - anchor[j]).abs()).sum();
        assert!(off < 1.5, "projection far from its anchor: {off}");
    }
}
