//! Single-threaded reference trainer — the "GPy" stand-in.
//!
//! Identical numerics to the distributed coordinator (same artifacts,
//! same global step, same SCG) but no worker pool, no channels, no
//! barriers: the honest sequential comparator for the paper's Fig. 3
//! ("GPy running time, a sequential implementation of the inference").

use std::time::Instant;

use anyhow::Result;

use crate::gp::params::GlobalParams;
use crate::gp::{self, kernel};
use crate::linalg::Matrix;
use crate::optim::{Adam, Scg};
use crate::runtime::{Manifest, ShardData, ShardExecutor};

/// Sequential trainer over the whole dataset in one shard.
pub struct SequentialTrainer {
    exec: ShardExecutor,
    shard: ShardData,
    pub params: GlobalParams,
    dout: usize,
    jitter: f64,
    lvm: bool,
    local_lr: f64,
    scg: Option<Scg>,
    adam_mu: Option<Adam>,
    adam_ls: Option<Adam>,
    /// Bound value per iteration.
    pub history: Vec<f64>,
    /// Wall seconds per iteration (the Fig. 3 sequential series).
    pub iter_secs: Vec<f64>,
    last_f: f64,
    update_locals_next: bool,
    min_xvar: f64,
}

impl SequentialTrainer {
    pub fn new(
        manifest: &Manifest,
        artifact: &str,
        params: GlobalParams,
        shard: ShardData,
        lvm: bool,
        local_lr: f64,
    ) -> Result<SequentialTrainer> {
        let exec = ShardExecutor::new(manifest, artifact)?;
        let dout = exec.config().d;
        let dof = shard.xmu.rows() * shard.xmu.cols();
        Ok(SequentialTrainer {
            exec,
            shard,
            params,
            dout,
            jitter: 1e-6,
            lvm,
            local_lr,
            scg: None,
            adam_mu: if lvm { Some(Adam::new(dof, local_lr)) } else { None },
            adam_ls: if lvm { Some(Adam::new(dof, local_lr)) } else { None },
            history: Vec::new(),
            iter_secs: Vec::new(),
            last_f: f64::NAN,
            update_locals_next: false,
            min_xvar: 1e-6,
        })
    }

    fn eval(&mut self, theta: &[f64]) -> Result<(f64, Vec<f64>)> {
        let params = self.params.unflatten(theta);
        let stats = self.exec.shard_stats(&params, &self.shard)?;
        let kmm = kernel::kmm(&params, self.jitter);
        let (bv, adj) = gp::assemble_bound(&stats, &kmm, params.log_beta, self.dout)?;
        let (mut g, local) = self.exec.shard_grads(&params, &self.shard, &adj)?;
        if self.update_locals_next && self.lvm {
            self.update_locals_next = false;
            self.apply_local(&local.d_xmu, &local.d_xvar);
        }
        g.accumulate(&kernel::kmm_vjp(&params, &adj.d_kmm));
        g.d_log_beta = adj.d_log_beta;
        self.last_f = bv.f;
        Ok((-bv.f, g.flatten().iter().map(|v| -v).collect()))
    }

    fn apply_local(&mut self, d_xmu: &Matrix, d_xvar: &Matrix) {
        let g_mu: Vec<f64> = d_xmu.data().iter().map(|g| -g).collect();
        let g_ls: Vec<f64> = d_xvar
            .data()
            .iter()
            .zip(self.shard.xvar.data())
            .map(|(g, s)| -g * s)
            .collect();
        self.adam_mu
            .as_mut()
            .unwrap()
            .step(self.shard.xmu.data_mut(), &g_mu);
        let mut log_s: Vec<f64> = self
            .shard
            .xvar
            .data()
            .iter()
            .map(|s| s.max(self.min_xvar).ln())
            .collect();
        self.adam_ls.as_mut().unwrap().step(&mut log_s, &g_ls);
        for (s, l) in self.shard.xvar.data_mut().iter_mut().zip(&log_s) {
            *s = l.exp().max(self.min_xvar);
        }
    }

    /// One outer iteration; mirrors `coordinator::Trainer::step`.
    pub fn step(&mut self) -> Result<f64> {
        let t0 = Instant::now();
        let mut scg = self.scg.take();
        let theta0 = self.params.flatten();
        self.update_locals_next = self.lvm;
        {
            let mut err: Option<anyhow::Error> = None;
            let mut obj = |x: &[f64]| match self.eval(x) {
                Ok(v) => v,
                Err(e) => {
                    err = Some(e);
                    (f64::INFINITY, vec![0.0; x.len()])
                }
            };
            match scg.as_mut() {
                None => scg = Some(Scg::new(theta0, &mut obj)),
                Some(s) => s.refresh(&mut obj),
            }
            scg.as_mut().unwrap().step(&mut obj);
            if let Some(e) = err {
                return Err(e);
            }
        }
        let scg = scg.expect("initialised");
        self.params = self.params.unflatten(scg.x());
        self.scg = Some(scg);
        self.history.push(self.last_f);
        self.iter_secs.push(t0.elapsed().as_secs_f64());
        Ok(self.last_f)
    }

    pub fn train(&mut self, iters: usize) -> Result<f64> {
        let mut f = f64::NAN;
        for _ in 0..iters {
            f = self.step()?;
        }
        Ok(f)
    }

    /// Current bound without stepping.
    pub fn evaluate(&mut self) -> Result<f64> {
        let theta = self.params.flatten();
        let (nf, _) = self.eval(&theta)?;
        Ok(-nf)
    }

    pub fn locals(&self) -> (&Matrix, &Matrix) {
        (&self.shard.xmu, &self.shard.xvar)
    }

    pub fn posterior(&mut self) -> Result<gp::PosteriorWeights> {
        let stats = self.exec.shard_stats(&self.params, &self.shard)?;
        let kmm = kernel::kmm(&self.params, self.jitter);
        gp::bound::posterior_weights(&stats, &kmm, self.params.log_beta)
    }
}
