//! Baselines the paper compares against (or that anchor correctness):
//!
//! * [`sequential`] — single-threaded trainer with identical numerics
//!   (stands in for GPy in Figs. 3-4: same bound, no distribution).
//! * [`svi`] — the Hensman et al. (2013) explicit-q(u) bound (related
//!   work §6; drives the Fig. 8 fixed-vs-optimal q(u) experiment).
//! * full GP — exact O(n^3) regression lives in [`crate::gp::exact`].
//! * PCA — the linear embedding baseline lives in [`crate::data::pca`].

pub mod sequential;
pub mod svi;
