//! The Hensman et al. (2013) "GPs for big data" bound with an EXPLICIT
//! variational distribution q(u) = N(m_u, S) — the related-work
//! comparison of paper §6 and the engine behind Fig. 8.
//!
//! For sparse GP regression with outputs Y (n x d), shared S across
//! output dimensions:
//!
//! ```text
//! F_svi(m_u, S) = sum_i [ log N(y_i; k_i^T Kmm^-1 m_u, beta^-1)
//!                        - beta/2 (K_ii - k_i^T Kmm^-1 k_i)
//!                        - beta d/2 k_i^T Kmm^-1 S Kmm^-1 k_i / d ... ]
//!               - KL(q(u) || N(0, Kmm))
//! ```
//!
//! The key property (tested below and plotted in Fig. 8): maximising
//! F_svi over (m_u, S) recovers the collapsed Titsias bound exactly —
//! but at any FIXED q(u), the landscape over the inducing-point
//! locations Z is different, which is the paper's §6 argument for why
//! SVI must pin Z while the collapsed parametrisation can optimise it.

use anyhow::Result;

use crate::gp::params::GlobalParams;
use crate::gp::{kernel, Stats};
use crate::linalg::{Cholesky, Matrix};

/// An explicit variational distribution over the inducing outputs:
/// mean m_u (m x d), covariance S (m x m, shared across output dims).
#[derive(Debug, Clone)]
pub struct ExplicitQu {
    pub mean: Matrix,
    pub cov: Matrix,
}

/// Evaluate the Hensman bound at a fixed q(u). X observed (regression).
pub fn svi_bound(
    p: &GlobalParams,
    qu: &ExplicitQu,
    x: &Matrix,
    y: &Matrix,
    jitter: f64,
) -> Result<f64> {
    let (n, d) = (y.rows(), y.cols() as f64);
    let beta = p.beta();
    let kmm = kernel::kmm(p, jitter);
    let chol = Cholesky::new_with_jitter(&kmm, 1e-10, 8)?;
    let knm = kernel::seard(x, &p.z, p); // n x m
    let kinv_kmn = chol.solve(&knm.transpose()); // m x n  (Kmm^-1 k_i columns)

    // predictive means at the training points: A^T m_u with A = Kmm^-1 Kmn
    let mean = kinv_kmn.t_matmul(&qu.mean); // n x d

    let sf2 = p.sf2();
    let mut f = 0.0;
    // log-likelihood terms
    f += -0.5 * n as f64 * d * ((2.0 * std::f64::consts::PI).ln() - p.log_beta);
    for i in 0..n {
        let mut se = 0.0;
        for j in 0..y.cols() {
            let r = y[(i, j)] - mean[(i, j)];
            se += r * r;
        }
        f -= 0.5 * beta * se;

        // k_i^T Kmm^-1 k_i
        let mut kqk = 0.0;
        // k_i^T Kmm^-1 S Kmm^-1 k_i
        let mut ksk = 0.0;
        for a in 0..p.m() {
            kqk += knm[(i, a)] * kinv_kmn[(a, i)];
            for b in 0..p.m() {
                ksk += kinv_kmn[(a, i)] * qu.cov[(a, b)] * kinv_kmn[(b, i)];
            }
        }
        // trace corrections (each output dim pays them once)
        f -= 0.5 * beta * d * (sf2 - kqk);
        f -= 0.5 * beta * d * ksk;
    }

    // KL(N(m_u, S) || N(0, Kmm)), S shared across d output dims
    let chol_s = Cholesky::new_with_jitter(&qu.cov, 1e-12, 8)?;
    let m = p.m() as f64;
    let tr = chol.solve(&qu.cov).trace();
    let kinv_mu = chol.solve(&qu.mean);
    let maha = qu.mean.dot(&kinv_mu);
    let kl = 0.5 * d * (tr - m + chol.log_det() - chol_s.log_det()) + 0.5 * maha;
    Ok(f - kl)
}

/// The optimal q(u) for the current statistics (the collapsed solution):
/// mean = beta Kmm Sigma^-1 C, cov = Kmm Sigma^-1 Kmm.
pub fn optimal_qu(p: &GlobalParams, stats: &Stats, jitter: f64) -> Result<ExplicitQu> {
    let kmm = kernel::kmm(p, jitter);
    let w = crate::gp::bound::posterior_weights(stats, &kmm, p.log_beta)?;
    Ok(ExplicitQu {
        mean: w.qu_mean,
        cov: w.qu_cov,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{self};
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (GlobalParams, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let n = 30;
        let x = Matrix::from_fn(n, 1, |_, _| rng.range(-2.0, 2.0));
        let y = Matrix::from_fn(n, 2, |i, j| {
            (x[(i, 0)] * (1.0 + j as f64)).sin() + 0.05 * rng.normal()
        });
        let p = GlobalParams {
            z: Matrix::from_fn(7, 1, |i, _| -2.0 + i as f64 * 0.6),
            log_ls: vec![(0.7_f64).ln()],
            log_sf2: 0.0,
            log_beta: (100.0_f64).ln(),
        };
        (p, x, y)
    }

    #[test]
    fn optimal_qu_recovers_collapsed_bound() {
        // F_svi(q*) must equal the collapsed Titsias bound — the
        // analytic-optimum property the paper's derivation rests on.
        let (p, x, y) = setup(0);
        let jitter = 1e-8;
        let stats = kernel::shard_stats(&p, &x, &Matrix::zeros(x.rows(), 1), &y,
                                        &vec![1.0; x.rows()], 0.0);
        let kmm = kernel::kmm(&p, jitter);
        let (bv, _) = gp::assemble_bound(&stats, &kmm, p.log_beta, 2).unwrap();
        let qu = optimal_qu(&p, &stats, jitter).unwrap();
        let f_svi = svi_bound(&p, &qu, &x, &y, jitter).unwrap();
        assert!(
            (f_svi - bv.f).abs() < 1e-6 * (1.0 + bv.f.abs()),
            "F_svi(q*) = {f_svi} vs collapsed {}",
            bv.f
        );
    }

    #[test]
    fn any_other_qu_is_worse() {
        let (p, x, y) = setup(1);
        let jitter = 1e-8;
        let stats = kernel::shard_stats(&p, &x, &Matrix::zeros(x.rows(), 1), &y,
                                        &vec![1.0; x.rows()], 0.0);
        let qu = optimal_qu(&p, &stats, jitter).unwrap();
        let f_star = svi_bound(&p, &qu, &x, &y, jitter).unwrap();
        let mut rng = Rng::new(2);
        for _ in 0..5 {
            let perturbed = ExplicitQu {
                mean: Matrix::from_fn(qu.mean.rows(), qu.mean.cols(), |i, j| {
                    qu.mean[(i, j)] + 0.3 * rng.normal()
                }),
                cov: qu.cov.clone(),
            };
            let f = svi_bound(&p, &perturbed, &x, &y, jitter).unwrap();
            assert!(f < f_star, "perturbed q(u) beat the optimum: {f} > {f_star}");
        }
    }

    #[test]
    fn svi_bound_is_below_exact_marginal() {
        let (p, x, y) = setup(3);
        let stats = kernel::shard_stats(&p, &x, &Matrix::zeros(x.rows(), 1), &y,
                                        &vec![1.0; x.rows()], 0.0);
        let qu = optimal_qu(&p, &stats, 1e-8).unwrap();
        let f = svi_bound(&p, &qu, &x, &y, 1e-8).unwrap();
        let exact = gp::exact::log_marginal(&p, &x, &y).unwrap();
        assert!(f <= exact + 1e-8, "bound {f} above exact {exact}");
    }
}
