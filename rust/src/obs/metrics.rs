//! Live metrics: counters, gauges and fixed-bucket log-scale latency
//! histograms with exact percentile extraction at bucket boundaries.
//!
//! Everything here is lock-free on the record path (relaxed atomics);
//! the only lock is the registry's name map, taken when a metric
//! handle is first created (callers cache the `Arc` handles) and when
//! a snapshot is rendered. Histograms use a fixed geometric bucket
//! ladder shared by every instance: bounds grow by ×19/16 (≈ +18.75%,
//! integer math, so small values get exact single-value buckets) from
//! 0 up past 2^62 ns (~146 years) — ~260 buckets, 2 KiB per
//! histogram. `percentile(q)` reports the upper bound of the bucket
//! holding the q-quantile observation: exact whenever the recorded
//! values sit on bucket boundaries, and never more than one bucket
//! width (≤ 18.75%) high otherwise. Values beyond the top bound
//! saturate into the last bucket.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Json;

/// Monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (queue depth, model version, heartbeat age...).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Saturating decrement (a late `sub` can never wrap the gauge).
    pub fn sub(&self, n: u64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n);
            match self
                .0
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// The shared geometric bucket ladder: 0, 1, 2, ... then ×19/16 per
/// step (always advancing by at least 1), ending with the first bound
/// past 2^62. Built once per process.
pub fn bucket_bounds() -> &'static [u64] {
    static BOUNDS: OnceLock<Vec<u64>> = OnceLock::new();
    BOUNDS.get_or_init(|| {
        let mut b = vec![0u64];
        let mut last = 0u64;
        while last < (1u64 << 62) {
            let grown = ((last as u128 * 19) / 16) as u64;
            last = grown.max(last + 1);
            b.push(last);
        }
        b
    })
}

/// Fixed-bucket log-scale histogram (latency in ns by convention).
#[derive(Debug)]
pub struct Histogram {
    counts: Box<[AtomicU64]>,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: (0..bucket_bounds().len())
                .map(|_| AtomicU64::new(0))
                .collect(),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one observation (saturates into the top bucket).
    pub fn record(&self, v: u64) {
        let bounds = bucket_bounds();
        // first bucket whose upper bound holds v
        let idx = bounds.partition_point(|&b| b < v).min(bounds.len() - 1);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Upper bound of the bucket containing the q-quantile observation
    /// (q in (0, 1]); `None` when nothing has been recorded. Exact for
    /// values recorded on bucket boundaries.
    pub fn percentile(&self, q: f64) -> Option<u64> {
        let total = self.count();
        if total == 0 {
            return None;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum >= target {
                return Some(bucket_bounds()[i]);
            }
        }
        Some(*bucket_bounds().last().unwrap())
    }
}

/// A named set of live metrics, shared across threads by `Arc`
/// handles; `snapshot_json` renders a deterministic (BTreeMap-ordered)
/// JSON document — the payload of the wire `ServeStats` reply and the
/// `gparml stats` CLI.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    histograms: BTreeMap<String, Arc<Histogram>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut g = self.inner.lock().unwrap();
        g.counters.entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut g = self.inner.lock().unwrap();
        g.gauges.entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut g = self.inner.lock().unwrap();
        g.histograms.entry(name.to_string()).or_default().clone()
    }

    /// Deterministic snapshot:
    /// `{"counters":{..},"gauges":{..},"histograms":{name:{count,p50,p90,p99}}}`.
    /// Percentiles are `null` for empty histograms.
    pub fn snapshot_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let num = |v: u64| Json::Num(v as f64);
        let opt_num = |v: Option<u64>| v.map(|x| Json::Num(x as f64)).unwrap_or(Json::Null);
        let counters: BTreeMap<String, Json> = g
            .counters
            .iter()
            .map(|(k, c)| (k.clone(), num(c.get())))
            .collect();
        let gauges: BTreeMap<String, Json> = g
            .gauges
            .iter()
            .map(|(k, c)| (k.clone(), num(c.get())))
            .collect();
        let histograms: BTreeMap<String, Json> = g
            .histograms
            .iter()
            .map(|(k, h)| {
                let hj: BTreeMap<String, Json> = [
                    ("count".to_string(), num(h.count())),
                    ("p50".to_string(), opt_num(h.percentile(0.50))),
                    ("p90".to_string(), opt_num(h.percentile(0.90))),
                    ("p99".to_string(), opt_num(h.percentile(0.99))),
                ]
                .into_iter()
                .collect();
                (k.clone(), Json::Obj(hj))
            })
            .collect();
        Json::Obj(
            [
                ("counters".to_string(), Json::Obj(counters)),
                ("gauges".to_string(), Json::Obj(gauges)),
                ("histograms".to_string(), Json::Obj(histograms)),
            ]
            .into_iter()
            .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_ladder_is_strictly_increasing_from_zero() {
        let b = bucket_bounds();
        assert_eq!(b[0], 0);
        assert_eq!(b[1], 1);
        for w in b.windows(2) {
            assert!(w[1] > w[0], "bounds not increasing: {} -> {}", w[0], w[1]);
        }
        assert!(*b.last().unwrap() >= (1u64 << 62));
        // the ladder is log-scale: a few hundred buckets cover 2^62
        assert!(b.len() < 400, "ladder too long: {}", b.len());
    }

    #[test]
    fn empty_histogram_has_no_percentiles() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        for q in [0.01, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(h.percentile(q), None);
        }
    }

    #[test]
    fn percentiles_are_exact_at_bucket_boundaries() {
        // a single boundary value recorded repeatedly is reported
        // exactly at every percentile
        for &b in &[0u64, 1, 5, 6, 7, 1_000_000] {
            let bound = *bucket_bounds()
                .iter()
                .find(|&&x| x >= b)
                .expect("bound exists");
            let h = Histogram::new();
            for _ in 0..100 {
                h.record(bound);
            }
            for q in [0.5, 0.9, 0.99, 1.0] {
                assert_eq!(h.percentile(q), Some(bound), "q={q} bound={bound}");
            }
        }
        // small values (the +1 ramp of the ladder) are ALWAYS exact
        let h = Histogram::new();
        for v in 0..=6u64 {
            h.record(v);
        }
        assert_eq!(h.percentile(1.0 / 7.0), Some(0));
        assert_eq!(h.percentile(0.5), Some(3));
        assert_eq!(h.percentile(1.0), Some(6));
    }

    #[test]
    fn tail_percentiles_split_a_bimodal_distribution() {
        let h = Histogram::new();
        let fast = 1u64; // exact bucket
        let slow = *bucket_bounds().iter().find(|&&x| x >= 1_000_000).unwrap();
        for _ in 0..90 {
            h.record(fast);
        }
        for _ in 0..10 {
            h.record(slow);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.percentile(0.5), Some(fast));
        assert_eq!(h.percentile(0.90), Some(fast));
        assert_eq!(h.percentile(0.99), Some(slow));
    }

    #[test]
    fn top_bucket_saturates() {
        let h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX - 1);
        let top = *bucket_bounds().last().unwrap();
        assert_eq!(h.percentile(0.5), Some(top));
        assert_eq!(h.percentile(1.0), Some(top));
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn percentiles_are_monotone_in_q() {
        let h = Histogram::new();
        let mut v = 3u64;
        for _ in 0..1000 {
            v = v.wrapping_mul(6364136223846793005).wrapping_add(1);
            h.record(v >> 40); // spread over ~2^24
        }
        let mut last = 0;
        for q in [0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            let p = h.percentile(q).unwrap();
            assert!(p >= last, "percentile dropped at q={q}: {p} < {last}");
            last = p;
        }
    }

    #[test]
    fn registry_snapshot_is_deterministic_json() {
        let r = Registry::new();
        r.counter("requests").add(7);
        r.counter("requests").inc(); // same handle by name
        r.gauge("queue_depth").set(3);
        r.gauge("queue_depth").sub(5); // saturates at 0
        r.histogram("request_ns").record(6);
        let j = r.snapshot_json();
        let text = j.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed, j);
        assert_eq!(
            parsed
                .get("counters")
                .unwrap()
                .get("requests")
                .unwrap()
                .as_usize()
                .unwrap(),
            8
        );
        assert_eq!(
            parsed
                .get("gauges")
                .unwrap()
                .get("queue_depth")
                .unwrap()
                .as_usize()
                .unwrap(),
            0
        );
        let hist = parsed.get("histograms").unwrap().get("request_ns").unwrap();
        assert_eq!(hist.get("count").unwrap().as_usize().unwrap(), 1);
        assert_eq!(hist.get("p50").unwrap().as_usize().unwrap(), 6);
        // empty histograms render null percentiles
        let r2 = Registry::new();
        r2.histogram("empty_ns");
        let j2 = r2.snapshot_json();
        assert_eq!(
            j2.get("histograms").unwrap().get("empty_ns").unwrap().get("p50").unwrap(),
            &Json::Null
        );
    }
}
