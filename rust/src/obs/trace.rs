//! Structured event/span recorder with a JSONL sink.
//!
//! Disabled (the default) the whole layer is one relaxed atomic load
//! per call site — provably near-free on the strict hot path (gated by
//! `bench psi`'s `traced_eval` series). Enabled (`--trace-out FILE`),
//! each span/event formats one JSON line into a per-thread buffer
//! (no allocation after warm-up, no lock held while formatting) and
//! appends it to a shared `BufWriter` under a short mutex.
//!
//! Record schema (one JSON object per line):
//! `{"ev":"span"|"event","name":...,"id":<u64 trace id>,"ts_ns":<since
//! process trace epoch>,"tid":<small per-thread ordinal>}` plus
//! `"dur_ns"` for spans and an optional `"n"` payload for events
//! (batch sizes, psi-fill counts). Timestamps are monotonic
//! (`Instant`-based), never wall-clock.
//!
//! The trace id is wire-propagated (DESIGN.md §10): training spans are
//! tagged with the evaluation version, serve spans with the client's
//! request id, so one id follows a request across processes.

use std::cell::RefCell;
use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<BufWriter<File>>> = Mutex::new(None);
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Ambient trace id for code that sits below the call site that knows
/// the id (the TCP backend stamping leader->worker frames). Set by the
/// trainer at the start of each evaluation.
static CURRENT: AtomicU64 = AtomicU64::new(0);

/// Start recording to `path` (truncates). Idempotent re-init swaps the
/// sink atomically; records from other threads land in one file or the
/// other, never interleaved mid-line.
pub fn init(path: &Path) -> Result<()> {
    let f = File::create(path)
        .with_context(|| format!("creating trace sink {}", path.display()))?;
    EPOCH.get_or_init(Instant::now);
    let mut g = SINK.lock().unwrap();
    if let Some(mut old) = g.replace(BufWriter::new(f)) {
        let _ = old.flush();
    }
    drop(g);
    ENABLED.store(true, Ordering::SeqCst);
    Ok(())
}

/// Stop recording and flush+close the sink.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
    if let Ok(mut g) = SINK.lock() {
        if let Some(mut w) = g.take() {
            let _ = w.flush();
        }
    }
}

/// Is the recorder on? One relaxed load — the only cost a disabled
/// call site ever pays.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Flush buffered records to disk (call before process exit; the
/// static sink is never dropped).
pub fn flush() {
    if let Ok(mut g) = SINK.lock() {
        if let Some(w) = g.as_mut() {
            let _ = w.flush();
        }
    }
}

/// Set the ambient trace id (see [`current`]).
pub fn set_current(id: u64) {
    CURRENT.store(id, Ordering::Relaxed);
}

/// The ambient trace id last set by [`set_current`].
pub fn current() -> u64 {
    CURRENT.load(Ordering::Relaxed)
}

fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Small dense per-thread ordinal (stable within the process).
fn thread_ordinal() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static ID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    ID.with(|i| *i)
}

fn write_line(ev: &str, name: &str, id: u64, ts_ns: u64, dur_ns: Option<u64>, n: Option<u64>) {
    thread_local! {
        static BUF: RefCell<String> = RefCell::new(String::with_capacity(192));
    }
    BUF.with(|b| {
        let Ok(mut s) = b.try_borrow_mut() else {
            return; // re-entrant tracing: drop the inner record
        };
        s.clear();
        let _ = write!(
            s,
            "{{\"ev\":\"{ev}\",\"name\":\"{name}\",\"id\":{id},\"ts_ns\":{ts_ns},\"tid\":{}",
            thread_ordinal()
        );
        if let Some(d) = dur_ns {
            let _ = write!(s, ",\"dur_ns\":{d}");
        }
        if let Some(n) = n {
            let _ = write!(s, ",\"n\":{n}");
        }
        s.push_str("}\n");
        if let Ok(mut g) = SINK.lock() {
            if let Some(w) = g.as_mut() {
                let _ = w.write_all(s.as_bytes());
            }
        }
    });
}

/// Record a point event tagged with `trace_id`; `n` is a free payload
/// (batch size, psi-fill count, ...).
pub fn event(name: &str, trace_id: u64, n: u64) {
    if !enabled() {
        return;
    }
    write_line("event", name, trace_id, now_ns(), None, Some(n));
}

/// An open span: records `{name, id, ts_ns, dur_ns}` when dropped.
/// When tracing is disabled at open time the guard is inert (a single
/// atomic load each at open and drop).
#[must_use]
pub struct Span {
    name: &'static str,
    trace_id: u64,
    start_ns: Option<u64>,
    count: Option<u64>,
}

impl Span {
    /// Number of items the span covered (written as `"n"` on drop).
    pub fn set_count(&mut self, n: u64) {
        self.count = Some(n);
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(t0) = self.start_ns {
            if enabled() {
                let t1 = now_ns();
                write_line(
                    "span",
                    self.name,
                    self.trace_id,
                    t0,
                    Some(t1.saturating_sub(t0)),
                    self.count,
                );
            }
        }
    }
}

/// Open a span tagged with `trace_id`.
pub fn span(name: &'static str, trace_id: u64) -> Span {
    Span {
        name,
        trace_id,
        start_ns: enabled().then(now_ns),
        count: None,
    }
}
