//! Unified observability layer (DESIGN.md §10): structured
//! tracing with wire-propagated span context ([`trace`]) and a live
//! metrics registry with tail-latency histograms ([`metrics`]).
//!
//! Three layers:
//! 1. [`trace`] — lock-cheap span/event recorder with a JSONL sink
//!    (`--trace-out FILE`), monotonic timestamps, per-thread format
//!    buffers. Near-free (one relaxed atomic load) when disabled.
//! 2. Wire-propagated context — every leader→worker frame and serve
//!    request carries a u64 trace/request id (wire v6); workers echo
//!    it and tag their own spans with it, so one id follows a request
//!    across processes.
//! 3. [`metrics`] — counters, gauges and log-scale latency histograms
//!    with exact-at-boundary p50/p90/p99 extraction, aggregated in the
//!    serve worker pool and trainer, exposed over the `ServeStats`
//!    control frame and the `gparml stats --connect` CLI.

pub mod metrics;
pub mod trace;

pub use metrics::{Counter, Gauge, Histogram, Registry};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{SystemTime, UNIX_EPOCH};

/// A fresh (process-unique, time-seeded) trace/request id for a
/// client-originated request. Ids only need to be distinct within the
/// window one server observes, not cryptographically unique.
pub fn next_trace_id() -> u64 {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    static BASE: OnceLock<u64> = OnceLock::new();
    let base = *BASE.get_or_init(|| {
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        // spread pid into the high bits so concurrent clients started
        // the same nanosecond still diverge
        (nanos ^ ((std::process::id() as u64) << 40)) | 1
    });
    base.wrapping_add(COUNTER.fetch_add(1, Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_ids_are_distinct_and_nonzero() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, b);
        assert_ne!(a, 0);
    }
}
