//! Shard-local data types shared by both executor backends (PJRT and
//! native) and serialised over the cluster wire protocol.

use crate::linalg::Matrix;

/// One worker's slice of the dataset (variational means/variances of
/// q(X) plus targets). In the regression model `xvar` is all zeros and
/// `kl_weight` is 0.
#[derive(Debug, Clone)]
pub struct ShardData {
    pub xmu: Matrix,
    pub xvar: Matrix,
    pub y: Matrix,
    pub kl_weight: f64,
}

impl ShardData {
    pub fn len(&self) -> usize {
        self.xmu.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Gradients w.r.t. a shard's local parameters (raw variance space).
#[derive(Debug, Clone)]
pub struct LocalGrads {
    pub d_xmu: Matrix,
    pub d_xvar: Matrix,
}
