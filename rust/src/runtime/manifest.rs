//! `artifacts/manifest.json` reader: which HLO files exist, at which
//! static shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Static shape configuration of one artifact family.
#[derive(Debug, Clone)]
pub struct ArtifactConfig {
    pub name: String,
    /// number of inducing points
    pub m: usize,
    /// latent dimensionality
    pub q: usize,
    /// output dimensionality
    pub d: usize,
    /// shard capacity (padded block length B)
    pub cap: usize,
    /// Pallas grid block size
    pub block_n: usize,
    /// entry name -> HLO file name
    pub entries: BTreeMap<String, String>,
}

/// Parsed manifest plus the directory it lives in.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dtype: String,
    pub configs: BTreeMap<String, ArtifactConfig>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let doc = Json::from_file(&dir.join("manifest.json"))?;
        let dtype = doc.get("dtype")?.as_str()?.to_string();
        if dtype != "f64" {
            bail!("unsupported artifact dtype {dtype:?} (runtime expects f64)");
        }
        let mut configs = BTreeMap::new();
        for (name, cfg) in doc.get("configs")?.as_obj()? {
            let mut entries = BTreeMap::new();
            for (entry, file) in cfg.get("entries")?.as_obj()? {
                entries.insert(entry.clone(), file.as_str()?.to_string());
            }
            configs.insert(
                name.clone(),
                ArtifactConfig {
                    name: name.clone(),
                    m: cfg.get("m")?.as_usize()?,
                    q: cfg.get("q")?.as_usize()?,
                    d: cfg.get("d")?.as_usize()?,
                    cap: cfg.get("B")?.as_usize()?,
                    block_n: cfg.get("block_n")?.as_usize()?,
                    entries,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            dtype,
            configs,
        })
    }

    pub fn config(&self, name: &str) -> Result<&ArtifactConfig> {
        self.configs.get(name).with_context(|| {
            format!(
                "no artifact config {name:?}; available: {:?} (run `make artifacts`)",
                self.configs.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Absolute path of one entry's HLO file.
    pub fn entry_path(&self, cfg: &ArtifactConfig, entry: &str) -> Result<PathBuf> {
        let file = cfg
            .entries
            .get(entry)
            .with_context(|| format!("config {} lacks entry {entry:?}", cfg.name))?;
        Ok(self.dir.join(file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("gparml_man_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"dtype":"f64","configs":{"t":{"m":4,"q":2,"d":3,"B":16,
               "block_n":8,"entries":{"shard_stats":"shard_stats_t.hlo.txt"}}}}"#,
        )
        .unwrap();
        let man = Manifest::load(&dir).unwrap();
        let cfg = man.config("t").unwrap();
        assert_eq!(cfg.m, 4);
        assert_eq!(cfg.cap, 16);
        assert!(man.config("nope").is_err());
        assert!(man
            .entry_path(cfg, "shard_stats")
            .unwrap()
            .ends_with("shard_stats_t.hlo.txt"));
        assert!(man.entry_path(cfg, "missing").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_f32_manifest() {
        let dir = std::env::temp_dir().join(format!("gparml_man32_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"dtype":"f32","configs":{}}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
