//! Per-worker PJRT executor: compiles the four artifact entries once and
//! runs them for arbitrary-size shards by chunking to the artifact's
//! static capacity B with mask padding.

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::gp::params::{GlobalGrads, GlobalParams};
use crate::gp::{MathMode, Stats};
use crate::linalg::Matrix;

use super::manifest::{ArtifactConfig, Manifest};
use super::shard::{LocalGrads, ShardData};
use super::EvalToken;

/// A compiled set of artifact executables bound to one PJRT CPU client.
///
/// Not `Send`: each worker thread builds its own (matching the paper's
/// one-process-per-node model; compilation happens once at startup).
pub struct ShardExecutor {
    client: PjRtClient,
    cfg: ArtifactConfig,
    stats_exe: PjRtLoadedExecutable,
    grads_exe: PjRtLoadedExecutable,
    /// full psi passes executed (telemetry parity with the native
    /// executor; the AOT artifacts are separate fixed graphs, so every
    /// round is a pass — see `shard_grads_cached`)
    fills: std::cell::Cell<u64>,
    /// kmm/predict are off the per-iteration hot path and only used by
    /// the leader / prediction flows — compiled lazily so worker startup
    /// pays for exactly the two entries it runs every round
    /// (EXPERIMENTS.md §Perf: halves cluster startup time).
    kmm_exe: std::cell::OnceCell<PjRtLoadedExecutable>,
    predict_exe: std::cell::OnceCell<PjRtLoadedExecutable>,
    kmm_path: std::path::PathBuf,
    predict_path: std::path::PathBuf,
}

fn compile(client: &PjRtClient, path: &std::path::Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
    )
    .with_context(|| format!("parsing HLO {}", path.display()))?;
    let comp = XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

fn mat_lit(m: &Matrix) -> Result<Literal> {
    Ok(Literal::vec1(m.data()).reshape(&[m.rows() as i64, m.cols() as i64])?)
}

fn vec_lit(v: &[f64]) -> Literal {
    Literal::vec1(v)
}

fn lit_mat(l: &Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let v = l.to_vec::<f64>()?;
    anyhow::ensure!(v.len() == rows * cols, "literal size mismatch");
    Ok(Matrix::from_vec(rows, cols, v))
}

fn lit_scalar(l: &Literal) -> Result<f64> {
    let v = l.to_vec::<f64>()?;
    anyhow::ensure!(v.len() == 1, "expected 1-element literal");
    Ok(v[0])
}

impl ShardExecutor {
    /// Mode-aware constructor (API parity with the native executor's
    /// `from_config_mode`). The AOT artifact graphs implement only the
    /// **Strict** numerical contract, so `MathMode::Fast` is rejected
    /// here instead of silently running strict graphs under a fast
    /// label (ROADMAP: fast-path artifact variants).
    pub fn with_mode(manifest: &Manifest, config: &str, mode: MathMode) -> Result<ShardExecutor> {
        Self::with_mode_threads(manifest, config, mode, 1)
    }

    /// Mode + fill-threads constructor (API parity with the native
    /// executor's `from_config_threads`). The AOT graphs evaluate the
    /// whole shard as one fixed computation, so intra-worker row
    /// splitting does not apply; `fill_threads > 1` is rejected here
    /// instead of silently running sequentially under a parallel label.
    pub fn with_mode_threads(
        manifest: &Manifest,
        config: &str,
        mode: MathMode,
        fill_threads: usize,
    ) -> Result<ShardExecutor> {
        anyhow::ensure!(
            mode == MathMode::Strict,
            "math mode {mode} is not available on the PJRT executor: the AOT artifact \
             graphs implement the Strict contract only"
        );
        anyhow::ensure!(
            fill_threads <= 1,
            "fill threads {fill_threads} is not available on the PJRT executor: the AOT \
             artifact graphs evaluate the whole shard as one fixed computation"
        );
        Self::new(manifest, config)
    }

    /// The execution policy this executor runs under (always Strict on
    /// the artifact path; see [`ShardExecutor::with_mode`]).
    pub fn math_mode(&self) -> MathMode {
        MathMode::Strict
    }

    /// Build a client and compile all entries of `config`.
    pub fn new(manifest: &Manifest, config: &str) -> Result<ShardExecutor> {
        let cfg = manifest.config(config)?.clone();
        let client = PjRtClient::cpu()?;
        let stats_exe = compile(&client, &manifest.entry_path(&cfg, "shard_stats")?)?;
        let grads_exe = compile(&client, &manifest.entry_path(&cfg, "shard_grads")?)?;
        let kmm_path = manifest.entry_path(&cfg, "kmm_grads")?;
        let predict_path = manifest.entry_path(&cfg, "predict")?;
        Ok(ShardExecutor {
            client,
            cfg,
            stats_exe,
            grads_exe,
            fills: std::cell::Cell::new(0),
            kmm_exe: std::cell::OnceCell::new(),
            predict_exe: std::cell::OnceCell::new(),
            kmm_path,
            predict_path,
        })
    }

    // ---- evaluation lifecycle (API parity with the native executor) ------
    //
    // The AOT artifact set compiles `shard_stats` and `shard_grads` as two
    // independent fixed graphs, so psi intermediates cannot yet be carried
    // from round 1 to round 2 on this path (ROADMAP: buffer donation).
    // The cached entry points therefore run the fresh graphs; the token
    // keeps the worker-node protocol identical across executors.

    /// Start an evaluation at parameter version `version` (no state to
    /// invalidate on this executor).
    pub fn begin_eval(&self, version: u64) -> EvalToken {
        EvalToken::new(version)
    }

    /// Drop cached psi intermediates (none on the artifact path).
    pub fn invalidate_cache(&self) {}

    /// Cumulative count of full psi passes this executor executed.
    pub fn psi_fills(&self) -> u64 {
        self.fills.get()
    }

    /// Gradient rounds served from a cache: always 0 on this path.
    pub fn cache_hits(&self) -> u64 {
        0
    }

    /// Map step 1 under an evaluation token (fresh graph execution).
    pub fn shard_stats_cached(
        &self,
        _tok: &EvalToken,
        p: &GlobalParams,
        shard: &ShardData,
    ) -> Result<Stats> {
        self.shard_stats(p, shard)
    }

    /// Map step 2 under an evaluation token (fresh graph execution).
    pub fn shard_grads_cached(
        &self,
        _tok: &EvalToken,
        p: &GlobalParams,
        shard: &ShardData,
        adj: &crate::gp::Adjoints,
    ) -> Result<(GlobalGrads, LocalGrads)> {
        self.shard_grads(p, shard, adj)
    }

    fn kmm_exe(&self) -> Result<&PjRtLoadedExecutable> {
        if self.kmm_exe.get().is_none() {
            let exe = compile(&self.client, &self.kmm_path)?;
            let _ = self.kmm_exe.set(exe);
        }
        Ok(self.kmm_exe.get().expect("just set"))
    }

    fn predict_exe(&self) -> Result<&PjRtLoadedExecutable> {
        if self.predict_exe.get().is_none() {
            let exe = compile(&self.client, &self.predict_path)?;
            let _ = self.predict_exe.set(exe);
        }
        Ok(self.predict_exe.get().expect("just set"))
    }

    pub fn config(&self) -> &ArtifactConfig {
        &self.cfg
    }

    fn check_params(&self, p: &GlobalParams) -> Result<()> {
        anyhow::ensure!(
            p.m() == self.cfg.m && p.q() == self.cfg.q,
            "params (m={}, q={}) do not match artifact config {} (m={}, q={})",
            p.m(),
            p.q(),
            self.cfg.name,
            self.cfg.m,
            self.cfg.q
        );
        Ok(())
    }

    /// Pad rows [lo, hi) of `src` into a cap x cols matrix.
    fn pad(&self, src: &Matrix, lo: usize, hi: usize, cols: usize) -> Matrix {
        let mut out = Matrix::zeros(self.cfg.cap, cols);
        for (r, i) in (lo..hi).enumerate() {
            out.row_mut(r).copy_from_slice(&src.row(i)[..cols]);
        }
        out
    }

    /// Literals that do not change across the chunks of one shard pass
    /// (global parameters + kl weight). Hoisted out of the chunk loop:
    /// literal construction showed up in the hot-path profile
    /// (EXPERIMENTS.md §Perf).
    fn invariant_inputs(&self, p: &GlobalParams, kl_weight: f64) -> Result<[Literal; 4]> {
        Ok([
            mat_lit(&p.z)?,
            vec_lit(&p.log_ls),
            vec_lit(&[p.log_sf2]),
            vec_lit(&[kl_weight]),
        ])
    }

    fn chunk_inputs(
        &self,
        inv: &[Literal; 4],
        shard: &ShardData,
        lo: usize,
        hi: usize,
    ) -> Result<Vec<Literal>> {
        let cfg = &self.cfg;
        let mut mask = vec![0.0; cfg.cap];
        for v in mask.iter_mut().take(hi - lo) {
            *v = 1.0;
        }
        // clones of Literal are shallow C++ copies of the backing buffer;
        // cheaper than re-encoding the matrices every chunk
        Ok(vec![
            inv[0].clone(),
            inv[1].clone(),
            inv[2].clone(),
            mat_lit(&self.pad(&shard.xmu, lo, hi, cfg.q))?,
            mat_lit(&self.pad(&shard.xvar, lo, hi, cfg.q))?,
            mat_lit(&self.pad(&shard.y, lo, hi, cfg.d))?,
            vec_lit(&mask),
            inv[3].clone(),
        ])
    }

    fn run(&self, exe: &PjRtLoadedExecutable, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let bufs = exe.execute::<Literal>(inputs)?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Map step 1: the shard's partial statistics (chunked over cap).
    pub fn shard_stats(&self, p: &GlobalParams, shard: &ShardData) -> Result<Stats> {
        self.check_params(p)?;
        self.fills.set(self.fills.get() + 1);
        let cfg = &self.cfg;
        let mut total = Stats::zeros(cfg.m, cfg.d);
        let b = shard.len();
        let inv = self.invariant_inputs(p, shard.kl_weight)?;
        let mut lo = 0;
        while lo < b {
            let hi = (lo + cfg.cap).min(b);
            let inputs = self.chunk_inputs(&inv, shard, lo, hi)?;
            let out = self.run(&self.stats_exe, &inputs)?;
            anyhow::ensure!(out.len() == 5, "shard_stats returned {} outputs", out.len());
            total.a += lit_scalar(&out[0])?;
            total.psi0 += lit_scalar(&out[1])?;
            total.c.axpy(1.0, &lit_mat(&out[2], cfg.m, cfg.d)?);
            total.d.axpy(1.0, &lit_mat(&out[3], cfg.m, cfg.m)?);
            total.kl += lit_scalar(&out[4])?;
            total.n += (hi - lo) as f64;
            lo = hi;
        }
        Ok(total)
    }

    /// Map step 2: chain-rule the adjoints into partial global gradients
    /// and this shard's local gradients.
    pub fn shard_grads(
        &self,
        p: &GlobalParams,
        shard: &ShardData,
        adj: &crate::gp::Adjoints,
    ) -> Result<(GlobalGrads, LocalGrads)> {
        self.check_params(p)?;
        self.fills.set(self.fills.get() + 1);
        let cfg = &self.cfg;
        let b = shard.len();
        let mut g = GlobalGrads::zeros(cfg.m, cfg.q);
        let mut local = LocalGrads {
            d_xmu: Matrix::zeros(b, cfg.q),
            d_xvar: Matrix::zeros(b, cfg.q),
        };
        let inv = self.invariant_inputs(p, shard.kl_weight)?;
        let adj_inv = [
            vec_lit(&[adj.d_psi0]),
            mat_lit(&adj.d_c)?,
            mat_lit(&adj.d_d)?,
            vec_lit(&[adj.d_kl]),
        ];
        let mut lo = 0;
        while lo < b {
            let hi = (lo + cfg.cap).min(b);
            let mut inputs = self.chunk_inputs(&inv, shard, lo, hi)?;
            for l in &adj_inv {
                inputs.push(l.clone());
            }
            let out = self.run(&self.grads_exe, &inputs)?;
            anyhow::ensure!(out.len() == 5, "shard_grads returned {} outputs", out.len());
            g.d_z.axpy(1.0, &lit_mat(&out[0], cfg.m, cfg.q)?);
            let dls = out[1].to_vec::<f64>()?;
            for (acc, v) in g.d_log_ls.iter_mut().zip(&dls) {
                *acc += v;
            }
            g.d_log_sf2 += lit_scalar(&out[2])?;
            let dxmu = lit_mat(&out[3], cfg.cap, cfg.q)?;
            let dxvar = lit_mat(&out[4], cfg.cap, cfg.q)?;
            for (r, i) in (lo..hi).enumerate() {
                local.d_xmu.row_mut(i).copy_from_slice(dxmu.row(r));
                local.d_xvar.row_mut(i).copy_from_slice(dxvar.row(r));
            }
            lo = hi;
        }
        Ok((g, local))
    }

    /// Central direct term: Kmm and the pullback of dF/dKmm.
    pub fn kmm_grads(
        &self,
        p: &GlobalParams,
        adj_kmm: &Matrix,
    ) -> Result<(Matrix, GlobalGrads)> {
        self.check_params(p)?;
        let cfg = &self.cfg;
        let inputs = vec![
            mat_lit(&p.z)?,
            vec_lit(&p.log_ls),
            vec_lit(&[p.log_sf2]),
            mat_lit(adj_kmm)?,
        ];
        let out = self.run(self.kmm_exe()?, &inputs)?;
        anyhow::ensure!(out.len() == 4, "kmm_grads returned {} outputs", out.len());
        let kmm = lit_mat(&out[0], cfg.m, cfg.m)?;
        let mut g = GlobalGrads::zeros(cfg.m, cfg.q);
        g.d_z = lit_mat(&out[1], cfg.m, cfg.q)?;
        g.d_log_ls = out[2].to_vec::<f64>()?;
        g.d_log_sf2 = lit_scalar(&out[3])?;
        Ok((kmm, g))
    }

    /// Posterior prediction at (possibly uncertain) test inputs.
    /// Returns (mean [t x d], var [t]) without observation noise.
    pub fn predict(
        &self,
        p: &GlobalParams,
        xt_mu: &Matrix,
        xt_var: &Matrix,
        w1: &Matrix,
        wv: &Matrix,
    ) -> Result<(Matrix, Vec<f64>)> {
        self.check_params(p)?;
        let cfg = &self.cfg;
        let t = xt_mu.rows();
        let mut mean = Matrix::zeros(t, cfg.d);
        let mut var = vec![0.0; t];
        let mut lo = 0;
        while lo < t {
            let hi = (lo + cfg.cap).min(t);
            let inputs = vec![
                mat_lit(&p.z)?,
                vec_lit(&p.log_ls),
                vec_lit(&[p.log_sf2]),
                mat_lit(&self.pad(xt_mu, lo, hi, cfg.q))?,
                mat_lit(&self.pad(xt_var, lo, hi, cfg.q))?,
                mat_lit(w1)?,
                mat_lit(wv)?,
            ];
            let out = self.run(self.predict_exe()?, &inputs)?;
            anyhow::ensure!(out.len() == 2, "predict returned {} outputs", out.len());
            let mchunk = lit_mat(&out[0], cfg.cap, cfg.d)?;
            let vchunk = out[1].to_vec::<f64>()?;
            for (r, i) in (lo..hi).enumerate() {
                mean.row_mut(i).copy_from_slice(mchunk.row(r));
                var[i] = vchunk[r];
            }
            lo = hi;
        }
        Ok((mean, var))
    }
}
