//! Shard execution runtime.
//!
//! Two interchangeable executors implement the same [`ShardExecutor`]
//! API (shape checks, outputs, numerics contract):
//!
//! * **native** (default): the hand-written `gp::kernel` mirrors of the
//!   psi statistics and their adjoint chain rules — no external
//!   runtime, works everywhere, and lets cluster workers initialise
//!   from shapes alone ([`ShardExecutor::from_config`]).
//! * **pjrt** (`--features pjrt`): loads the AOT HLO-text artifacts
//!   produced by `python/compile/aot.py` and executes them via PJRT.
//!   One executor is created per worker thread (the `xla` crate's
//!   `PjRtClient` is `Rc`-based and not `Send`, which conveniently
//!   mirrors one-PJRT-client-per-node). Offline builds link the API
//!   stub in `rust/vendor/xla-stub`; swap in the real `xla` crate to
//!   execute artifacts.

mod manifest;
pub mod psibench;
mod shard;

#[cfg(feature = "pjrt")]
mod executor;
#[cfg(not(feature = "pjrt"))]
mod native;

pub use manifest::{ArtifactConfig, Manifest};
pub use shard::{LocalGrads, ShardData};

/// Handle for one bound/gradient evaluation of the two-round protocol,
/// carrying the **parameter version** both map rounds of the evaluation
/// run at. Obtained from [`ShardExecutor::begin_eval`]; passing it to
/// `shard_stats_cached` / `shard_grads_cached` keys the executor's psi
/// scratch so a gradient round can only consume intermediates computed
/// at the *same* version — SCG line-search trial points (each a fresh
/// version) can never alias a stale cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvalToken(u64);

impl EvalToken {
    pub fn new(version: u64) -> EvalToken {
        EvalToken(version)
    }

    pub fn version(&self) -> u64 {
        self.0
    }
}

#[cfg(feature = "pjrt")]
pub use executor::ShardExecutor;
#[cfg(not(feature = "pjrt"))]
pub use native::ShardExecutor;

/// Locate the artifacts directory: $GPARML_ARTIFACTS, ./artifacts, or
/// the checked-in rust/artifacts (shape manifest only) as a fallback so
/// `cargo run` works from the workspace root without `make artifacts`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Some(dir) = std::env::var_os("GPARML_ARTIFACTS") {
        return std::path::PathBuf::from(dir);
    }
    let local = std::path::PathBuf::from("artifacts");
    if local.join("manifest.json").exists() {
        return local;
    }
    let checked_in = std::path::PathBuf::from("rust/artifacts");
    if checked_in.join("manifest.json").exists() {
        return checked_in;
    }
    local
}

/// Build a Strict-mode executor for one artifact configuration. Native
/// builds need only the shapes; PJRT builds load and compile the HLO
/// entries from `artifacts_dir`.
pub fn build_executor(
    cfg: &ArtifactConfig,
    artifacts_dir: &std::path::Path,
) -> anyhow::Result<ShardExecutor> {
    build_executor_mode(cfg, artifacts_dir, crate::gp::MathMode::Strict)
}

/// Build an executor under an explicit [`crate::gp::MathMode`] — the
/// cluster workers' entry (the mode arrives in the wire `Init` frame).
/// The PJRT path only implements Strict and rejects Fast with a
/// descriptive error.
pub fn build_executor_mode(
    cfg: &ArtifactConfig,
    artifacts_dir: &std::path::Path,
    mode: crate::gp::MathMode,
) -> anyhow::Result<ShardExecutor> {
    build_executor_threads(cfg, artifacts_dir, mode, 1)
}

/// Build an executor with an explicit mode AND intra-worker fill
/// parallelism (`fill_threads`, from the wire `Init` frame or the
/// `--fill-threads` CLI flag; DESIGN.md §11). `fill_threads == 1` is
/// the sequential path on every executor; values above 1 enable the
/// deterministic row-range-split psi fill on the native executor and
/// are rejected on the PJRT path (whole-shard fixed graphs cannot be
/// row-split).
pub fn build_executor_threads(
    cfg: &ArtifactConfig,
    artifacts_dir: &std::path::Path,
    mode: crate::gp::MathMode,
    fill_threads: usize,
) -> anyhow::Result<ShardExecutor> {
    #[cfg(feature = "pjrt")]
    {
        let manifest = Manifest::load(artifacts_dir)?;
        ShardExecutor::with_mode_threads(&manifest, &cfg.name, mode, fill_threads)
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = artifacts_dir;
        Ok(ShardExecutor::from_config_threads(cfg.clone(), mode, fill_threads))
    }
}
