//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the coordinator's hot
//! path. Python never runs here — the artifacts are self-contained.
//!
//! One [`ShardExecutor`] is created per worker thread (the `xla` crate's
//! `PjRtClient` is `Rc`-based and not `Send`, which conveniently mirrors
//! one-PJRT-client-per-node), compiled once at startup, and reused for
//! every iteration.

mod executor;
mod manifest;

pub use executor::{LocalGrads, ShardData, ShardExecutor};
pub use manifest::{ArtifactConfig, Manifest};

/// Locate the artifacts directory: $GPARML_ARTIFACTS or ./artifacts.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("GPARML_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
