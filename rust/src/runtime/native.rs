//! Native executor: the default (no-PJRT) implementation of
//! [`ShardExecutor`], running the O(n m^2 q) shard statistics and
//! chain-rule gradients through the hand-written `gp::kernel` mirrors
//! instead of the AOT HLO artifacts.
//!
//! Identical API and numerics contract as the PJRT executor
//! (`executor.rs`, compiled under `--features pjrt`): same shape
//! checks, same outputs, validated against finite differences of the
//! assembled bound in `gp::kernel::tests`. Because it needs no
//! artifact files, cluster worker daemons can be initialised purely
//! from the shapes carried in the wire `Init` frame
//! ([`ShardExecutor::from_config`]).

use anyhow::Result;

use crate::gp::params::{GlobalGrads, GlobalParams};
use crate::gp::{kernel, Stats};
use crate::linalg::Matrix;

use super::manifest::{ArtifactConfig, Manifest};
use super::shard::{LocalGrads, ShardData};

/// Native stand-in for the compiled artifact set: holds only the shape
/// configuration; all compute is done by `gp::kernel`.
pub struct ShardExecutor {
    cfg: ArtifactConfig,
}

impl ShardExecutor {
    /// Manifest-based constructor (API-compatible with the PJRT
    /// executor; the HLO entry files are not touched).
    pub fn new(manifest: &Manifest, config: &str) -> Result<ShardExecutor> {
        Ok(ShardExecutor {
            cfg: manifest.config(config)?.clone(),
        })
    }

    /// Build directly from a shape configuration — no artifacts
    /// directory needed (used by TCP cluster workers, whose shapes
    /// arrive in the `Init` frame).
    pub fn from_config(cfg: ArtifactConfig) -> ShardExecutor {
        ShardExecutor { cfg }
    }

    pub fn config(&self) -> &ArtifactConfig {
        &self.cfg
    }

    fn check_params(&self, p: &GlobalParams) -> Result<()> {
        anyhow::ensure!(
            p.m() == self.cfg.m && p.q() == self.cfg.q,
            "params (m={}, q={}) do not match artifact config {} (m={}, q={})",
            p.m(),
            p.q(),
            self.cfg.name,
            self.cfg.m,
            self.cfg.q
        );
        Ok(())
    }

    /// Map step 1: the shard's partial statistics.
    pub fn shard_stats(&self, p: &GlobalParams, shard: &ShardData) -> Result<Stats> {
        self.check_params(p)?;
        let mask = vec![1.0; shard.len()];
        Ok(kernel::shard_stats(
            p,
            &shard.xmu,
            &shard.xvar,
            &shard.y,
            &mask,
            shard.kl_weight,
        ))
    }

    /// Map step 2: chain-rule the adjoints into partial global gradients
    /// and this shard's local gradients.
    pub fn shard_grads(
        &self,
        p: &GlobalParams,
        shard: &ShardData,
        adj: &crate::gp::Adjoints,
    ) -> Result<(GlobalGrads, LocalGrads)> {
        self.check_params(p)?;
        let (g, d_xmu, d_xvar) =
            kernel::shard_grads_vjp(p, &shard.xmu, &shard.xvar, &shard.y, shard.kl_weight, adj);
        Ok((g, LocalGrads { d_xmu, d_xvar }))
    }

    /// Central direct term: Kmm (no jitter) and the pullback of dF/dKmm.
    pub fn kmm_grads(&self, p: &GlobalParams, adj_kmm: &Matrix) -> Result<(Matrix, GlobalGrads)> {
        self.check_params(p)?;
        let kmm = kernel::seard(&p.z, &p.z, p);
        let g = kernel::kmm_vjp(p, adj_kmm);
        Ok((kmm, g))
    }

    /// Posterior prediction at (possibly uncertain) test inputs.
    /// Returns (mean [t x d], var [t]) without observation noise.
    pub fn predict(
        &self,
        p: &GlobalParams,
        xt_mu: &Matrix,
        xt_var: &Matrix,
        w1: &Matrix,
        wv: &Matrix,
    ) -> Result<(Matrix, Vec<f64>)> {
        self.check_params(p)?;
        let mean = kernel::psi1(p, xt_mu, xt_var).matmul(w1);
        let sf2 = p.sf2();
        let var = (0..xt_mu.rows())
            .map(|i| {
                let p2 = kernel::psi2_point(p, xt_mu.row(i), xt_var.row(i));
                sf2 - wv.dot(&p2)
            })
            .collect();
        Ok((mean, var))
    }
}
