//! Native executor: the default (no-PJRT) implementation of
//! [`ShardExecutor`], running the O(n m^2 q) shard statistics and
//! chain-rule gradients through the hand-written `gp::kernel` mirrors
//! instead of the AOT HLO artifacts.
//!
//! Identical API and numerics contract as the PJRT executor
//! (`executor.rs`, compiled under `--features pjrt`): same shape
//! checks, same outputs, validated against finite differences of the
//! assembled bound in `gp::kernel::tests`. Because it needs no
//! artifact files, cluster worker daemons can be initialised purely
//! from the shapes carried in the wire `Init` frame
//! ([`ShardExecutor::from_config`]).
//!
//! The executor is **stateful per shard**: it owns a
//! [`kernel::ShardScratch`] keyed by a parameter version
//! ([`super::EvalToken`], handed out by [`ShardExecutor::begin_eval`]).
//! Within one evaluation the statistics round fills the scratch and the
//! gradient round consumes it — one psi pass instead of two. A token
//! with a different version, a mutated shard
//! ([`ShardExecutor::invalidate_cache`]) or mismatched shapes all force
//! a bit-identical fresh recompute, never a stale reuse.

use std::cell::{Cell, RefCell};

use anyhow::Result;

use crate::gp::params::{GlobalGrads, GlobalParams};
use crate::gp::{kernel, MathMode, Stats};
use crate::linalg::Matrix;

use super::manifest::{ArtifactConfig, Manifest};
use super::shard::{LocalGrads, ShardData};
use super::EvalToken;

/// Native stand-in for the compiled artifact set: holds the shape
/// configuration plus the per-shard psi scratch; all compute is done by
/// `gp::kernel`.
pub struct ShardExecutor {
    cfg: ArtifactConfig,
    /// psi workspace reused across rounds and evaluations
    scratch: RefCell<kernel::ShardScratch>,
    /// parameter version the scratch was last filled at
    version: Cell<Option<u64>>,
    /// full psi passes computed (telemetry; see `WorkerNode`)
    fills: Cell<u64>,
    /// gradient rounds served entirely from the scratch
    hits: Cell<u64>,
    /// execution policy the cached map rounds run under: `Strict`
    /// selects the bit-for-bit kernels, `Fast` the reciprocal/batched
    /// variants (DESIGN.md §8). Fixed at construction, so a scratch
    /// filled in one mode can never be consumed by the other.
    mode: MathMode,
}

impl ShardExecutor {
    /// Manifest-based constructor (API-compatible with the PJRT
    /// executor; the HLO entry files are not touched). Strict mode.
    pub fn new(manifest: &Manifest, config: &str) -> Result<ShardExecutor> {
        Ok(Self::from_config(manifest.config(config)?.clone()))
    }

    /// Build directly from a shape configuration — no artifacts
    /// directory needed (used by TCP cluster workers, whose shapes
    /// arrive in the `Init` frame). Strict mode.
    pub fn from_config(cfg: ArtifactConfig) -> ShardExecutor {
        Self::from_config_mode(cfg, MathMode::Strict)
    }

    /// Build from shapes with an explicit [`MathMode`] (the cluster
    /// workers pass the mode negotiated in the wire `Init` frame).
    /// Sequential fill (`fill_threads == 1`).
    pub fn from_config_mode(cfg: ArtifactConfig, mode: MathMode) -> ShardExecutor {
        Self::from_config_threads(cfg, mode, 1)
    }

    /// Build from shapes with an explicit mode and intra-worker fill
    /// parallelism. `fill_threads` splits psi fills over fixed row
    /// ranges (pure function of shard size and thread count; DESIGN.md
    /// §11) so any value produces bit-identical results — it is a purely
    /// physical knob, like `MathMode` is a numerical one.
    pub fn from_config_threads(
        cfg: ArtifactConfig,
        mode: MathMode,
        fill_threads: usize,
    ) -> ShardExecutor {
        let mut scratch = kernel::ShardScratch::new();
        scratch.set_fill_threads(fill_threads);
        ShardExecutor {
            cfg,
            scratch: RefCell::new(scratch),
            version: Cell::new(None),
            fills: Cell::new(0),
            hits: Cell::new(0),
            mode,
        }
    }

    pub fn config(&self) -> &ArtifactConfig {
        &self.cfg
    }

    /// The execution policy this executor's cached rounds run under.
    pub fn math_mode(&self) -> MathMode {
        self.mode
    }

    fn check_params(&self, p: &GlobalParams) -> Result<()> {
        anyhow::ensure!(
            p.m() == self.cfg.m && p.q() == self.cfg.q,
            "params (m={}, q={}) do not match artifact config {} (m={}, q={})",
            p.m(),
            p.q(),
            self.cfg.name,
            self.cfg.m,
            self.cfg.q
        );
        Ok(())
    }

    // ---- evaluation lifecycle --------------------------------------------

    /// Start (or continue) an evaluation at parameter version
    /// `version`. If the cached scratch belongs to a different version
    /// it is invalidated here, so a stale cache can never leak into the
    /// rounds run under the returned token.
    pub fn begin_eval(&self, version: u64) -> EvalToken {
        if self.version.get() != Some(version) {
            self.scratch.borrow_mut().invalidate();
            self.version.set(None);
        }
        EvalToken::new(version)
    }

    /// Drop any cached psi intermediates (the shard or its local
    /// parameters changed under the executor).
    pub fn invalidate_cache(&self) {
        self.scratch.borrow_mut().invalidate();
        self.version.set(None);
    }

    /// Cumulative count of full psi passes this executor computed.
    pub fn psi_fills(&self) -> u64 {
        self.fills.get()
    }

    /// Cumulative count of gradient rounds served from the cache.
    pub fn cache_hits(&self) -> u64 {
        self.hits.get()
    }

    // ---- map rounds -------------------------------------------------------

    /// Map step 1, cached: compute the shard's partial statistics into
    /// the executor scratch so the gradient round of the same token can
    /// reuse the psi intermediates.
    pub fn shard_stats_cached(
        &self,
        tok: &EvalToken,
        p: &GlobalParams,
        shard: &ShardData,
    ) -> Result<Stats> {
        self.check_params(p)?;
        let mask = vec![1.0; shard.len()];
        let mut scratch = self.scratch.borrow_mut();
        let before = scratch.psi_fills();
        let st = match self.mode {
            MathMode::Strict => kernel::shard_stats_into(
                p,
                &shard.xmu,
                &shard.xvar,
                &shard.y,
                &mask,
                shard.kl_weight,
                &mut scratch,
            ),
            MathMode::Fast => kernel::shard_stats_into_fast(
                p,
                &shard.xmu,
                &shard.xvar,
                &shard.y,
                &mask,
                shard.kl_weight,
                &mut scratch,
            ),
        };
        self.fills.set(self.fills.get() + (scratch.psi_fills() - before));
        self.version.set(Some(tok.version()));
        Ok(st)
    }

    /// Map step 2, cached: chain-rule the adjoints, consuming the psi
    /// intermediates of the statistics round run under the same token.
    /// A version/shape mismatch refills fresh (bit-identical result).
    pub fn shard_grads_cached(
        &self,
        tok: &EvalToken,
        p: &GlobalParams,
        shard: &ShardData,
        adj: &crate::gp::Adjoints,
    ) -> Result<(GlobalGrads, LocalGrads)> {
        self.check_params(p)?;
        let mut scratch = self.scratch.borrow_mut();
        if self.version.get() != Some(tok.version()) {
            scratch.invalidate();
        }
        let before = scratch.psi_fills();
        let (g, d_xmu, d_xvar) = match self.mode {
            MathMode::Strict => kernel::shard_grads_vjp_cached(
                p,
                &shard.xmu,
                &shard.xvar,
                &shard.y,
                shard.kl_weight,
                adj,
                &mut scratch,
            ),
            MathMode::Fast => kernel::shard_grads_vjp_cached_fast(
                p,
                &shard.xmu,
                &shard.xvar,
                &shard.y,
                shard.kl_weight,
                adj,
                &mut scratch,
            ),
        };
        let delta = scratch.psi_fills() - before;
        self.fills.set(self.fills.get() + delta);
        if delta == 0 {
            self.hits.set(self.hits.get() + 1);
        }
        // the scratch now reflects this token's parameters either way
        self.version.set(Some(tok.version()));
        Ok((g, LocalGrads { d_xmu, d_xvar }))
    }

    /// Map step 1, stateless: the shard's partial statistics with no
    /// caching (the forced-fresh path; also the baselines' entry).
    /// Always runs the **Strict** reference kernels regardless of the
    /// executor's mode — the forced-fresh path exists to pin the
    /// pre-refactor trace, and fast mode requires the psi cache
    /// (enforced at `TrainConfig` / `Init` validation).
    pub fn shard_stats(&self, p: &GlobalParams, shard: &ShardData) -> Result<Stats> {
        self.check_params(p)?;
        let mask = vec![1.0; shard.len()];
        self.fills.set(self.fills.get() + 1);
        Ok(kernel::shard_stats(
            p,
            &shard.xmu,
            &shard.xvar,
            &shard.y,
            &mask,
            shard.kl_weight,
        ))
    }

    /// Map step 2, stateless: chain-rule the adjoints with a fresh psi
    /// recompute (no cache read or write).
    pub fn shard_grads(
        &self,
        p: &GlobalParams,
        shard: &ShardData,
        adj: &crate::gp::Adjoints,
    ) -> Result<(GlobalGrads, LocalGrads)> {
        self.check_params(p)?;
        self.fills.set(self.fills.get() + 1);
        let (g, d_xmu, d_xvar) =
            kernel::shard_grads_vjp(p, &shard.xmu, &shard.xvar, &shard.y, shard.kl_weight, adj);
        Ok((g, LocalGrads { d_xmu, d_xvar }))
    }

    /// Central direct term: Kmm (no jitter) and the pullback of dF/dKmm.
    pub fn kmm_grads(&self, p: &GlobalParams, adj_kmm: &Matrix) -> Result<(Matrix, GlobalGrads)> {
        self.check_params(p)?;
        let kmm = kernel::seard(&p.z, &p.z, p);
        let g = kernel::kmm_vjp(p, adj_kmm);
        Ok((kmm, g))
    }

    /// Posterior prediction at (possibly uncertain) test inputs.
    /// Returns (mean [t x d], var [t]) without observation noise.
    pub fn predict(
        &self,
        p: &GlobalParams,
        xt_mu: &Matrix,
        xt_var: &Matrix,
        w1: &Matrix,
        wv: &Matrix,
    ) -> Result<(Matrix, Vec<f64>)> {
        self.check_params(p)?;
        let mean = kernel::psi1(p, xt_mu, xt_var).matmul(w1);
        let sf2 = p.sf2();
        let var = (0..xt_mu.rows())
            .map(|i| {
                let p2 = kernel::psi2_point(p, xt_mu.row(i), xt_var.row(i));
                sf2 - wv.dot(&p2)
            })
            .collect();
        Ok((mean, var))
    }
}
