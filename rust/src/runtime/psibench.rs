//! `gparml bench psi` — machine-readable hot-path benchmark of the two
//! map rounds (shard statistics + chain-rule gradients), cached vs
//! forced-fresh and Strict vs Fast math mode — plus `gparml bench
//! check`, the CI regression gate over the emitted JSON.
//!
//! `bench psi` writes `BENCH_psi.json` (ns/point per round and per
//! full evaluation, the cached-vs-nocache speedup and the
//! Fast-vs-Strict speedup). `bench check` diffs a fresh report against
//! the committed `BENCH_baseline.json` and fails on a >25% ns/point
//! regression on any series, or on Fast being slower than Strict —
//! turning the perf trajectory into an enforced gate instead of a
//! number nobody reads (DESIGN.md §8).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::gp::{self, kernel, GlobalParams, MathMode};
use crate::linalg::Matrix;
use crate::util::bench::bench;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::{build_executor, build_executor_mode, default_artifacts_dir, Manifest, ShardData};

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir)
}

/// Run the psi hot-path benchmark and write the JSON report.
///
/// Flags: `--config` (artifact shape, default `perf`), `--points`
/// (shard size, default the config's capacity B), `--reps`,
/// `--out` (default `BENCH_psi.json`), `--artifacts DIR`,
/// `--math-mode strict` to skip the Fast series (default: measure
/// both, which the CI gate requires).
pub fn run(args: &Args) -> Result<()> {
    let cfg_name = args.get_str("config", "perf");
    let reps = args.get_usize("reps", 10)?.max(1);
    let out_path = args.get_str("out", "BENCH_psi.json");
    // "strict" skips the fast series; "fast"/"both" measure both (the
    // strict series is the denominator of the fast speedup either way)
    let mode_sel = args.get_str("math-mode", "both");
    anyhow::ensure!(
        matches!(mode_sel, "strict" | "fast" | "both"),
        "--math-mode expects strict|fast|both for bench psi, got {mode_sel:?}"
    );

    let dir = artifacts_dir(args);
    let manifest = Manifest::load(&dir)?;
    let art = manifest.config(cfg_name)?.clone();
    let b = args.get_usize("points", art.cap)?.max(1);

    let exec = build_executor(&art, &dir)?;
    let mut rng = Rng::new(42);
    let params = GlobalParams {
        z: Matrix::from_fn(art.m, art.q, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0; art.q],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let shard = ShardData {
        xmu: Matrix::from_fn(b, art.q, |_, _| rng.normal()),
        xvar: Matrix::from_fn(b, art.q, |_, _| 0.1 + rng.uniform()),
        y: Matrix::from_fn(b, art.d, |_, _| rng.normal()),
        kl_weight: 1.0,
    };
    let kmm = kernel::kmm(&params, 1e-6);
    let stats = exec.shard_stats(&params, &shard)?;
    let (_, adj) = gp::assemble_bound(&stats, &kmm, params.log_beta, art.d)?;

    println!(
        "bench psi: config {cfg_name} (b={b}, m={}, q={}, d={}), {reps} reps",
        art.m, art.q, art.d
    );

    // one full evaluation, cached pipeline: round 1 fills the executor
    // scratch, round 2 consumes it (a fresh parameter version per rep,
    // exactly the trainer's per-evaluation behaviour)
    let mut version = 0u64;
    let eval_cached = bench("eval cached (stats fill + grads reuse)", 1, reps, || {
        version += 1;
        let tok = exec.begin_eval(version);
        let st = exec.shard_stats_cached(&tok, &params, &shard).unwrap();
        let g = exec.shard_grads_cached(&tok, &params, &shard, &adj).unwrap();
        (st, g)
    });
    // forced no-cache evaluation: both rounds recompute psi from scratch
    let eval_nocache = bench("eval nocache (stats + fresh grads)", 1, reps, || {
        let st = exec.shard_stats(&params, &shard).unwrap();
        let g = exec.shard_grads(&params, &shard, &adj).unwrap();
        (st, g)
    });

    // per-round series: the statistics round (identical work in both
    // modes modulo the slab writes), a gradient round reusing a warm
    // cache, and a forced-fresh gradient round
    let stats_round = bench("round 1: shard_stats", 1, reps, || {
        let tok = exec.begin_eval(version);
        exec.shard_stats_cached(&tok, &params, &shard).unwrap()
    });
    let grads_cached = bench("round 2: shard_grads (cache hit)", 1, reps, || {
        let tok = exec.begin_eval(version);
        exec.shard_grads_cached(&tok, &params, &shard, &adj).unwrap()
    });
    let grads_nocache = bench("round 2: shard_grads (forced fresh)", 1, reps, || {
        exec.shard_grads(&params, &shard, &adj).unwrap()
    });

    // Fast-mode series, same shard and adjoints: the gate asserts this
    // beats the strict cached pipeline (unavailable on the PJRT path)
    let fast = if mode_sel == "strict" {
        None
    } else {
        match build_executor_mode(&art, &dir, MathMode::Fast) {
            Ok(fexec) => {
                let eval_fast = bench("eval fast (stats fill + grads reuse)", 1, reps, || {
                    version += 1;
                    let tok = fexec.begin_eval(version);
                    let st = fexec.shard_stats_cached(&tok, &params, &shard).unwrap();
                    let g = fexec
                        .shard_grads_cached(&tok, &params, &shard, &adj)
                        .unwrap();
                    (st, g)
                });
                let fast_stats = bench("round 1: shard_stats (fast)", 1, reps, || {
                    let tok = fexec.begin_eval(version);
                    fexec.shard_stats_cached(&tok, &params, &shard).unwrap()
                });
                let fast_grads = bench("round 2: shard_grads (fast, cache hit)", 1, reps, || {
                    let tok = fexec.begin_eval(version);
                    fexec.shard_grads_cached(&tok, &params, &shard, &adj).unwrap()
                });
                Some((eval_fast, fast_stats, fast_grads))
            }
            Err(e) => {
                println!("fast math mode unavailable on this executor: {e:#}");
                None
            }
        }
    };

    let per_point = |median_s: f64| median_s * 1e9 / b as f64;
    let speedup = eval_nocache.median_s / eval_cached.median_s.max(1e-12);
    println!(
        "combined stats+grads per evaluation: cached {:.0} ns/point, \
         nocache {:.0} ns/point => {speedup:.2}x",
        per_point(eval_cached.median_s),
        per_point(eval_nocache.median_s),
    );

    let mut json = format!(
        "{{\n  \"config\": \"{}\",\n  \"points\": {},\n  \"m\": {},\n  \"q\": {},\n  \
         \"d\": {},\n  \"reps\": {},\n  \"stats_ns_per_point\": {:.1},\n  \
         \"grads_cached_ns_per_point\": {:.1},\n  \"grads_nocache_ns_per_point\": {:.1},\n  \
         \"eval_cached_ns_per_point\": {:.1},\n  \"eval_nocache_ns_per_point\": {:.1},\n  \
         \"speedup_eval\": {:.3}",
        cfg_name,
        b,
        art.m,
        art.q,
        art.d,
        reps,
        per_point(stats_round.median_s),
        per_point(grads_cached.median_s),
        per_point(grads_nocache.median_s),
        per_point(eval_cached.median_s),
        per_point(eval_nocache.median_s),
        speedup,
    );
    if let Some((eval_fast, fast_stats, fast_grads)) = &fast {
        let speedup_fast = eval_cached.median_s / eval_fast.median_s.max(1e-12);
        println!(
            "fast mode per evaluation: {:.0} ns/point => {speedup_fast:.2}x over strict",
            per_point(eval_fast.median_s),
        );
        json.push_str(&format!(
            ",\n  \"fast_stats_ns_per_point\": {:.1},\n  \
             \"fast_grads_cached_ns_per_point\": {:.1},\n  \
             \"fast_eval_ns_per_point\": {:.1},\n  \"speedup_fast\": {:.3}",
            per_point(fast_stats.median_s),
            per_point(fast_grads.median_s),
            per_point(eval_fast.median_s),
            speedup_fast,
        ));
    }
    json.push_str("\n}\n");
    std::fs::write(out_path, json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// `gparml bench check`: diff a fresh `BENCH_psi.json` against the
/// committed baseline; non-zero exit on regression (the CI gate).
///
/// Flags: `--baseline` (default `BENCH_baseline.json`), `--current`
/// (default `BENCH_psi.json`), `--max-regress` (fractional ns/point
/// regression budget, default 0.25).
pub fn check(args: &Args) -> Result<()> {
    let baseline_path = args.get_str("baseline", "BENCH_baseline.json");
    let current_path = args.get_str("current", "BENCH_psi.json");
    let max_regress = args.get_f64("max-regress", 0.25)?;
    let baseline = Json::from_file(Path::new(baseline_path))?;
    let current = Json::from_file(Path::new(current_path))?;
    let failures = gate(&baseline, &current, max_regress)?;
    if failures.is_empty() {
        println!(
            "bench check: OK ({current_path} within {:.0}% of {baseline_path}, fast <= strict)",
            max_regress * 100.0
        );
        return Ok(());
    }
    for f in &failures {
        eprintln!("bench check FAILED: {f}");
    }
    bail!(
        "{} bench regression(s) against {baseline_path} (budget {:.0}%)",
        failures.len(),
        max_regress * 100.0
    )
}

/// The pure gate: every `*_ns_per_point` series in the baseline must be
/// present in the current report and within `(1 + max_regress)` of the
/// baseline value, and the current Fast evaluation must not be slower
/// than the current Strict one. Returns the list of violations.
fn gate(baseline: &Json, current: &Json, max_regress: f64) -> Result<Vec<String>> {
    let mut fails = Vec::new();
    for (key, bv) in baseline.as_obj()? {
        if !key.ends_with("_ns_per_point") {
            continue;
        }
        let base = bv.as_f64()?;
        let Some(cv) = current.opt(key) else {
            fails.push(format!("series {key} is missing from the current report"));
            continue;
        };
        let cur = cv.as_f64()?;
        if base > 0.0 && cur > base * (1.0 + max_regress) {
            fails.push(format!(
                "{key}: {cur:.1} ns/point vs baseline {base:.1} \
                 (>{:.0}% regression)",
                max_regress * 100.0
            ));
        }
    }
    match (
        current.opt("fast_eval_ns_per_point"),
        current.opt("eval_cached_ns_per_point"),
    ) {
        (Some(f), Some(s)) => {
            let (f, s) = (f.as_f64()?, s.as_f64()?);
            if f > s {
                fails.push(format!(
                    "fast eval ({f:.1} ns/point) is slower than strict ({s:.1} ns/point)"
                ));
            }
        }
        _ => fails.push("current report is missing the fast-vs-strict series".to_string()),
    }
    Ok(fails)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn j(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    #[test]
    fn gate_passes_within_budget() {
        let base = j(r#"{"stats_ns_per_point": 100.0, "fast_eval_ns_per_point": 60.0}"#);
        let cur = j(
            r#"{"stats_ns_per_point": 120.0, "fast_eval_ns_per_point": 70.0,
                "eval_cached_ns_per_point": 110.0}"#,
        );
        assert!(gate(&base, &cur, 0.25).unwrap().is_empty());
    }

    #[test]
    fn gate_flags_regression_and_missing_series() {
        let base = j(r#"{"stats_ns_per_point": 100.0, "grads_cached_ns_per_point": 50.0}"#);
        let cur = j(
            r#"{"stats_ns_per_point": 126.0, "fast_eval_ns_per_point": 10.0,
                "eval_cached_ns_per_point": 20.0}"#,
        );
        let fails = gate(&base, &cur, 0.25).unwrap();
        assert_eq!(fails.len(), 2, "{fails:?}");
        assert!(fails.iter().any(|f| f.contains("stats_ns_per_point")));
        assert!(fails.iter().any(|f| f.contains("grads_cached_ns_per_point")));
    }

    #[test]
    fn gate_flags_fast_slower_than_strict() {
        let base = j(r#"{"stats_ns_per_point": 100.0}"#);
        let cur = j(
            r#"{"stats_ns_per_point": 90.0, "fast_eval_ns_per_point": 120.0,
                "eval_cached_ns_per_point": 100.0}"#,
        );
        let fails = gate(&base, &cur, 0.25).unwrap();
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("slower than strict"));
    }

    #[test]
    fn gate_requires_fast_series() {
        let base = j(r#"{"stats_ns_per_point": 100.0}"#);
        let cur = j(r#"{"stats_ns_per_point": 90.0}"#);
        let fails = gate(&base, &cur, 0.25).unwrap();
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("missing the fast-vs-strict"));
    }

    /// The committed CI baseline must stay parseable and carry every
    /// series the gate compares (guards against the baseline rotting
    /// while the bench JSON schema moves).
    #[test]
    fn committed_baseline_is_gate_compatible() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap()
            .join("BENCH_baseline.json");
        let base = Json::from_file(&path).expect("committed BENCH_baseline.json");
        let obj = base.as_obj().unwrap();
        for key in [
            "stats_ns_per_point",
            "grads_cached_ns_per_point",
            "grads_nocache_ns_per_point",
            "eval_cached_ns_per_point",
            "eval_nocache_ns_per_point",
            "fast_stats_ns_per_point",
            "fast_grads_cached_ns_per_point",
            "fast_eval_ns_per_point",
        ] {
            assert!(obj.contains_key(key), "baseline missing {key}");
            assert!(obj[key].as_f64().unwrap() > 0.0, "baseline {key} not positive");
        }
        // a report identical to the baseline must pass its own gate
        let fails = gate(&base, &base, 0.25).unwrap();
        assert!(fails.is_empty(), "{fails:?}");
    }
}
