//! `gparml bench psi` — machine-readable hot-path benchmark of the two
//! map rounds (shard statistics + chain-rule gradients), cached vs
//! forced-fresh.
//!
//! Writes `BENCH_psi.json` (ns/point per round and per full evaluation,
//! plus the cached-vs-nocache speedup) so the perf trajectory of the
//! worker hot path is tracked as a checked artifact from PR 2 on. CI
//! runs a small-rep smoke of this command to keep the harness alive.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::gp::{self, kernel, GlobalParams};
use crate::linalg::Matrix;
use crate::util::bench::bench;
use crate::util::cli::Args;
use crate::util::rng::Rng;

use super::{build_executor, default_artifacts_dir, Manifest, ShardData};

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir)
}

/// Run the psi hot-path benchmark and write the JSON report.
///
/// Flags: `--config` (artifact shape, default `perf`), `--points`
/// (shard size, default the config's capacity B), `--reps`,
/// `--out` (default `BENCH_psi.json`), `--artifacts DIR`.
pub fn run(args: &Args) -> Result<()> {
    let cfg_name = args.get_str("config", "perf");
    let reps = args.get_usize("reps", 10)?.max(1);
    let out_path = args.get_str("out", "BENCH_psi.json");

    let dir = artifacts_dir(args);
    let manifest = Manifest::load(&dir)?;
    let art = manifest.config(cfg_name)?.clone();
    let b = args.get_usize("points", art.cap)?.max(1);

    let exec = build_executor(&art, &dir)?;
    let mut rng = Rng::new(42);
    let params = GlobalParams {
        z: Matrix::from_fn(art.m, art.q, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0; art.q],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let shard = ShardData {
        xmu: Matrix::from_fn(b, art.q, |_, _| rng.normal()),
        xvar: Matrix::from_fn(b, art.q, |_, _| 0.1 + rng.uniform()),
        y: Matrix::from_fn(b, art.d, |_, _| rng.normal()),
        kl_weight: 1.0,
    };
    let kmm = kernel::kmm(&params, 1e-6);
    let stats = exec.shard_stats(&params, &shard)?;
    let (_, adj) = gp::assemble_bound(&stats, &kmm, params.log_beta, art.d)?;

    println!(
        "bench psi: config {cfg_name} (b={b}, m={}, q={}, d={}), {reps} reps",
        art.m, art.q, art.d
    );

    // one full evaluation, cached pipeline: round 1 fills the executor
    // scratch, round 2 consumes it (a fresh parameter version per rep,
    // exactly the trainer's per-evaluation behaviour)
    let mut version = 0u64;
    let eval_cached = bench("eval cached (stats fill + grads reuse)", 1, reps, || {
        version += 1;
        let tok = exec.begin_eval(version);
        let st = exec.shard_stats_cached(&tok, &params, &shard).unwrap();
        let g = exec.shard_grads_cached(&tok, &params, &shard, &adj).unwrap();
        (st, g)
    });
    // forced no-cache evaluation: both rounds recompute psi from scratch
    let eval_nocache = bench("eval nocache (stats + fresh grads)", 1, reps, || {
        let st = exec.shard_stats(&params, &shard).unwrap();
        let g = exec.shard_grads(&params, &shard, &adj).unwrap();
        (st, g)
    });

    // per-round series: the statistics round (identical work in both
    // modes modulo the slab writes), a gradient round reusing a warm
    // cache, and a forced-fresh gradient round
    let stats_round = bench("round 1: shard_stats", 1, reps, || {
        let tok = exec.begin_eval(version);
        exec.shard_stats_cached(&tok, &params, &shard).unwrap()
    });
    let grads_cached = bench("round 2: shard_grads (cache hit)", 1, reps, || {
        let tok = exec.begin_eval(version);
        exec.shard_grads_cached(&tok, &params, &shard, &adj).unwrap()
    });
    let grads_nocache = bench("round 2: shard_grads (forced fresh)", 1, reps, || {
        exec.shard_grads(&params, &shard, &adj).unwrap()
    });

    let per_point = |median_s: f64| median_s * 1e9 / b as f64;
    let speedup = eval_nocache.median_s / eval_cached.median_s.max(1e-12);
    println!(
        "combined stats+grads per evaluation: cached {:.0} ns/point, \
         nocache {:.0} ns/point => {speedup:.2}x",
        per_point(eval_cached.median_s),
        per_point(eval_nocache.median_s),
    );

    let json = format!(
        "{{\n  \"config\": \"{}\",\n  \"points\": {},\n  \"m\": {},\n  \"q\": {},\n  \
         \"d\": {},\n  \"reps\": {},\n  \"stats_ns_per_point\": {:.1},\n  \
         \"grads_cached_ns_per_point\": {:.1},\n  \"grads_nocache_ns_per_point\": {:.1},\n  \
         \"eval_cached_ns_per_point\": {:.1},\n  \"eval_nocache_ns_per_point\": {:.1},\n  \
         \"speedup_eval\": {:.3}\n}}\n",
        cfg_name,
        b,
        art.m,
        art.q,
        art.d,
        reps,
        per_point(stats_round.median_s),
        per_point(grads_cached.median_s),
        per_point(grads_nocache.median_s),
        per_point(eval_cached.median_s),
        per_point(eval_nocache.median_s),
        speedup,
    );
    std::fs::write(out_path, json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}
