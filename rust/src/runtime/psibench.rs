//! `gparml bench psi` — machine-readable hot-path benchmark of the two
//! map rounds (shard statistics + chain-rule gradients), cached vs
//! forced-fresh and Strict vs Fast math mode — plus `gparml bench
//! check`, the CI regression gate over the emitted JSON.
//!
//! `bench psi` writes `BENCH_psi.json` (ns/point per round and per
//! full evaluation, the cached-vs-nocache speedup and the
//! Fast-vs-Strict speedup). `bench check` diffs a fresh report against
//! the committed `BENCH_baseline.json` and fails on a >25% ns/point
//! regression on any series, or on Fast being slower than Strict —
//! turning the perf trajectory into an enforced gate instead of a
//! number nobody reads (DESIGN.md §8).
//!
//! The `traced_eval` series re-runs the strict cached evaluation with
//! the `obs::trace` JSONL sink live and a span per evaluation — the
//! observability overhead guard (DESIGN.md §10): it must stay within
//! the normal `--max-regress` budget of its baseline AND of the
//! untraced `eval_cached` series from the same report.
//!
//! The `par{2,4}_stats` / `fast_par{2,4}_stats` series re-run the
//! statistics round with the psi fill split over 2 and 4 intra-worker
//! threads (DESIGN.md §11) — bit-identical numbers by construction; the
//! gate asserts the threaded fill is never slower than the sequential
//! one beyond the budget, and that every measured series carries a
//! committed ceiling.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::gp::{self, kernel, GlobalParams, MathMode};
use crate::linalg::Matrix;
use crate::util::bench::bench;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::{
    build_executor, build_executor_mode, build_executor_threads, default_artifacts_dir, Manifest,
    ShardData,
};

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir)
}

/// One measured psi-bench report: the shape metadata plus every
/// ns/point series, ready to render as JSON (for `BENCH_psi.json` or,
/// via [`rebaseline`], as a fresh `BENCH_baseline.json`).
struct PsiReport {
    config: String,
    points: usize,
    m: usize,
    q: usize,
    d: usize,
    reps: usize,
    /// `*_ns_per_point` series in output order.
    series: Vec<(&'static str, f64)>,
    speedup_eval: f64,
    speedup_fast: Option<f64>,
}

/// Render a report as the bench JSON. `note` becomes a leading `_note`
/// field; `headroom` inflates every ns/point series by `(1 + headroom)`
/// (rebaseline slack for machine-to-machine noise — 0 for reports).
fn render(r: &PsiReport, note: Option<&str>, headroom: f64) -> String {
    let mut json = String::from("{\n");
    if let Some(note) = note {
        json.push_str(&format!("  \"_note\": \"{}\",\n", note.replace('"', "'")));
    }
    json.push_str(&format!(
        "  \"config\": \"{}\",\n  \"points\": {},\n  \"m\": {},\n  \"q\": {},\n  \
         \"d\": {},\n  \"reps\": {}",
        r.config, r.points, r.m, r.q, r.d, r.reps
    ));
    for (key, ns) in &r.series {
        json.push_str(&format!(",\n  \"{key}\": {:.1}", ns * (1.0 + headroom)));
    }
    json.push_str(&format!(",\n  \"speedup_eval\": {:.3}", r.speedup_eval));
    if let Some(sf) = r.speedup_fast {
        json.push_str(&format!(",\n  \"speedup_fast\": {sf:.3}"));
    }
    json.push_str("\n}\n");
    json
}

/// Run the psi hot-path benchmark and write the JSON report.
///
/// Flags: `--config` (artifact shape, default `perf`), `--points`
/// (shard size, default the config's capacity B), `--reps`,
/// `--out` (default `BENCH_psi.json`), `--artifacts DIR`,
/// `--math-mode strict` to skip the Fast series (default: measure
/// both, which the CI gate requires).
pub fn run(args: &Args) -> Result<()> {
    let out_path = args.get_str("out", "BENCH_psi.json");
    let report = measure(args)?;
    std::fs::write(out_path, render(&report, None, 0.0))
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// `gparml bench rebaseline`: re-measure the psi series on THIS machine
/// and regenerate `BENCH_baseline.json` in place (ROADMAP "tighten the
/// bench baseline"). `--headroom X` (default 0.15) inflates the
/// measured medians by `(1+X)` so run-to-run noise on the same machine
/// does not trip the gate; once the baseline reflects the CI reference
/// machine, drop `gparml bench check --max-regress` toward 0.1 (the
/// written `_note` records the procedure).
pub fn rebaseline(args: &Args) -> Result<()> {
    let out_path = args.get_str("out", "BENCH_baseline.json");
    let headroom = args.get_f64("headroom", 0.15)?;
    anyhow::ensure!(
        headroom >= 0.0,
        "--headroom must be non-negative, got {headroom}"
    );
    let report = measure(args)?;
    let note = format!(
        "Regenerated in place by `gparml bench rebaseline` (medians x {:.2} headroom, \
         reps={}). Tightening path: run this on the CI reference machine, commit the \
         result, then lower the gate budget from the default \
         `gparml bench check --max-regress 0.25` toward 0.1 in ci.yml — the gate then \
         catches creeping regressions, not just catastrophic ones.",
        1.0 + headroom,
        report.reps
    );
    std::fs::write(out_path, render(&report, Some(&note), headroom))
        .with_context(|| format!("writing {out_path}"))?;
    println!("rebaselined {out_path} (headroom {:.0}%)", headroom * 100.0);
    Ok(())
}

/// Measure every bench series (the shared body of [`run`] and
/// [`rebaseline`]).
fn measure(args: &Args) -> Result<PsiReport> {
    let cfg_name = args.get_str("config", "perf");
    let reps = args.get_usize("reps", 10)?.max(1);
    // "strict" skips the fast series; "fast"/"both" measure both (the
    // strict series is the denominator of the fast speedup either way)
    let mode_sel = args.get_str("math-mode", "both");
    anyhow::ensure!(
        matches!(mode_sel, "strict" | "fast" | "both"),
        "--math-mode expects strict|fast|both for bench psi, got {mode_sel:?}"
    );

    let dir = artifacts_dir(args);
    let manifest = Manifest::load(&dir)?;
    let art = manifest.config(cfg_name)?.clone();
    let b = args.get_usize("points", art.cap)?.max(1);

    let exec = build_executor(&art, &dir)?;
    let mut rng = Rng::new(42);
    let params = GlobalParams {
        z: Matrix::from_fn(art.m, art.q, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0; art.q],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let shard = ShardData {
        xmu: Matrix::from_fn(b, art.q, |_, _| rng.normal()),
        xvar: Matrix::from_fn(b, art.q, |_, _| 0.1 + rng.uniform()),
        y: Matrix::from_fn(b, art.d, |_, _| rng.normal()),
        kl_weight: 1.0,
    };
    let kmm = kernel::kmm(&params, 1e-6);
    let stats = exec.shard_stats(&params, &shard)?;
    let (_, adj) = gp::assemble_bound(&stats, &kmm, params.log_beta, art.d)?;

    println!(
        "bench psi: config {cfg_name} (b={b}, m={}, q={}, d={}), {reps} reps",
        art.m, art.q, art.d
    );

    // one full evaluation, cached pipeline: round 1 fills the executor
    // scratch, round 2 consumes it (a fresh parameter version per rep,
    // exactly the trainer's per-evaluation behaviour)
    let mut version = 0u64;
    let eval_cached = bench("eval cached (stats fill + grads reuse)", 1, reps, || {
        version += 1;
        let tok = exec.begin_eval(version);
        let st = exec.shard_stats_cached(&tok, &params, &shard).unwrap();
        let g = exec.shard_grads_cached(&tok, &params, &shard, &adj).unwrap();
        (st, g)
    });
    // forced no-cache evaluation: both rounds recompute psi from scratch
    let eval_nocache = bench("eval nocache (stats + fresh grads)", 1, reps, || {
        let st = exec.shard_stats(&params, &shard).unwrap();
        let g = exec.shard_grads(&params, &shard, &adj).unwrap();
        (st, g)
    });
    // the same cached evaluation with the trace sink LIVE and a span
    // per rep — the obs overhead guard. Uses a private temp sink (this
    // replaces any `--trace-out` sink; bench exits right after anyway)
    // and disables tracing again so later series measure the one-load
    // disabled path.
    let trace_path = std::env::temp_dir().join(format!(
        "gparml-bench-trace-{}.jsonl",
        std::process::id()
    ));
    crate::obs::trace::init(&trace_path)
        .with_context(|| format!("opening bench trace sink {}", trace_path.display()))?;
    let eval_traced = bench("eval traced (strict, sink live)", 1, reps, || {
        version += 1;
        let mut sp = crate::obs::trace::span("bench_eval", version);
        let tok = exec.begin_eval(version);
        let st = exec.shard_stats_cached(&tok, &params, &shard).unwrap();
        let g = exec.shard_grads_cached(&tok, &params, &shard, &adj).unwrap();
        sp.set_count(b as u64);
        (st, g)
    });
    crate::obs::trace::disable();
    let _ = std::fs::remove_file(&trace_path);

    // per-round series: the statistics round (identical work in both
    // modes modulo the slab writes), a gradient round reusing a warm
    // cache, and a forced-fresh gradient round
    let stats_round = bench("round 1: shard_stats", 1, reps, || {
        let tok = exec.begin_eval(version);
        exec.shard_stats_cached(&tok, &params, &shard).unwrap()
    });
    let grads_cached = bench("round 2: shard_grads (cache hit)", 1, reps, || {
        let tok = exec.begin_eval(version);
        exec.shard_grads_cached(&tok, &params, &shard, &adj).unwrap()
    });
    let grads_nocache = bench("round 2: shard_grads (forced fresh)", 1, reps, || {
        exec.shard_grads(&params, &shard, &adj).unwrap()
    });

    // Fast-mode series, same shard and adjoints: the gate asserts this
    // beats the strict cached pipeline (unavailable on the PJRT path)
    let fast = if mode_sel == "strict" {
        None
    } else {
        match build_executor_mode(&art, &dir, MathMode::Fast) {
            Ok(fexec) => {
                let eval_fast = bench("eval fast (stats fill + grads reuse)", 1, reps, || {
                    version += 1;
                    let tok = fexec.begin_eval(version);
                    let st = fexec.shard_stats_cached(&tok, &params, &shard).unwrap();
                    let g = fexec
                        .shard_grads_cached(&tok, &params, &shard, &adj)
                        .unwrap();
                    (st, g)
                });
                let fast_stats = bench("round 1: shard_stats (fast)", 1, reps, || {
                    let tok = fexec.begin_eval(version);
                    fexec.shard_stats_cached(&tok, &params, &shard).unwrap()
                });
                let fast_grads = bench("round 2: shard_grads (fast, cache hit)", 1, reps, || {
                    let tok = fexec.begin_eval(version);
                    fexec.shard_grads_cached(&tok, &params, &shard, &adj).unwrap()
                });
                Some((eval_fast, fast_stats, fast_grads))
            }
            Err(e) => {
                println!("fast math mode unavailable on this executor: {e:#}");
                None
            }
        }
    };

    let per_point = |median_s: f64| median_s * 1e9 / b as f64;
    let speedup = eval_nocache.median_s / eval_cached.median_s.max(1e-12);
    println!(
        "combined stats+grads per evaluation: cached {:.0} ns/point, \
         nocache {:.0} ns/point => {speedup:.2}x",
        per_point(eval_cached.median_s),
        per_point(eval_nocache.median_s),
    );

    let mut series = vec![
        ("stats_ns_per_point", per_point(stats_round.median_s)),
        ("grads_cached_ns_per_point", per_point(grads_cached.median_s)),
        ("grads_nocache_ns_per_point", per_point(grads_nocache.median_s)),
        ("eval_cached_ns_per_point", per_point(eval_cached.median_s)),
        ("eval_nocache_ns_per_point", per_point(eval_nocache.median_s)),
        ("traced_eval_ns_per_point", per_point(eval_traced.median_s)),
    ];
    let mut speedup_fast = None;
    if let Some((eval_fast, fast_stats, fast_grads)) = &fast {
        let sf = eval_cached.median_s / eval_fast.median_s.max(1e-12);
        println!(
            "fast mode per evaluation: {:.0} ns/point => {sf:.2}x over strict",
            per_point(eval_fast.median_s),
        );
        series.push(("fast_stats_ns_per_point", per_point(fast_stats.median_s)));
        series.push((
            "fast_grads_cached_ns_per_point",
            per_point(fast_grads.median_s),
        ));
        series.push(("fast_eval_ns_per_point", per_point(eval_fast.median_s)));
        speedup_fast = Some(sf);
    }

    // thread-sweep (DESIGN.md §11): the same statistics round with the
    // psi fill split over 2 and 4 intra-worker threads, strict and (when
    // measured above) fast. Bit-identical numbers by construction — the
    // sweep measures only whether the parallel fill pays for itself; the
    // gate asserts it is never a slowdown beyond the budget. Skipped as
    // a block when the executor rejects fill_threads > 1 (the PJRT
    // path, whose AOT graphs evaluate the whole shard as one fixed
    // computation).
    for threads in [2usize, 4] {
        let pexec = match build_executor_threads(&art, &dir, MathMode::Strict, threads) {
            Ok(e) => e,
            Err(e) => {
                println!("fill-thread sweep unavailable on this executor: {e:#}");
                break;
            }
        };
        let key: &'static str = if threads == 2 {
            "par2_stats_ns_per_point"
        } else {
            "par4_stats_ns_per_point"
        };
        let label = format!("round 1: shard_stats ({threads} fill threads)");
        let r = bench(&label, 1, reps, || {
            let tok = pexec.begin_eval(version);
            pexec.shard_stats_cached(&tok, &params, &shard).unwrap()
        });
        series.push((key, per_point(r.median_s)));
        if fast.is_some() {
            let fkey: &'static str = if threads == 2 {
                "fast_par2_stats_ns_per_point"
            } else {
                "fast_par4_stats_ns_per_point"
            };
            match build_executor_threads(&art, &dir, MathMode::Fast, threads) {
                Ok(fexec) => {
                    let label = format!("round 1: shard_stats (fast, {threads} fill threads)");
                    let r = bench(&label, 1, reps, || {
                        let tok = fexec.begin_eval(version);
                        fexec.shard_stats_cached(&tok, &params, &shard).unwrap()
                    });
                    series.push((fkey, per_point(r.median_s)));
                }
                Err(e) => println!("fast fill-thread sweep unavailable: {e:#}"),
            }
        }
    }

    Ok(PsiReport {
        config: cfg_name.to_string(),
        points: b,
        m: art.m,
        q: art.q,
        d: art.d,
        reps,
        series,
        speedup_eval: speedup,
        speedup_fast,
    })
}

/// `gparml bench check`: diff a fresh `BENCH_psi.json` against the
/// committed baseline; non-zero exit on regression (the CI gate).
///
/// Flags: `--baseline` (default `BENCH_baseline.json`), `--current`
/// (default `BENCH_psi.json`), `--max-regress` (fractional regression
/// budget, default 0.25). `--scenario R1,R2` additionally gates the
/// named `BENCH_scenario_*.json` reports (written by
/// `gparml experiment flights` / `mnist-lvm`) against
/// `--scenario-baseline` (default `BENCH_scenario_baseline.json`) via
/// [`scenario_gate`] — one command, one exit code for the whole perf
/// surface.
pub fn check(args: &Args) -> Result<()> {
    let baseline_path = args.get_str("baseline", "BENCH_baseline.json");
    let current_path = args.get_str("current", "BENCH_psi.json");
    let max_regress = args.get_f64("max-regress", 0.25)?;
    let baseline = Json::from_file(Path::new(baseline_path))?;
    let current = Json::from_file(Path::new(current_path))?;
    let mut failures = gate(&baseline, &current, max_regress)?;
    let mut gated = vec![current_path.to_string()];
    if let Some(reports) = args.get("scenario") {
        let sb_path = args.get_str("scenario-baseline", "BENCH_scenario_baseline.json");
        let sbase = Json::from_file(Path::new(sb_path))
            .with_context(|| format!("loading scenario baseline {sb_path}"))?;
        for report in reports.split(',').filter(|r| !r.is_empty()) {
            let cur = Json::from_file(Path::new(report))
                .with_context(|| format!("loading scenario report {report}"))?;
            failures.extend(scenario_gate(&sbase, &cur, max_regress)?);
            gated.push(report.to_string());
        }
    }
    if failures.is_empty() {
        println!(
            "bench check: OK ({} within {:.0}% of the committed ceilings, fast <= strict)",
            gated.join(", "),
            max_regress * 100.0
        );
        return Ok(());
    }
    for f in &failures {
        eprintln!("bench check FAILED: {f}");
    }
    // name every offender in the final error too: CI logs often show
    // only the last line, and "3 regressions" without WHICH series and
    // against WHAT baseline value is undebuggable from a red check
    bail!(
        "{} bench regression(s) against the committed ceilings (budget {:.0}%): {}",
        failures.len(),
        max_regress * 100.0,
        failures.join("; ")
    )
}

/// The pure gate: every `*_ns_per_point` series in the baseline must be
/// present in the current report and within `(1 + max_regress)` of the
/// baseline value; every `*_ns_per_point` series in the current report
/// must carry a baseline ceiling (a measured-but-ungated series is a
/// silent hole in the gate); the current Fast evaluation must not be
/// slower than the current Strict one; the current traced evaluation
/// must stay within `(1 + max_regress)` of the current untraced one
/// (the obs overhead guard); and each current `par*_stats` series must
/// stay within `(1 + max_regress)` of its single-threaded counterpart
/// (the threaded-fill guard, DESIGN.md §11). The in-report comparisons
/// are deliberate: machine speed cancels out. Returns the list of
/// violations.
fn gate(baseline: &Json, current: &Json, max_regress: f64) -> Result<Vec<String>> {
    let mut fails = Vec::new();
    let base_obj = baseline.as_obj()?;
    for (key, bv) in base_obj {
        if !key.ends_with("_ns_per_point") {
            continue;
        }
        let base = bv.as_f64()?;
        let Some(cv) = current.opt(key) else {
            fails.push(format!(
                "series {key} (baseline {base:.1} ns/point) is missing from the current report"
            ));
            continue;
        };
        let cur = cv.as_f64()?;
        if base > 0.0 && cur > base * (1.0 + max_regress) {
            fails.push(format!(
                "{key}: {cur:.1} ns/point vs baseline {base:.1} \
                 (>{:.0}% regression)",
                max_regress * 100.0
            ));
        }
    }
    // the reverse direction: a series measured in the current report
    // with no committed ceiling would be silently ungated forever (the
    // loop above only walks baseline keys) — fail loudly so every new
    // series lands together with its baseline entry
    for (key, cv) in current.as_obj()? {
        if !key.ends_with("_ns_per_point") {
            continue;
        }
        let cur = cv.as_f64()?;
        if !base_obj.contains_key(key) {
            fails.push(format!(
                "series {key} ({cur:.1} ns/point) is in the current report but has no \
                 ceiling in the baseline — add one (e.g. via `gparml bench rebaseline`)"
            ));
        }
    }
    // the threaded-fill guard (DESIGN.md §11): a multi-threaded psi
    // fill must not be slower than its sequential counterpart beyond
    // the budget
    for (par, single) in [
        ("par2_stats_ns_per_point", "stats_ns_per_point"),
        ("par4_stats_ns_per_point", "stats_ns_per_point"),
        ("fast_par2_stats_ns_per_point", "fast_stats_ns_per_point"),
        ("fast_par4_stats_ns_per_point", "fast_stats_ns_per_point"),
    ] {
        if let (Some(pv), Some(sv)) = (current.opt(par), current.opt(single)) {
            let (pv, sv) = (pv.as_f64()?, sv.as_f64()?);
            if pv > sv * (1.0 + max_regress) {
                fails.push(format!(
                    "{par} ({pv:.1} ns/point) exceeds the single-threaded {single} \
                     ({sv:.1} ns/point) by more than {:.0}% — threaded fill regression",
                    max_regress * 100.0
                ));
            }
        }
    }
    match (
        current.opt("fast_eval_ns_per_point"),
        current.opt("eval_cached_ns_per_point"),
    ) {
        (Some(f), Some(s)) => {
            let (f, s) = (f.as_f64()?, s.as_f64()?);
            if f > s {
                fails.push(format!(
                    "fast eval ({f:.1} ns/point) is slower than strict ({s:.1} ns/point)"
                ));
            }
        }
        _ => fails.push("current report is missing the fast-vs-strict series".to_string()),
    }
    if let (Some(t), Some(s)) = (
        current.opt("traced_eval_ns_per_point"),
        current.opt("eval_cached_ns_per_point"),
    ) {
        let (t, s) = (t.as_f64()?, s.as_f64()?);
        if t > s * (1.0 + max_regress) {
            fails.push(format!(
                "traced eval ({t:.1} ns/point) exceeds untraced eval_cached \
                 ({s:.1} ns/point) by more than {:.0}% — tracing overhead regression",
                max_regress * 100.0
            ));
        }
    }
    Ok(fails)
}

/// The pure scenario gate (DESIGN.md §13): a scenario report (from
/// `gparml experiment flights` / `mnist-lvm`) carries a `"scenario"`
/// name plus un-prefixed `*_ns_per_row` series; the committed
/// `BENCH_scenario_baseline.json` holds ceilings keyed
/// `<scenario>_<series>` so one flat file gates every scenario. Every
/// ceiling with a matching prefix must be met within
/// `(1 + max_regress)`, and — mirroring [`gate`]'s reverse direction —
/// every measured `*_ns_per_row` series must carry a committed ceiling,
/// so a new series can never ship silently ungated. Ceilings for OTHER
/// scenarios are ignored (each report is gated per-scenario; the
/// missing-report case is the CI job's job, not this function's).
fn scenario_gate(baseline: &Json, current: &Json, max_regress: f64) -> Result<Vec<String>> {
    let mut fails = Vec::new();
    let name = current
        .get("scenario")
        .context("scenario report has no \"scenario\" field")?
        .as_str()?
        .to_string();
    let prefix = format!("{name}_");
    let base_obj = baseline.as_obj()?;
    for (key, bv) in base_obj {
        if !key.ends_with("_ns_per_row") || !key.starts_with(&prefix) {
            continue;
        }
        let series = &key[prefix.len()..];
        let base = bv.as_f64()?;
        let Some(cv) = current.opt(series) else {
            fails.push(format!(
                "scenario {name}: series {series} (ceiling {base:.1} ns/row) is missing \
                 from the report"
            ));
            continue;
        };
        let cur = cv.as_f64()?;
        if base > 0.0 && cur > base * (1.0 + max_regress) {
            fails.push(format!(
                "scenario {name}: {series} at {cur:.1} ns/row vs ceiling {base:.1} \
                 (>{:.0}% over)",
                max_regress * 100.0
            ));
        }
    }
    for (series, cv) in current.as_obj()? {
        if !series.ends_with("_ns_per_row") {
            continue;
        }
        let cur = cv.as_f64()?;
        if !base_obj.contains_key(&format!("{prefix}{series}")) {
            fails.push(format!(
                "scenario {name}: series {series} ({cur:.1} ns/row) has no ceiling \
                 {prefix}{series} in the scenario baseline — add one"
            ));
        }
    }
    Ok(fails)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn j(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    #[test]
    fn gate_passes_within_budget() {
        let base = j(
            r#"{"stats_ns_per_point": 100.0, "fast_eval_ns_per_point": 60.0,
                "eval_cached_ns_per_point": 100.0}"#,
        );
        let cur = j(
            r#"{"stats_ns_per_point": 120.0, "fast_eval_ns_per_point": 70.0,
                "eval_cached_ns_per_point": 110.0}"#,
        );
        assert!(gate(&base, &cur, 0.25).unwrap().is_empty());
    }

    #[test]
    fn gate_flags_regression_and_missing_series() {
        let base = j(
            r#"{"stats_ns_per_point": 100.0, "grads_cached_ns_per_point": 50.0,
                "fast_eval_ns_per_point": 10.0, "eval_cached_ns_per_point": 20.0}"#,
        );
        let cur = j(
            r#"{"stats_ns_per_point": 126.0, "fast_eval_ns_per_point": 10.0,
                "eval_cached_ns_per_point": 20.0}"#,
        );
        let fails = gate(&base, &cur, 0.25).unwrap();
        assert_eq!(fails.len(), 2, "{fails:?}");
        assert!(fails.iter().any(|f| f.contains("stats_ns_per_point")));
        assert!(fails.iter().any(|f| f.contains("grads_cached_ns_per_point")));
    }

    /// A series measured in the current report but absent from the
    /// baseline must fail the gate (it used to be silently skipped —
    /// gate() only iterated baseline keys).
    #[test]
    fn gate_flags_series_without_ceiling() {
        let base = j(
            r#"{"stats_ns_per_point": 100.0, "fast_eval_ns_per_point": 60.0,
                "eval_cached_ns_per_point": 90.0}"#,
        );
        let cur = j(
            r#"{"stats_ns_per_point": 90.0, "fast_eval_ns_per_point": 50.0,
                "eval_cached_ns_per_point": 80.0, "par2_stats_ns_per_point": 100.0}"#,
        );
        let fails = gate(&base, &cur, 0.25).unwrap();
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(
            fails[0].contains("par2_stats_ns_per_point") && fails[0].contains("no"),
            "ungated-series failure must name the series: {fails:?}"
        );
    }

    /// The threaded-fill guard: a par series beyond budget of its
    /// single-threaded counterpart fails even when it is within its own
    /// baseline ceiling.
    #[test]
    fn gate_flags_threaded_fill_regression() {
        let base = j(
            r#"{"stats_ns_per_point": 100.0, "par2_stats_ns_per_point": 200.0,
                "fast_eval_ns_per_point": 60.0, "eval_cached_ns_per_point": 90.0}"#,
        );
        let cur = j(
            r#"{"stats_ns_per_point": 90.0, "par2_stats_ns_per_point": 150.0,
                "fast_eval_ns_per_point": 50.0, "eval_cached_ns_per_point": 90.0}"#,
        );
        let fails = gate(&base, &cur, 0.25).unwrap();
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("threaded fill regression"), "{fails:?}");
    }

    #[test]
    fn gate_flags_fast_slower_than_strict() {
        let base = j(
            r#"{"stats_ns_per_point": 100.0, "fast_eval_ns_per_point": 120.0,
                "eval_cached_ns_per_point": 100.0}"#,
        );
        let cur = j(
            r#"{"stats_ns_per_point": 90.0, "fast_eval_ns_per_point": 120.0,
                "eval_cached_ns_per_point": 100.0}"#,
        );
        let fails = gate(&base, &cur, 0.25).unwrap();
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("slower than strict"));
    }

    #[test]
    fn gate_flags_tracing_overhead_and_names_baseline_in_missing() {
        // traced eval more than budget over the in-report untraced eval
        let base = j(
            r#"{"stats_ns_per_point": 100.0, "traced_eval_ns_per_point": 100.0,
                "fast_eval_ns_per_point": 50.0, "eval_cached_ns_per_point": 80.0}"#,
        );
        let cur = j(
            r#"{"stats_ns_per_point": 90.0, "fast_eval_ns_per_point": 50.0,
                "eval_cached_ns_per_point": 80.0, "traced_eval_ns_per_point": 101.0}"#,
        );
        let fails = gate(&base, &cur, 0.25).unwrap();
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("tracing overhead"), "{fails:?}");

        // a missing series names its baseline value in the failure
        let cur = j(
            r#"{"stats_ns_per_point": 90.0, "fast_eval_ns_per_point": 50.0,
                "eval_cached_ns_per_point": 80.0}"#,
        );
        let fails = gate(&base, &cur, 0.25).unwrap();
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(
            fails[0].contains("traced_eval_ns_per_point") && fails[0].contains("100.0"),
            "missing-series failure must name the series and baseline value: {fails:?}"
        );
    }

    #[test]
    fn gate_requires_fast_series() {
        let base = j(r#"{"stats_ns_per_point": 100.0}"#);
        let cur = j(r#"{"stats_ns_per_point": 90.0}"#);
        let fails = gate(&base, &cur, 0.25).unwrap();
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("missing the fast-vs-strict"));
    }

    /// `render` (the shared writer behind `bench psi` and `bench
    /// rebaseline`) must emit gate-compatible JSON: parseable, every
    /// series present, headroom applied multiplicatively, `_note`
    /// leading when given — and a rebaselined report must pass its own
    /// gate against the fresh report it came from.
    #[test]
    fn render_roundtrips_through_the_gate() {
        let report = PsiReport {
            config: "perf".into(),
            points: 512,
            m: 64,
            q: 2,
            d: 3,
            reps: 3,
            series: vec![
                ("stats_ns_per_point", 100.0),
                ("grads_cached_ns_per_point", 50.0),
                ("eval_cached_ns_per_point", 150.0),
                ("fast_eval_ns_per_point", 120.0),
            ],
            speedup_eval: 1.4,
            speedup_fast: Some(1.25),
        };
        let current = j(&render(&report, None, 0.0));
        assert_eq!(current.get("stats_ns_per_point").unwrap().as_f64().unwrap(), 100.0);
        assert!(current.opt("_note").is_none());

        let baseline = j(&render(&report, Some(r#"say "hi""#), 0.15));
        let note = baseline.get("_note").unwrap().as_str().unwrap().to_string();
        assert!(note.contains("say 'hi'"), "quotes must be sanitised: {note}");
        let base_stats = baseline.get("stats_ns_per_point").unwrap().as_f64().unwrap();
        assert!((base_stats - 115.0).abs() < 1e-9, "headroom not applied: {base_stats}");
        // the fresh report passes the gate against its own rebaseline
        assert!(gate(&baseline, &current, 0.25).unwrap().is_empty());
    }

    #[test]
    fn scenario_gate_passes_and_flags_regressions() {
        let base = j(
            r#"{"flights_pack_ns_per_row": 1000.0, "flights_train_ns_per_row": 5000.0}"#,
        );
        let ok = j(
            r#"{"scenario": "flights", "pack_ns_per_row": 1100.0,
                "train_ns_per_row": 4000.0}"#,
        );
        assert!(scenario_gate(&base, &ok, 0.25).unwrap().is_empty());

        let slow = j(
            r#"{"scenario": "flights", "pack_ns_per_row": 1300.0,
                "train_ns_per_row": 4000.0}"#,
        );
        let fails = scenario_gate(&base, &slow, 0.25).unwrap();
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(
            fails[0].contains("flights") && fails[0].contains("pack_ns_per_row"),
            "failure must name the scenario and series: {fails:?}"
        );
    }

    /// Both directions fail loudly — a ceiling with no measurement and a
    /// measurement with no ceiling — while ceilings that belong to OTHER
    /// scenarios are ignored entirely.
    #[test]
    fn scenario_gate_is_bidirectional_and_per_scenario() {
        let base = j(
            r#"{"flights_pack_ns_per_row": 1000.0, "flights_train_ns_per_row": 5000.0,
                "mnist_lvm_train_ns_per_row": 9000.0}"#,
        );
        // train series measured but unceilinged extra series present;
        // pack series (ceilinged) missing; mnist_lvm ceiling irrelevant
        let cur = j(
            r#"{"scenario": "flights", "train_ns_per_row": 4000.0,
                "rmse_ns_per_row": 7.0}"#,
        );
        let fails = scenario_gate(&base, &cur, 0.25).unwrap();
        assert_eq!(fails.len(), 2, "{fails:?}");
        assert!(fails.iter().any(|f| f.contains("pack_ns_per_row") && f.contains("missing")));
        assert!(fails.iter().any(|f| f.contains("rmse_ns_per_row") && f.contains("no ceiling")));

        // the mnist_lvm report gates only against its own prefix
        let lvm = j(r#"{"scenario": "mnist_lvm", "train_ns_per_row": 8000.0}"#);
        assert!(scenario_gate(&base, &lvm, 0.25).unwrap().is_empty());

        // a report without a scenario name is a hard error, not a pass
        let anon = j(r#"{"train_ns_per_row": 1.0}"#);
        assert!(scenario_gate(&base, &anon, 0.25).is_err());
    }

    /// The committed scenario baseline must stay parseable and carry a
    /// ceiling for every series the scenario runners emit.
    #[test]
    fn committed_scenario_baseline_is_gate_compatible() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap()
            .join("BENCH_scenario_baseline.json");
        let base = Json::from_file(&path).expect("committed BENCH_scenario_baseline.json");
        let obj = base.as_obj().unwrap();
        for key in [
            "flights_pack_ns_per_row",
            "flights_train_ns_per_row",
            "mnist_lvm_pack_ns_per_row",
            "mnist_lvm_train_ns_per_row",
        ] {
            assert!(obj.contains_key(key), "scenario baseline missing {key}");
            assert!(obj[key].as_f64().unwrap() > 0.0, "{key} not positive");
        }
    }

    /// The committed CI baseline must stay parseable and carry every
    /// series the gate compares (guards against the baseline rotting
    /// while the bench JSON schema moves).
    #[test]
    fn committed_baseline_is_gate_compatible() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap()
            .join("BENCH_baseline.json");
        let base = Json::from_file(&path).expect("committed BENCH_baseline.json");
        let obj = base.as_obj().unwrap();
        for key in [
            "stats_ns_per_point",
            "grads_cached_ns_per_point",
            "grads_nocache_ns_per_point",
            "eval_cached_ns_per_point",
            "eval_nocache_ns_per_point",
            "traced_eval_ns_per_point",
            "fast_stats_ns_per_point",
            "fast_grads_cached_ns_per_point",
            "fast_eval_ns_per_point",
            "par2_stats_ns_per_point",
            "par4_stats_ns_per_point",
            "fast_par2_stats_ns_per_point",
            "fast_par4_stats_ns_per_point",
        ] {
            assert!(obj.contains_key(key), "baseline missing {key}");
            assert!(obj[key].as_f64().unwrap() > 0.0, "baseline {key} not positive");
        }
        // a report identical to the baseline must pass its own gate
        let fails = gate(&base, &base, 0.25).unwrap();
        assert!(fails.is_empty(), "{fails:?}");
    }
}
