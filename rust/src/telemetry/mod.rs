//! Telemetry: per-round per-worker timing and the simulated-cluster
//! clock used to regenerate the paper's scaling figures on this
//! single-core container.
//!
//! The paper reports two series per scaling experiment (Figs. 2-3):
//! total running time, and "the amount of time spent only in the two
//! Map-Reduce functions". We record every worker's in-map compute time
//! per round; the modeled parallel wall time of a round is
//! `max_k t_k` (the reduce barrier waits for the slowest node — the
//! paper's own rate-limiting-step argument in §5.1) and the modeled
//! sequential time is `sum_k t_k`. Central (global-step) time is
//! measured directly and added to both.

use crate::gp::MathMode;
use crate::util::stats;

/// Timing of one map round across all workers.
#[derive(Debug, Clone, Default)]
pub struct RoundTiming {
    /// In-map compute seconds per worker (index = worker id).
    pub worker_secs: Vec<f64>,
    /// Measured wall-clock of the whole round including dispatch/collect.
    pub wall_secs: f64,
    /// Leader -> workers bytes for this round (0 for the in-process
    /// backend; the TCP backend reports actual wire bytes).
    pub bytes_tx: u64,
    /// Workers -> leader bytes for this round.
    pub bytes_rx: u64,
    /// Full psi recomputations across all workers in this round. With
    /// the psi cache on, a statistics round costs one per worker and a
    /// gradient round 0 — i.e. exactly one psi pass per worker per
    /// evaluation, the observable proof the two-round reuse happened.
    pub psi_recomputes: u64,
    /// Math mode the cluster ran this round under (DESIGN.md §8): a
    /// recorded timing is only comparable to another at the same mode,
    /// so the mode travels with every round it produced.
    pub math_mode: MathMode,
    /// Intra-worker psi-fill threads the cluster ran this round under
    /// (DESIGN.md §11). Like `math_mode` it changes only the cost of a
    /// round, never its bytes — recorded so per-round timings stay
    /// interpretable across thread-count sweeps. 0 in
    /// `Default::default()` means "unrecorded" (pre-v7 logs).
    pub fill_threads: usize,
}

impl RoundTiming {
    /// Modeled parallel time: the barrier waits for the slowest worker.
    pub fn modeled_parallel(&self) -> f64 {
        stats::max(&self.worker_secs).max(0.0)
    }

    /// Total compute across workers (sequential-equivalent).
    pub fn total_compute(&self) -> f64 {
        self.worker_secs.iter().sum()
    }

    /// Thread-communication / dispatch overhead beyond pure compute.
    pub fn overhead(&self) -> f64 {
        (self.wall_secs - self.total_compute()).max(0.0)
    }
}

/// Telemetry of one outer training iteration.
#[derive(Debug, Clone, Default)]
pub struct IterationLog {
    pub iter: usize,
    /// Bound value F at this iteration.
    pub f: f64,
    /// Map rounds executed (stats and gradient rounds).
    pub rounds: Vec<RoundTiming>,
    /// Seconds spent in the central global step (O(m^3) algebra + SCG).
    pub central_secs: f64,
    /// Worker ids that "failed" this iteration (dropped partial terms).
    pub failed_workers: Vec<usize>,
}

impl IterationLog {
    /// Modeled wall time of the iteration on a real cluster:
    /// sum over rounds of (slowest worker) plus central time.
    pub fn modeled_parallel_secs(&self) -> f64 {
        self.rounds.iter().map(|r| r.modeled_parallel()).sum::<f64>() + self.central_secs
    }

    /// Total map compute (what a sequential run would pay), plus central.
    pub fn total_compute_secs(&self) -> f64 {
        self.rounds.iter().map(|r| r.total_compute()).sum::<f64>() + self.central_secs
    }

    /// Measured wall time including threading overheads.
    pub fn measured_wall_secs(&self) -> f64 {
        self.rounds.iter().map(|r| r.wall_secs).sum::<f64>() + self.central_secs
    }

    /// Network traffic of this iteration: (leader->workers,
    /// workers->leader) bytes. The paper's requirement 3 — constant-size
    /// global messages — makes this independent of the data size.
    pub fn network_bytes(&self) -> (u64, u64) {
        let tx = self.rounds.iter().map(|r| r.bytes_tx).sum();
        let rx = self.rounds.iter().map(|r| r.bytes_rx).sum();
        (tx, rx)
    }

    /// Total psi recomputations across this iteration's rounds (the
    /// cache-effectiveness counter: with reuse on, equals workers x
    /// evaluations rather than workers x rounds).
    pub fn psi_recomputes(&self) -> u64 {
        self.rounds.iter().map(|r| r.psi_recomputes).sum()
    }

    /// Per-iteration load-balance summary over all rounds'
    /// worker times: (min, mean, max) — the Fig. 5 series.
    pub fn load_min_mean_max(&self) -> (f64, f64, f64) {
        let mut per_worker: Vec<f64> = Vec::new();
        if let Some(first) = self.rounds.first() {
            per_worker = vec![0.0; first.worker_secs.len()];
        }
        for r in &self.rounds {
            for (acc, t) in per_worker.iter_mut().zip(&r.worker_secs) {
                *acc += t;
            }
        }
        (
            stats::min(&per_worker),
            stats::mean(&per_worker),
            stats::max(&per_worker),
        )
    }
}

/// Whole-run telemetry.
#[derive(Debug, Clone, Default)]
pub struct RunLog {
    pub iterations: Vec<IterationLog>,
    /// One-off startup cost (client creation + artifact compilation).
    pub startup_secs: f64,
}

impl RunLog {
    pub fn final_bound(&self) -> f64 {
        self.iterations.last().map(|i| i.f).unwrap_or(f64::NAN)
    }

    pub fn mean_iteration_modeled_secs(&self) -> f64 {
        let v: Vec<f64> = self
            .iterations
            .iter()
            .map(|i| i.modeled_parallel_secs())
            .collect();
        stats::mean(&v)
    }

    pub fn mean_iteration_compute_secs(&self) -> f64 {
        let v: Vec<f64> = self
            .iterations
            .iter()
            .map(|i| i.total_compute_secs())
            .collect();
        stats::mean(&v)
    }

    /// Total network traffic over the run: (tx, rx) bytes.
    pub fn total_network_bytes(&self) -> (u64, u64) {
        let mut tx = 0;
        let mut rx = 0;
        for it in &self.iterations {
            let (t, r) = it.network_bytes();
            tx += t;
            rx += r;
        }
        (tx, rx)
    }

    /// Total psi recomputations over the run.
    pub fn total_psi_recomputes(&self) -> u64 {
        self.iterations.iter().map(|i| i.psi_recomputes()).sum()
    }

    /// Mean relative gap between max and mean worker load (paper §5.1
    /// reports 3.7%).
    pub fn mean_load_gap(&self) -> f64 {
        let gaps: Vec<f64> = self
            .iterations
            .iter()
            .filter_map(|i| {
                let (_, mean, max) = i.load_min_mean_max();
                (mean > 0.0).then_some((max - mean) / mean)
            })
            .collect();
        stats::mean(&gaps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(ws: &[f64], wall: f64) -> RoundTiming {
        RoundTiming {
            worker_secs: ws.to_vec(),
            wall_secs: wall,
            ..Default::default()
        }
    }

    #[test]
    fn network_bytes_aggregate() {
        let mut r1 = round(&[1.0], 1.0);
        r1.bytes_tx = 100;
        r1.bytes_rx = 40;
        r1.psi_recomputes = 2;
        let mut r2 = round(&[1.0], 1.0);
        r2.bytes_tx = 60;
        r2.bytes_rx = 10;
        let it = IterationLog {
            iter: 0,
            f: 0.0,
            rounds: vec![r1, r2],
            central_secs: 0.0,
            failed_workers: vec![],
        };
        assert_eq!(it.network_bytes(), (160, 50));
        assert_eq!(it.psi_recomputes(), 2);
        let log = RunLog {
            iterations: vec![it.clone(), it],
            startup_secs: 0.0,
        };
        assert_eq!(log.total_network_bytes(), (320, 100));
        assert_eq!(log.total_psi_recomputes(), 4);
    }

    #[test]
    fn modeled_times() {
        let r = round(&[1.0, 3.0, 2.0], 6.5);
        assert_eq!(r.modeled_parallel(), 3.0);
        assert_eq!(r.total_compute(), 6.0);
        assert!((r.overhead() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn iteration_aggregates() {
        let it = IterationLog {
            iter: 0,
            f: -10.0,
            rounds: vec![round(&[1.0, 2.0], 3.5), round(&[2.0, 1.0], 3.5)],
            central_secs: 0.5,
            failed_workers: vec![],
        };
        assert_eq!(it.modeled_parallel_secs(), 2.0 + 2.0 + 0.5);
        assert_eq!(it.total_compute_secs(), 6.5);
        let (mn, mean, mx) = it.load_min_mean_max();
        assert_eq!((mn, mean, mx), (3.0, 3.0, 3.0)); // perfectly balanced
    }

    #[test]
    fn load_gap() {
        let it = IterationLog {
            iter: 0,
            f: 0.0,
            rounds: vec![round(&[1.0, 1.0, 2.0], 4.0)],
            central_secs: 0.0,
            failed_workers: vec![],
        };
        let log = RunLog {
            iterations: vec![it],
            startup_secs: 0.0,
        };
        let expected = (2.0 - 4.0 / 3.0) / (4.0 / 3.0);
        assert!((log.mean_load_gap() - expected).abs() < 1e-12);
    }
}
