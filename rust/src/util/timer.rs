//! Wall-clock timing helpers for telemetry and the bench harness.

// The workspace denies `unsafe_code`; this module holds the repo's
// single sanctioned unsafe block (the `clock_gettime` FFI below).
// `gparml analyze` still enforces its SAFETY comment.
#![allow(unsafe_code)]

use std::time::Instant;

/// Measure the wall-clock seconds `f` takes, returning (result, secs).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Per-thread CPU seconds (CLOCK_THREAD_CPUTIME_ID).
///
/// On this single-core container worker threads are time-sliced, so a
/// worker's wall-clock inside a map round includes preemption by its
/// peers. Thread CPU time measures the *work* a node actually did —
/// exactly what the paper's "time spent in the computations alone"
/// series needs for the modeled-cluster clock (DESIGN.md §5).
///
/// Binds `clock_gettime` directly — the `libc` crate is not in the
/// offline set (DESIGN.md §5), and every supported unix links libc
/// anyway. The direct binding is only compiled on 64-bit unix, where
/// `struct timespec` is reliably `{ i64 tv_sec; i64 tv_nsec }`; on
/// 32-bit targets the layout varies (musl >= 1.2 and glibc time64
/// use a 16-byte struct), so guessing would corrupt the stack — those
/// targets get the wall-clock fallback below instead.
#[cfg(all(unix, target_pointer_width = "64"))]
pub fn thread_cpu_secs() -> f64 {
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }
    extern "C" {
        fn clock_gettime(clk_id: i32, tp: *mut Timespec) -> i32;
    }
    #[cfg(target_os = "macos")]
    const CLOCK_THREAD_CPUTIME_ID: i32 = 16;
    #[cfg(not(target_os = "macos"))]
    const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    let mut ts = Timespec {
        tv_sec: 0,
        tv_nsec: 0,
    };
    // SAFETY: `clock_gettime` only writes through `tp`, and `&mut ts`
    // is a valid, exclusive, properly aligned pointer to a live
    // `Timespec` whose `#[repr(C)]` layout matches the platform's
    // 16-byte `struct timespec` on every 64-bit unix this cfg admits
    // (the 32-bit targets with divergent layouts are excluded above).
    // The clock id is a plain integer; an unsupported id makes the
    // call return nonzero, which is handled, not UB.
    let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    if rc != 0 {
        return 0.0;
    }
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Fallback for non-unix and 32-bit unix targets: process-wide
/// monotonic wall clock (no per-thread CPU clock without a platform
/// API whose struct layout we can rely on).
#[cfg(not(all(unix, target_pointer_width = "64")))]
pub fn thread_cpu_secs() -> f64 {
    use std::sync::OnceLock;
    static ORIGIN: OnceLock<Instant> = OnceLock::new();
    ORIGIN.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Measure thread-CPU seconds spent in `f`.
pub fn cpu_timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let c0 = thread_cpu_secs();
    let out = f();
    (out, thread_cpu_secs() - c0)
}

/// A simple accumulating stopwatch: `start`/`stop` pairs add up.
#[derive(Debug, Default, Clone)]
pub struct Stopwatch {
    total: f64,
    started: Option<f64>,
    origin: Option<Instant>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    fn now(&mut self) -> f64 {
        let origin = *self.origin.get_or_insert_with(Instant::now);
        origin.elapsed().as_secs_f64()
    }

    pub fn start(&mut self) {
        let t = self.now();
        self.started = Some(t);
    }

    pub fn stop(&mut self) {
        if let Some(s) = self.started.take() {
            let t = self.now();
            self.total += t - s;
        }
    }

    pub fn total_secs(&self) -> f64 {
        self.total
    }

    pub fn reset(&mut self) {
        self.total = 0.0;
        self.started = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value_and_positive_time() {
        let (v, t) = timed(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499_500);
        assert!(t >= 0.0);
    }

    #[test]
    fn thread_cpu_clock_is_monotonic_and_counts_work() {
        let t0 = thread_cpu_secs();
        assert!(t0 >= 0.0, "CPU clock must not be negative, got {t0}");
        // burn actual CPU (not sleep — the thread clock must tick only
        // when this thread computes)
        let mut acc = 0u64;
        for i in 0..2_000_000u64 {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        assert_ne!(acc, 1, "keep the loop observable");
        let t1 = thread_cpu_secs();
        assert!(
            t1 >= t0,
            "thread CPU clock went backwards: {t0} -> {t1}"
        );
        assert!(t1 > 0.0, "CPU clock still zero after real work");
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        sw.stop();
        let t1 = sw.total_secs();
        assert!(t1 >= 0.004);
        sw.start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        sw.stop();
        assert!(sw.total_secs() > t1);
        sw.reset();
        assert_eq!(sw.total_secs(), 0.0);
    }
}
