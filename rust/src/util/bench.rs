//! Minimal benchmark harness (criterion is not in the offline crate
//! set). Warms up, runs a fixed number of timed repetitions, and
//! reports median / mean / sigma. `cargo bench` drives the
//! `harness = false` targets in `rust/benches/`.

use crate::util::stats;
use crate::util::timer::thread_cpu_secs;

/// One measured series.
pub struct BenchResult {
    pub name: String,
    pub median_s: f64,
    pub mean_s: f64,
    pub std_s: f64,
    pub reps: usize,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} median {:>10.6}s  mean {:>10.6}s  sd {:>9.6}s  ({} reps)",
            self.name, self.median_s, self.mean_s, self.std_s, self.reps
        );
    }
}

/// Time `f` for `reps` repetitions after `warmup` runs (thread-CPU time,
/// stable under the container's time-slicing).
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        let _ = f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let c0 = thread_cpu_secs();
        let _ = f();
        times.push(thread_cpu_secs() - c0);
    }
    let r = BenchResult {
        name: name.to_string(),
        median_s: stats::median(&times),
        mean_s: stats::mean(&times),
        std_s: stats::std_dev(&times),
        reps,
    };
    r.print();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("spin", 1, 5, || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.median_s >= 0.0);
        assert!(r.median_s < 1.0);
        assert_eq!(r.reps, 5);
    }
}
