//! Tiny flag parser for the `gparml` binary, examples and benches
//! (clap is unavailable offline).
//!
//! Grammar: positional arguments plus `--key value` / `--flag` pairs.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(key.to_string(), v);
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse("experiment fig2 --workers 8 --verbose --out=results");
        assert_eq!(a.positional, vec!["experiment", "fig2"]);
        assert_eq!(a.get("workers"), Some("8"));
        assert_eq!(a.get("out"), Some("results"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("workers", 1).unwrap(), 8);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("train");
        assert_eq!(a.get_usize("workers", 4).unwrap(), 4);
        assert_eq!(a.get_f64("lr", 0.1).unwrap(), 0.1);
        assert_eq!(a.get_str("config", "small"), "small");
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("--workers abc");
        assert!(a.get_usize("workers", 1).is_err());
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("--offset -3.5");
        assert_eq!(a.get_f64("offset", 0.0).unwrap(), -3.5);
    }
}
