//! Minimal JSON parser/emitter (serde is unavailable in the offline
//! crate set). Supports the full JSON grammar; numbers are f64.
//!
//! Used for `artifacts/manifest.json`, `artifacts/testvectors.json`,
//! experiment configs and result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Parse the file at `path`.
    pub fn from_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(map) => map
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("expected object while reading key {key:?}"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object"),
        }
    }

    /// Flatten a (possibly nested) numeric array to `Vec<f64>` in row-major
    /// order — how testvector matrices are read.
    pub fn as_f64_flat(&self) -> Result<Vec<f64>> {
        let mut out = Vec::new();
        fn rec(v: &Json, out: &mut Vec<f64>) -> Result<()> {
            match v {
                Json::Num(x) => out.push(*x),
                Json::Arr(items) => {
                    for it in items {
                        rec(it, out)?;
                    }
                }
                _ => bail!("expected numeric array, got {v:?}"),
            }
            Ok(())
        }
        rec(self, &mut out)?;
        Ok(out)
    }

    // ---- emission --------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s);
        s
    }

    fn emit(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => emit_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, it) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.emit(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_str(k, out);
                    out.push(':');
                    v.emit(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience builder: numeric array from a slice.
pub fn num_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

fn emit_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        let x: f64 = s
            .parse()
            .map_err(|_| anyhow!("invalid number {s:?} at byte {start}"))?;
        Ok(Json::Num(x))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_document() {
        let src = r#"{"a": [1, 2.5, -3e-2], "b": {"c": true, "d": null}, "s": "x\"y\n"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"m": 8, "name": "test", "xs": [[1,2],[3,4]]}"#).unwrap();
        assert_eq!(v.get("m").unwrap().as_usize().unwrap(), 8);
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "test");
        assert_eq!(
            v.get("xs").unwrap().as_f64_flat().unwrap(),
            vec![1.0, 2.0, 3.0, 4.0]
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn emit_integers_cleanly() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
