//! Small self-contained substrates: JSON, PRNG, CSV, timing, stats, CLI.
//!
//! The offline crate set for this build contains no serde / rand /
//! clap / criterion, so the handful of utilities the system needs are
//! implemented here from scratch (documented in DESIGN.md §5).

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;
