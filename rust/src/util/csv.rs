//! CSV result writers, plus the numeric-matrix readers the serving CLI
//! and the dataset store use (`gparml predict --points`, `gparml data
//! pack --csv`). Every experiment emits its series to `results/` so
//! figures can be regenerated/plotted externally (EXPERIMENTS.md).
//!
//! Reading is streaming: a buffered line reader, never
//! `read_to_string` (which holds file + matrix simultaneously — 2x
//! peak memory on exactly the million-row files the store exists
//! for). [`read_matrix_chunked`] exposes the same parser as an
//! iterator of row chunks so CSV → store conversion is O(chunk).

use std::fs;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::linalg::Matrix;

/// Shared line parser: the header/ragged/garbage rules below are the
/// contract both readers obey (and the tests pin).
struct RowParser {
    path: String,
    cols: usize,
    seen_content: bool,
}

impl RowParser {
    fn new(path: &Path) -> RowParser {
        RowParser {
            path: path.display().to_string(),
            cols: 0,
            seen_content: false,
        }
    }

    /// `Ok(None)` for blank lines and a (fully non-numeric) header row.
    fn parse_line(&mut self, lineno: usize, line: &str) -> Result<Option<Vec<f64>>> {
        let line = line.trim();
        if line.is_empty() {
            return Ok(None);
        }
        let first_content = !self.seen_content;
        self.seen_content = true;
        let cells: Vec<&str> = line.split(',').map(str::trim).collect();
        let parsed: Result<Vec<f64>, _> = cells.iter().map(|c| c.parse::<f64>()).collect();
        let row = match parsed {
            Ok(row) => row,
            // a fully non-numeric leading row is a header; a partially
            // numeric one is a corrupt data row and must not be skipped
            Err(_) if first_content && cells.iter().all(|c| c.parse::<f64>().is_err()) => {
                return Ok(None)
            }
            Err(_) => bail!("{}:{}: non-numeric cell in {:?}", self.path, lineno + 1, line),
        };
        if self.cols == 0 {
            self.cols = row.len();
        }
        ensure!(
            row.len() == self.cols,
            "{}:{}: row has {} columns, expected {}",
            self.path,
            lineno + 1,
            row.len(),
            self.cols
        );
        Ok(Some(row))
    }
}

/// Read a numeric CSV into a [`Matrix`]. An optional single header row
/// is skipped — but only if NONE of its cells parse as a float, so a
/// data row with one typo is a loud error, never a silently dropped
/// row. Every data row must have the same number of columns; blank
/// lines are ignored. Floats are parsed with Rust's round-trip-exact
/// `f64` parser, so a file written with `{:.17e}` formatting reloads
/// bit-for-bit.
pub fn read_matrix(path: &Path) -> Result<Matrix> {
    let mut data: Vec<f64> = Vec::new();
    let mut rows = 0usize;
    let mut cols = 0usize;
    for chunk in read_matrix_chunked(path, 4096)? {
        let chunk = chunk?;
        cols = chunk.cols();
        rows += chunk.rows();
        data.extend_from_slice(chunk.data());
    }
    ensure!(cols > 0, "{}: no data rows", path.display());
    Ok(Matrix::from_vec(rows, cols, data))
}

/// Streaming CSV reader: yields the file's data rows as matrices of at
/// most `chunk_rows` rows, under exactly [`read_matrix`]'s parsing
/// rules. The file is never materialised — `gparml data pack --csv`
/// streams a CSV into the dataset store through this.
pub fn read_matrix_chunked(path: &Path, chunk_rows: usize) -> Result<CsvChunks> {
    ensure!(chunk_rows >= 1, "chunk_rows must be >= 1");
    let file = fs::File::open(path).with_context(|| format!("reading CSV {}", path.display()))?;
    Ok(CsvChunks {
        lines: BufReader::new(file).lines().enumerate(),
        parser: RowParser::new(path),
        chunk_rows,
        done: false,
    })
}

/// Iterator over a CSV file's row chunks (see [`read_matrix_chunked`]).
pub struct CsvChunks {
    lines: std::iter::Enumerate<std::io::Lines<BufReader<fs::File>>>,
    parser: RowParser,
    chunk_rows: usize,
    done: bool,
}

impl CsvChunks {
    /// Columns per row, once the first data row has been parsed.
    pub fn cols(&self) -> usize {
        self.parser.cols
    }
}

impl Iterator for CsvChunks {
    type Item = Result<Matrix>;

    fn next(&mut self) -> Option<Result<Matrix>> {
        if self.done {
            return None;
        }
        let mut data: Vec<f64> = Vec::new();
        let mut rows = 0usize;
        while rows < self.chunk_rows {
            match self.lines.next() {
                None => {
                    self.done = true;
                    break;
                }
                Some((_, Err(e))) => {
                    self.done = true;
                    return Some(
                        Err(e).with_context(|| format!("reading CSV {}", self.parser.path)),
                    );
                }
                Some((lineno, Ok(line))) => match self.parser.parse_line(lineno, &line) {
                    Err(e) => {
                        self.done = true;
                        return Some(Err(e));
                    }
                    Ok(None) => continue,
                    Ok(Some(row)) => {
                        data.extend_from_slice(&row);
                        rows += 1;
                    }
                },
            }
        }
        if rows == 0 {
            return None;
        }
        Some(Ok(Matrix::from_vec(rows, self.parser.cols, data)))
    }
}

/// A CSV table accumulated in memory and flushed to disk.
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(columns: &[&str]) -> Self {
        CsvWriter {
            header: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of f64 cells (formatted with full precision).
    pub fn row(&mut self, cells: &[f64]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows
            .push(cells.iter().map(|x| format!("{x}")).collect());
    }

    /// Append a row of preformatted cells.
    pub fn row_str(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut f =
            fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
        f.write_all(self.to_string().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_rows() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&[1.0, 2.5]);
        w.row_str(&["x".into(), "y".into()]);
        assert_eq!(w.to_string(), "a,b\n1,2.5\nx,y\n");
    }

    #[test]
    #[should_panic]
    fn panics_on_column_mismatch() {
        let mut w = CsvWriter::new(&["a"]);
        w.row(&[1.0, 2.0]);
    }

    fn tmp_csv(name: &str, content: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("gparml_csv_{}_{name}", std::process::id()));
        fs::write(&p, content).unwrap();
        p
    }

    #[test]
    fn read_matrix_roundtrips_with_and_without_header() {
        let p = tmp_csv("hdr.csv", "x0,x1\n1.5,-2.25e-3\n0,3\n\n4,5\n");
        let m = read_matrix(&p).unwrap();
        fs::remove_file(&p).ok();
        assert_eq!((m.rows(), m.cols()), (3, 2));
        assert_eq!(m[(0, 1)], -2.25e-3);
        assert_eq!(m[(2, 0)], 4.0);

        let p = tmp_csv("nohdr.csv", "1,2,3\n4,5,6\n");
        let m = read_matrix(&p).unwrap();
        fs::remove_file(&p).ok();
        assert_eq!((m.rows(), m.cols()), (2, 3));
        assert_eq!(m[(1, 2)], 6.0);
    }

    #[test]
    fn read_matrix_rejects_ragged_and_garbage_rows() {
        let p = tmp_csv("ragged.csv", "1,2\n3\n");
        let msg = format!("{:#}", read_matrix(&p).unwrap_err());
        fs::remove_file(&p).ok();
        assert!(msg.contains("columns"), "{msg}");

        let p = tmp_csv("garbage.csv", "1,2\nfoo,bar\n");
        let msg = format!("{:#}", read_matrix(&p).unwrap_err());
        fs::remove_file(&p).ok();
        assert!(msg.contains("non-numeric"), "{msg}");

        // a typo in the FIRST row of a headerless file must be a loud
        // error, not a silently skipped "header"
        let p = tmp_csv("typo.csv", "1.0,2.O\n3,4\n");
        let msg = format!("{:#}", read_matrix(&p).unwrap_err());
        fs::remove_file(&p).ok();
        assert!(msg.contains("non-numeric"), "{msg}");

        let p = tmp_csv("empty.csv", "only,a,header\n");
        let msg = format!("{:#}", read_matrix(&p).unwrap_err());
        fs::remove_file(&p).ok();
        assert!(msg.contains("no data"), "{msg}");
    }

    #[test]
    fn chunked_reader_matches_read_matrix_at_every_chunk_size() {
        let mut content = String::from("h0,h1,h2\n");
        for i in 0..23 {
            content.push_str(&format!("{},{},{}\n", i, i * 2, 0.5 * i as f64));
        }
        let p = tmp_csv("chunked.csv", &content);
        let whole = read_matrix(&p).unwrap();
        for chunk_rows in [1usize, 2, 5, 23, 64] {
            let mut rows = 0usize;
            let mut data: Vec<f64> = Vec::new();
            for chunk in read_matrix_chunked(&p, chunk_rows).unwrap() {
                let chunk = chunk.unwrap();
                assert!(chunk.rows() <= chunk_rows);
                rows += chunk.rows();
                data.extend_from_slice(chunk.data());
            }
            assert_eq!(rows, 23, "chunk_rows {chunk_rows}");
            for (a, b) in whole.data().iter().zip(&data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        fs::remove_file(&p).ok();
    }

    #[test]
    fn chunked_reader_propagates_parse_errors_and_stops() {
        let p = tmp_csv("chunked_bad.csv", "1,2\n3,4\nx,y\n5,6\n");
        let mut it = read_matrix_chunked(&p, 1).unwrap();
        assert!(it.next().unwrap().is_ok());
        assert!(it.next().unwrap().is_ok());
        let msg = format!("{:#}", it.next().unwrap().unwrap_err());
        assert!(msg.contains("non-numeric"), "{msg}");
        assert!(it.next().is_none(), "iterator must fuse after an error");
        fs::remove_file(&p).ok();
    }
}
