//! CSV result writers. Every experiment emits its series to `results/`
//! so figures can be regenerated/plotted externally (EXPERIMENTS.md).

use std::fs;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// A CSV table accumulated in memory and flushed to disk.
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(columns: &[&str]) -> Self {
        CsvWriter {
            header: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of f64 cells (formatted with full precision).
    pub fn row(&mut self, cells: &[f64]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows
            .push(cells.iter().map(|x| format!("{x}")).collect());
    }

    /// Append a row of preformatted cells.
    pub fn row_str(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut f =
            fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
        f.write_all(self.to_string().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_rows() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&[1.0, 2.5]);
        w.row_str(&["x".into(), "y".into()]);
        assert_eq!(w.to_string(), "a,b\n1,2.5\nx,y\n");
    }

    #[test]
    #[should_panic]
    fn panics_on_column_mismatch() {
        let mut w = CsvWriter::new(&["a"]);
        w.row(&[1.0, 2.0]);
    }
}
