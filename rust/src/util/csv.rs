//! CSV result writers, plus the numeric-matrix reader the serving CLI
//! uses for `gparml predict --points file.csv`. Every experiment emits
//! its series to `results/` so figures can be regenerated/plotted
//! externally (EXPERIMENTS.md).

use std::fs;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::linalg::Matrix;

/// Read a numeric CSV into a [`Matrix`]. An optional single header row
/// is skipped — but only if NONE of its cells parse as a float, so a
/// data row with one typo is a loud error, never a silently dropped
/// row. Every data row must have the same number of columns; blank
/// lines are ignored. Floats are parsed with Rust's round-trip-exact
/// `f64` parser, so a file written with `{:.17e}` formatting reloads
/// bit-for-bit.
pub fn read_matrix(path: &Path) -> Result<Matrix> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading CSV {}", path.display()))?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut cols = 0usize;
    let mut seen_content = false;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let first_content = !seen_content;
        seen_content = true;
        let cells: Vec<&str> = line.split(',').map(str::trim).collect();
        let parsed: Result<Vec<f64>, _> = cells.iter().map(|c| c.parse::<f64>()).collect();
        let row = match parsed {
            Ok(row) => row,
            // a fully non-numeric leading row is a header; a partially
            // numeric one is a corrupt data row and must not be skipped
            Err(_) if first_content && cells.iter().all(|c| c.parse::<f64>().is_err()) => {
                continue
            }
            Err(_) => bail!(
                "{}:{}: non-numeric cell in {:?}",
                path.display(),
                lineno + 1,
                line
            ),
        };
        if rows.is_empty() {
            cols = row.len();
        }
        ensure!(
            row.len() == cols,
            "{}:{}: row has {} columns, expected {cols}",
            path.display(),
            lineno + 1,
            row.len()
        );
        rows.push(row);
    }
    ensure!(cols > 0, "{}: no data rows", path.display());
    let n = rows.len();
    Ok(Matrix::from_vec(n, cols, rows.into_iter().flatten().collect()))
}

/// A CSV table accumulated in memory and flushed to disk.
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(columns: &[&str]) -> Self {
        CsvWriter {
            header: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of f64 cells (formatted with full precision).
    pub fn row(&mut self, cells: &[f64]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows
            .push(cells.iter().map(|x| format!("{x}")).collect());
    }

    /// Append a row of preformatted cells.
    pub fn row_str(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut f =
            fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
        f.write_all(self.to_string().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_rows() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&[1.0, 2.5]);
        w.row_str(&["x".into(), "y".into()]);
        assert_eq!(w.to_string(), "a,b\n1,2.5\nx,y\n");
    }

    #[test]
    #[should_panic]
    fn panics_on_column_mismatch() {
        let mut w = CsvWriter::new(&["a"]);
        w.row(&[1.0, 2.0]);
    }

    fn tmp_csv(name: &str, content: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("gparml_csv_{}_{name}", std::process::id()));
        fs::write(&p, content).unwrap();
        p
    }

    #[test]
    fn read_matrix_roundtrips_with_and_without_header() {
        let p = tmp_csv("hdr.csv", "x0,x1\n1.5,-2.25e-3\n0,3\n\n4,5\n");
        let m = read_matrix(&p).unwrap();
        fs::remove_file(&p).ok();
        assert_eq!((m.rows(), m.cols()), (3, 2));
        assert_eq!(m[(0, 1)], -2.25e-3);
        assert_eq!(m[(2, 0)], 4.0);

        let p = tmp_csv("nohdr.csv", "1,2,3\n4,5,6\n");
        let m = read_matrix(&p).unwrap();
        fs::remove_file(&p).ok();
        assert_eq!((m.rows(), m.cols()), (2, 3));
        assert_eq!(m[(1, 2)], 6.0);
    }

    #[test]
    fn read_matrix_rejects_ragged_and_garbage_rows() {
        let p = tmp_csv("ragged.csv", "1,2\n3\n");
        let msg = format!("{:#}", read_matrix(&p).unwrap_err());
        fs::remove_file(&p).ok();
        assert!(msg.contains("columns"), "{msg}");

        let p = tmp_csv("garbage.csv", "1,2\nfoo,bar\n");
        let msg = format!("{:#}", read_matrix(&p).unwrap_err());
        fs::remove_file(&p).ok();
        assert!(msg.contains("non-numeric"), "{msg}");

        // a typo in the FIRST row of a headerless file must be a loud
        // error, not a silently skipped "header"
        let p = tmp_csv("typo.csv", "1.0,2.O\n3,4\n");
        let msg = format!("{:#}", read_matrix(&p).unwrap_err());
        fs::remove_file(&p).ok();
        assert!(msg.contains("non-numeric"), "{msg}");

        let p = tmp_csv("empty.csv", "only,a,header\n");
        let msg = format!("{:#}", read_matrix(&p).unwrap_err());
        fs::remove_file(&p).ok();
        assert!(msg.contains("no data"), "{msg}");
    }
}
