//! Deterministic PRNG (xoshiro256++ seeded via splitmix64) with the
//! handful of distributions the system needs. The `rand` crate is not in
//! the offline set; this generator is small, fast, and reproducible —
//! every experiment takes an explicit seed.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the full state
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
            spare: None,
        }
    }

    /// Independent stream for worker `k` (used to give each node its own
    /// reproducible randomness).
    pub fn fork(&mut self, k: u64) -> Rng {
        Rng::new(self.next_u64() ^ k.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Vector of standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Bernoulli(p).
    pub fn flip(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u64> = (0..5).map(|_| Rng::new(42).next_u64()).collect();
        assert!(a.windows(2).all(|w| w[0] == w[1]));
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(3);
        let n = 50_000;
        let xs = rng.normals(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(11);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
