//! Summary statistics used by the telemetry/bench harnesses
//! (criterion is unavailable offline — see DESIGN.md §5).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Minimum (NaN-free input assumed).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolation quantile, q in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Pearson correlation (the Fig-1 embedding-recovery metric).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.118).abs() < 1e-3);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 4.0);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(median(&xs), 2.5);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0];
        assert!((pearson(&xs, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&xs, &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
    }
}
