//! The fleet front door (`gparml lb`): one address that speaks the
//! same wire frames a single `gparml serve` replica would, backed by
//! many of them (DESIGN.md §12).
//!
//! Routing policy: among backends that are healthy and not draining,
//! pick the least-in-flight one, breaking ties round-robin. A
//! transport failure (dial, write, read, desync) marks the backend
//! unhealthy and retries the SAME request ONCE on a sibling, so a
//! `SIGKILL`ed replica costs clients latency, not errors. Semantic
//! errors (`Response::Err` from a replica that answered) are forwarded
//! as-is — the replica spoke; re-asking a sibling would just repeat
//! the answer.
//!
//! Membership comes from one of two upstreams: a control plane polled
//! for `FleetInfo` on an interval, or a static backend list probed
//! with `ModelInfo` (which doubles as the health check and the
//! version-skew source). Version skew across healthy backends is
//! surfaced as the `lb.version_skew` gauge and by `ModelInfo` answers
//! (each reports the version of whichever replica answered it).
//!
//! `Reload` is NOT forwarded to one replica: the lb drives it as a
//! rolling swap across the whole fleet (drain, reload, verify the
//! version advanced, re-enable, next), one replica out of rotation at
//! a time — see [`rolling_reload`].
//!
//! Determinism contract: the lb never touches payload floats; every
//! f64 crosses it bit-for-bit, so a predict through the front door
//! equals a direct predict against any same-version replica exactly.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cluster::wire::{self, Frame, Request, Response};
use crate::fleet::client::ControlClient;
use crate::model::serve::{ConnectOpts, ServeClient, ServedModelInfo};
use crate::obs;

/// How the front door behaves.
#[derive(Debug, Clone)]
pub struct LbOptions {
    /// Exit after this many counted clients (0 = run forever). Same
    /// counting rule as `serve`: a connection counts once it completes
    /// ≥ 1 valid request-bearing frame.
    pub max_clients: u64,
    /// Membership refresh cadence: control-plane `FleetInfo` poll, or
    /// static-backend `ModelInfo` probe.
    pub refresh_ms: u64,
    /// Rolling reload: per-replica bound on waiting for its in-flight
    /// count to reach zero before asking it to reload.
    pub drain_timeout_ms: u64,
    /// Dial/read policy for backend and control connections. Retries
    /// are forced off internally — failover to a sibling IS the lb's
    /// retry policy, and it must not double up underneath.
    pub connect: ConnectOpts,
}

impl Default for LbOptions {
    fn default() -> LbOptions {
        LbOptions {
            max_clients: 0,
            refresh_ms: 1_000,
            drain_timeout_ms: 10_000,
            connect: ConnectOpts::default(),
        }
    }
}

/// Where the lb learns its backend set.
#[derive(Debug, Clone)]
pub enum Upstream {
    /// Poll a `gparml control` plane for the live replica set.
    Control(String),
    /// A fixed backend list — no control plane; health and model
    /// versions come from probing each backend directly.
    Static(Vec<String>),
}

/// What `run_lb` did, for callers and tests.
#[derive(Debug, Clone, Copy, Default)]
pub struct LbStats {
    /// Connections that completed ≥ 1 valid request-bearing frame.
    pub clients: u64,
    /// Requests answered (compute + control, across all clients).
    pub requests: u64,
    /// Requests saved by the one-sibling retry after a backend failed.
    pub failovers: u64,
    /// Replicas successfully rolled by fleet-wide reloads.
    pub reloads: u64,
}

/// Grace window for the shutdown drain, mirroring `serve`.
const DRAIN_GRACE_MS: u64 = 10_000;

#[derive(Default)]
struct Counters {
    clients: AtomicU64,
    requests: AtomicU64,
    failovers: AtomicU64,
    reloads: AtomicU64,
    /// Connection threads currently running (shutdown barrier).
    active_conns: AtomicU64,
}

/// One backend replica as the lb sees it. Health and drain flags are
/// routing inputs only; the entry (and its in-flight count) survives
/// membership refreshes so counts never reset mid-request.
struct Backend {
    addr: String,
    /// Cleared on transport failure, restored by the next successful
    /// membership refresh/probe (the failover path protects clients
    /// in between).
    healthy: AtomicBool,
    /// Set while a rolling reload holds this replica out of rotation.
    draining: AtomicBool,
    /// Requests currently forwarded to this backend.
    in_flight: AtomicU64,
    /// Last model version this backend reported (refresh or reply).
    model_version: AtomicU64,
}

/// The routing pool: the live backend set plus the round-robin cursor
/// used to break least-in-flight ties.
struct Pool {
    members: RwLock<Vec<Arc<Backend>>>,
    rr: AtomicUsize,
    backends_gauge: Arc<obs::Gauge>,
    healthy_gauge: Arc<obs::Gauge>,
    skew_gauge: Arc<obs::Gauge>,
}

impl Pool {
    fn new(registry: &obs::Registry) -> Pool {
        Pool {
            members: RwLock::new(Vec::new()),
            rr: AtomicUsize::new(0),
            backends_gauge: registry.gauge("lb.backends"),
            healthy_gauge: registry.gauge("lb.healthy"),
            skew_gauge: registry.gauge("lb.version_skew"),
        }
    }

    /// Reconcile the member set against `infos` (addr, model version):
    /// existing entries are kept (their in-flight counts persist) and
    /// re-marked healthy — the upstream just vouched for them; if one
    /// is actually unreachable the next forward re-marks it unhealthy
    /// and fails over, so clients stay whole either way. New addresses
    /// join healthy; vanished ones are dropped.
    fn set_members(&self, infos: &[(String, u64)]) {
        // the member Vec stays coherent even if a forwarder panicked
        // (Arc swaps only) — recover instead of poisoning the fleet
        let mut members = self
            .members
            .write()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut next = Vec::with_capacity(infos.len());
        for (addr, version) in infos {
            match members.iter().find(|b| &b.addr == addr) {
                Some(existing) => {
                    existing.model_version.store(*version, Ordering::Release);
                    existing.healthy.store(true, Ordering::Release);
                    next.push(existing.clone());
                }
                None => {
                    eprintln!("[gparml-lb] backend {addr} joined (model version {version})");
                    next.push(Arc::new(Backend {
                        addr: addr.clone(),
                        healthy: AtomicBool::new(true),
                        draining: AtomicBool::new(false),
                        in_flight: AtomicU64::new(0),
                        model_version: AtomicU64::new(*version),
                    }));
                }
            }
        }
        for old in members.iter() {
            if !infos.iter().any(|(addr, _)| addr == &old.addr) {
                eprintln!("[gparml-lb] backend {} left", old.addr);
            }
        }
        *members = next;
        drop(members);
        self.update_gauges();
    }

    /// Pick a backend for one request: healthy, not draining, not the
    /// `exclude` address (the one that just failed), least in-flight,
    /// round-robin among ties.
    fn pick(&self, exclude: Option<&str>) -> Option<Arc<Backend>> {
        let members = self
            .members
            .read()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let eligible: Vec<&Arc<Backend>> = members
            .iter()
            .filter(|b| {
                b.healthy.load(Ordering::Acquire)
                    && !b.draining.load(Ordering::Acquire)
                    && match exclude {
                        Some(addr) => b.addr != addr,
                        None => true,
                    }
            })
            .collect();
        let min = eligible
            .iter()
            .map(|b| b.in_flight.load(Ordering::Acquire))
            .min()?;
        let tied: Vec<&Arc<Backend>> = eligible
            .into_iter()
            .filter(|b| b.in_flight.load(Ordering::Acquire) == min)
            .collect();
        let at = self.rr.fetch_add(1, Ordering::AcqRel) % tied.len();
        Some(tied[at].clone())
    }

    /// The current member set in upstream (address-sorted) order.
    fn snapshot(&self) -> Vec<Arc<Backend>> {
        self.members
            .read()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .clone()
    }

    fn update_gauges(&self) {
        let members = self
            .members
            .read()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        self.backends_gauge.set(members.len() as u64);
        let healthy: Vec<&Arc<Backend>> = members
            .iter()
            .filter(|b| b.healthy.load(Ordering::Acquire))
            .collect();
        self.healthy_gauge.set(healthy.len() as u64);
        let mut versions: Vec<u64> = healthy
            .iter()
            .map(|b| b.model_version.load(Ordering::Acquire))
            .collect();
        versions.sort_unstable();
        versions.dedup();
        self.skew_gauge.set(u64::from(versions.len() > 1));
    }
}

/// Cached handles into the lb [`obs::Registry`] (it answers
/// `ServeStats` frames with its own snapshot, like every other
/// gparml server).
struct LbMetrics {
    registry: obs::Registry,
    clients: Arc<obs::Counter>,
    req_predict: Arc<obs::Counter>,
    req_project: Arc<obs::Counter>,
    req_model_info: Arc<obs::Counter>,
    req_reload: Arc<obs::Counter>,
    req_stats: Arc<obs::Counter>,
    req_ping: Arc<obs::Counter>,
    req_rejected: Arc<obs::Counter>,
    /// Requests saved by the one-sibling retry.
    failovers: Arc<obs::Counter>,
    /// Backend transport failures observed while forwarding.
    backend_errors: Arc<obs::Counter>,
    /// Requests refused because no eligible backend remained.
    no_backend: Arc<obs::Counter>,
    /// Replicas rolled by fleet-wide reloads.
    reloads: Arc<obs::Counter>,
    /// Accept -> reply-written latency per forwarded request.
    request_ns: Arc<obs::Histogram>,
}

impl LbMetrics {
    fn new() -> LbMetrics {
        let registry = obs::Registry::new();
        LbMetrics {
            clients: registry.counter("lb.clients"),
            req_predict: registry.counter("lb.requests.predict"),
            req_project: registry.counter("lb.requests.project"),
            req_model_info: registry.counter("lb.requests.model_info"),
            req_reload: registry.counter("lb.requests.reload"),
            req_stats: registry.counter("lb.requests.stats"),
            req_ping: registry.counter("lb.requests.ping"),
            req_rejected: registry.counter("lb.requests.rejected"),
            failovers: registry.counter("lb.failovers"),
            backend_errors: registry.counter("lb.backend_errors"),
            no_backend: registry.counter("lb.no_backend"),
            reloads: registry.counter("lb.reloads"),
            request_ns: registry.histogram("lb.request_ns"),
            registry,
        }
    }
}

// ---------------------------------------------------------------------------
// server
// ---------------------------------------------------------------------------

/// Run the front door on `listener` until [`LbOptions::max_clients`]
/// counted clients have been served (0 = forever). Blocks; returns the
/// run's [`LbStats`]. The accept/drain scaffolding mirrors
/// `model::serve::serve` so tests and the bench can drive it the same
/// way.
pub fn run_lb(listener: &TcpListener, upstream: &Upstream, opts: &LbOptions) -> Result<LbStats> {
    listener
        .set_nonblocking(true)
        .context("setting the lb listener nonblocking")?;
    let metrics = LbMetrics::new();
    let pool = Pool::new(&metrics.registry);
    // static members route immediately; the refresher only adjusts
    // health and versions. Control members arrive on the first poll.
    if let Upstream::Static(addrs) = upstream {
        let infos: Vec<(String, u64)> = addrs.iter().map(|a| (a.clone(), 0)).collect();
        pool.set_members(&infos);
    }
    let counters = Counters::default();
    let stop_refresh = AtomicBool::new(false);
    // socket handles of live connections, so the shutdown drain can
    // force-close stragglers (handlers deregister on exit)
    let registry: Mutex<HashMap<u64, TcpStream>> = Mutex::new(HashMap::new());
    let mut next_conn = 0u64;

    std::thread::scope(|s| {
        {
            let (pool, metrics, stop) = (&pool, &metrics, &stop_refresh);
            s.spawn(move || refresher(upstream, pool, opts, stop, metrics));
        }
        loop {
            let served = counters.clients.load(Ordering::Acquire);
            if opts.max_clients != 0 && served >= opts.max_clients {
                break;
            }
            match listener.accept() {
                Ok((stream, peer)) => {
                    counters.active_conns.fetch_add(1, Ordering::AcqRel);
                    let conn_id = next_conn;
                    next_conn += 1;
                    if let Ok(clone) = stream.try_clone() {
                        registry
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner)
                            .insert(conn_id, clone);
                    }
                    let (pool, counters, registry, metrics) =
                        (&pool, &counters, &registry, &metrics);
                    s.spawn(move || {
                        let client = lb_client(stream, pool, opts, counters, metrics);
                        match client {
                            Ok(requests) => {
                                eprintln!("[gparml-lb] client {peer}: {requests} request(s)")
                            }
                            Err(e) => eprintln!("[gparml-lb] client {peer} failed: {e:#}"),
                        }
                        registry
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner)
                            .remove(&conn_id);
                        counters.active_conns.fetch_sub(1, Ordering::AcqRel);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                // transient under load: log, back off, keep serving
                Err(e) => {
                    eprintln!("[gparml-lb] accept failed (retrying): {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
        // drain in-flight connections, force-closing stragglers after
        // the grace window so `--clients N` always exits
        let mut waited_ms = 0u64;
        while counters.active_conns.load(Ordering::Acquire) > 0 {
            std::thread::sleep(Duration::from_millis(5));
            waited_ms += 5;
            if waited_ms == DRAIN_GRACE_MS {
                // the guard is deliberately live across shutdown() (a
                // non-blocking fd call) so handlers cannot deregister
                // mid-sweep; justified in analyze-allowlist.toml
                let conns = registry
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                if !conns.is_empty() {
                    eprintln!(
                        "[gparml-lb] force-closing {} lingering connection(s) after the \
                         {DRAIN_GRACE_MS}ms drain grace",
                        conns.len()
                    );
                    for conn in conns.values() {
                        let _ = conn.shutdown(std::net::Shutdown::Both);
                    }
                }
            }
        }
        stop_refresh.store(true, Ordering::Release);
    });
    listener.set_nonblocking(false).ok();

    Ok(LbStats {
        clients: counters.clients.load(Ordering::Acquire),
        requests: counters.requests.load(Ordering::Acquire),
        failovers: counters.failovers.load(Ordering::Acquire),
        reloads: counters.reloads.load(Ordering::Acquire),
    })
}

/// Keep the pool in sync with the upstream until `stop` is set. A poll
/// failure leaves the pool unchanged — the lb keeps routing to the
/// last known set rather than dropping to zero backends because the
/// control plane blipped.
fn refresher(
    upstream: &Upstream,
    pool: &Pool,
    opts: &LbOptions,
    stop: &AtomicBool,
    metrics: &LbMetrics,
) {
    let mut control: Option<ControlClient> = None;
    let mut probes: HashMap<String, ServeClient> = HashMap::new();
    let mut control_down = false;
    while !stop.load(Ordering::Acquire) {
        match upstream {
            Upstream::Control(addr) => {
                let polled = poll_control(&mut control, addr, &opts.connect);
                match polled {
                    Ok(infos) => {
                        pool.set_members(&infos);
                        if control_down {
                            eprintln!("[gparml-lb] control plane at {addr} is back");
                            control_down = false;
                        }
                    }
                    Err(e) => {
                        control = None;
                        if !control_down {
                            eprintln!(
                                "[gparml-lb] control plane at {addr} unreachable (routing to \
                                 the last known set; will keep retrying): {e:#}"
                            );
                            control_down = true;
                        }
                    }
                }
            }
            Upstream::Static(_) => {
                for backend in pool.snapshot() {
                    match probe(&mut probes, &backend.addr, &opts.connect) {
                        Ok(info) => {
                            backend.model_version.store(info.version, Ordering::Release);
                            if !backend.healthy.swap(true, Ordering::AcqRel) {
                                eprintln!("[gparml-lb] backend {} is back", backend.addr);
                            }
                        }
                        Err(e) => {
                            probes.remove(&backend.addr);
                            if backend.healthy.swap(false, Ordering::AcqRel) {
                                metrics.backend_errors.inc();
                                eprintln!(
                                    "[gparml-lb] backend {} failed its probe: {e:#}",
                                    backend.addr
                                );
                            }
                        }
                    }
                }
                pool.update_gauges();
            }
        }
        // sleep in short steps so stop stays responsive
        let mut slept = 0u64;
        while slept < opts.refresh_ms.max(25) && !stop.load(Ordering::Acquire) {
            std::thread::sleep(Duration::from_millis(25));
            slept += 25;
        }
    }
}

/// One `FleetInfo` poll, (re)dialing the control plane as needed.
fn poll_control(
    control: &mut Option<ControlClient>,
    addr: &str,
    connect: &ConnectOpts,
) -> Result<Vec<(String, u64)>> {
    let client = match control {
        Some(client) => client,
        None => control.insert(ControlClient::with_opts(addr, connect.clone().no_retry())?),
    };
    let replicas = client.fleet_info()?;
    Ok(replicas
        .into_iter()
        .map(|r| (r.addr, r.model_version))
        .collect())
}

/// One `ModelInfo` probe of a static backend over a cached connection
/// (the caller drops the cache entry on failure).
fn probe(
    probes: &mut HashMap<String, ServeClient>,
    addr: &str,
    connect: &ConnectOpts,
) -> Result<ServedModelInfo> {
    let client = match probes.entry(addr.to_string()) {
        std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
        std::collections::hash_map::Entry::Vacant(v) => {
            v.insert(ServeClient::with_opts(addr, connect.clone().no_retry())?)
        }
    };
    client.model_info()
}

// ---------------------------------------------------------------------------
// per-connection forwarding
// ---------------------------------------------------------------------------

/// Serve one front-door client until `Shutdown`, EOF or an error.
/// Returns the number of requests answered. Backend connections are
/// cached per client connection (one hop each way, reused across
/// requests) and dropped on the first transport failure.
fn lb_client(
    mut stream: TcpStream,
    pool: &Pool,
    opts: &LbOptions,
    counters: &Counters,
    metrics: &LbMetrics,
) -> Result<u64> {
    // the listener is nonblocking (accept-loop polling); the accepted
    // socket must not inherit that
    stream.set_nonblocking(false).ok();
    stream.set_nodelay(true).ok();
    let mut conns: HashMap<String, ServeClient> = HashMap::new();
    let mut served = 0u64;
    let mut counted = false;
    loop {
        let (trace_id, req) = match wire::read_frame(&mut stream)? {
            None | Some((Frame::Shutdown, _)) => return Ok(served),
            Some((Frame::Ping, _)) => {
                count_client(&mut counted, counters, metrics);
                metrics.req_ping.inc();
                wire::write_frame(&mut stream, &Frame::Pong)?;
                served += 1;
                counters.requests.fetch_add(1, Ordering::AcqRel);
                continue;
            }
            Some((Frame::Request { trace_id, req }, _)) => {
                count_client(&mut counted, counters, metrics);
                (trace_id, req)
            }
            Some((f, _)) => bail!("unexpected frame {f:?} from lb client"),
        };
        let t0 = Instant::now();
        match &*req {
            Request::ServePredict { .. } | Request::ServeProject { .. } | Request::ModelInfo => {
                match &*req {
                    Request::ServePredict { .. } => metrics.req_predict.inc(),
                    Request::ServeProject { .. } => metrics.req_project.inc(),
                    _ => metrics.req_model_info.inc(),
                }
                let resp = forward(&mut conns, pool, opts, trace_id, &req, counters, metrics);
                respond(&mut stream, trace_id, resp)?;
            }
            Request::Reload => {
                metrics.req_reload.inc();
                let resp = match rolling_reload(pool, opts, counters, metrics) {
                    Ok(resp) => resp,
                    Err(e) => Response::Err(format!("{e:#}")),
                };
                respond(&mut stream, trace_id, resp)?;
            }
            // the lb answers stats from its OWN registry — scrape a
            // replica directly for per-replica serve metrics
            Request::ServeStats => {
                metrics.req_stats.inc();
                let json = metrics.registry.snapshot_json().to_string();
                respond(&mut stream, trace_id, Response::StatsJson(json))?;
            }
            other => {
                metrics.req_rejected.inc();
                respond(
                    &mut stream,
                    trace_id,
                    Response::Err(format!(
                        "lb front door only answers ServePredict/ServeProject/ModelInfo/\
                         Reload/ServeStats, got {other:?}"
                    )),
                )?;
            }
        }
        metrics.request_ns.record(t0.elapsed().as_nanos() as u64);
        served += 1;
        counters.requests.fetch_add(1, Ordering::AcqRel);
    }
}

/// Route one request to a healthy replica, preserving the client's
/// trace id across the hop. A transport failure marks the backend
/// unhealthy and retries ONCE on a sibling (never the same address);
/// a second failure — or an empty pool — yields `Response::Err`.
fn forward(
    conns: &mut HashMap<String, ServeClient>,
    pool: &Pool,
    opts: &LbOptions,
    trace_id: u64,
    req: &Request,
    counters: &Counters,
    metrics: &LbMetrics,
) -> Response {
    let mut failed: Option<String> = None;
    for attempt in 0..2 {
        let Some(backend) = pool.pick(failed.as_deref()) else {
            break;
        };
        backend.in_flight.fetch_add(1, Ordering::AcqRel);
        let result = backend_request(conns, &backend.addr, &opts.connect, trace_id, req);
        backend.in_flight.fetch_sub(1, Ordering::AcqRel);
        match result {
            Ok(resp) => {
                if let Response::ModelInfo { version, .. } = &resp {
                    backend.model_version.store(*version, Ordering::Release);
                    pool.update_gauges();
                }
                if attempt == 1 {
                    counters.failovers.fetch_add(1, Ordering::AcqRel);
                    metrics.failovers.inc();
                }
                return resp;
            }
            Err(e) => {
                eprintln!(
                    "[gparml-lb] backend {} failed{}: {e:#}",
                    backend.addr,
                    if attempt == 0 { ", retrying on a sibling" } else { "" }
                );
                metrics.backend_errors.inc();
                backend.healthy.store(false, Ordering::Release);
                pool.update_gauges();
                conns.remove(&backend.addr);
                failed = Some(backend.addr.clone());
            }
        }
    }
    metrics.no_backend.inc();
    Response::Err(match failed {
        Some(addr) => format!("no healthy replica could answer (last failure on {addr})"),
        None => "no healthy replicas in the fleet".to_string(),
    })
}

/// One request over the cached per-client-connection backend link,
/// dialing lazily. Exactly one attempt — failover policy lives in
/// [`forward`].
fn backend_request(
    conns: &mut HashMap<String, ServeClient>,
    addr: &str,
    connect: &ConnectOpts,
    trace_id: u64,
    req: &Request,
) -> Result<Response> {
    let client = match conns.entry(addr.to_string()) {
        std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
        std::collections::hash_map::Entry::Vacant(v) => {
            v.insert(ServeClient::with_opts(addr, connect.clone().no_retry())?)
        }
    };
    client.request_with_id(trace_id, req)
}

// ---------------------------------------------------------------------------
// rolling reload
// ---------------------------------------------------------------------------

/// Drive a fleet-wide reload as a rolling swap, in address order: take
/// one replica out of rotation (drain flag), wait for its in-flight
/// count to reach zero, ask it to reload over a direct connection,
/// verify the version advanced, put it back, move on. One replica is
/// out at a time, so a fleet of ≥ 2 keeps serving throughout.
///
/// Stops at the first failure (already-rolled replicas keep the new
/// model — reloads are idempotent on the artifact bytes, so re-issuing
/// once the replica is fixed converges the rest). On success answers
/// with the last replica's `ModelInfo`, and warns + sets the
/// `lb.version_skew` gauge if the fleet's versions still disagree
/// (replicas restarted at different times count reloads from
/// different bases).
fn rolling_reload(
    pool: &Pool,
    opts: &LbOptions,
    counters: &Counters,
    metrics: &LbMetrics,
) -> Result<Response> {
    let members = pool.snapshot();
    if members.is_empty() {
        bail!("no replicas in the fleet to reload");
    }
    let mut last: Option<ServedModelInfo> = None;
    for backend in &members {
        if !backend.healthy.load(Ordering::Acquire) {
            bail!(
                "replica {} is unhealthy; evict or recover it before a rolling reload",
                backend.addr
            );
        }
        backend.draining.store(true, Ordering::Release);
        let drained = wait_drained(backend, opts.drain_timeout_ms);
        let rolled = roll_one(backend, drained, opts);
        backend.draining.store(false, Ordering::Release);
        let info = rolled.with_context(|| {
            format!(
                "rolling reload stopped at replica {} (earlier replicas keep the new \
                 model; re-issue the reload to converge)",
                backend.addr
            )
        })?;
        backend.model_version.store(info.version, Ordering::Release);
        pool.update_gauges();
        counters.reloads.fetch_add(1, Ordering::AcqRel);
        metrics.reloads.inc();
        eprintln!(
            "[gparml-lb] rolled {} to model version {}",
            backend.addr, info.version
        );
        last = Some(info);
    }
    let mut versions: Vec<u64> = members
        .iter()
        .map(|b| b.model_version.load(Ordering::Acquire))
        .collect();
    versions.sort_unstable();
    versions.dedup();
    if versions.len() > 1 {
        eprintln!(
            "[gparml-lb] WARNING: fleet model versions disagree after the rolling reload \
             ({versions:?}) — replicas count reloads from their own start, so skew here \
             means a replica joined mid-history; predictions still come from the same \
             artifact bytes"
        );
    }
    pool.update_gauges();
    let info = match last {
        Some(info) => info,
        None => bail!("the fleet emptied out mid-reload; nothing was rolled"),
    };
    Ok(Response::ModelInfo {
        m: info.m as u32,
        q: info.q as u32,
        d: info.d as u32,
        version: info.version,
    })
}

/// Reload one drained replica over a fresh direct connection and
/// verify its version advanced.
fn roll_one(backend: &Backend, drained: bool, opts: &LbOptions) -> Result<ServedModelInfo> {
    if !drained {
        bail!(
            "drain timed out after {}ms with {} request(s) still in flight",
            opts.drain_timeout_ms,
            backend.in_flight.load(Ordering::Acquire)
        );
    }
    let mut direct = ServeClient::with_opts(&backend.addr, opts.connect.clone().no_retry())?;
    let before = direct.model_info()?.version;
    let info = direct.reload()?;
    anyhow::ensure!(
        info.version > before,
        "replica reported model version {} after the reload (was {})",
        info.version,
        before
    );
    Ok(info)
}

/// Wait for a draining backend's in-flight count to reach zero,
/// bounded by `timeout_ms`. Best-effort capacity management, not a
/// correctness gate: a request that races the drain flag still
/// finishes safely on the replica's old model (its reload swap is
/// atomic and in-flight work completes first).
fn wait_drained(backend: &Backend, timeout_ms: u64) -> bool {
    let deadline = Instant::now() + Duration::from_millis(timeout_ms);
    while backend.in_flight.load(Ordering::Acquire) > 0 {
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    true
}

/// Count this connection toward `--clients` on its first valid
/// request-bearing frame (never at accept time) — same rule as
/// `serve`, so tests drive both the same way.
fn count_client(counted: &mut bool, counters: &Counters, metrics: &LbMetrics) {
    if !*counted {
        *counted = true;
        counters.clients.fetch_add(1, Ordering::AcqRel);
        metrics.clients.inc();
    }
}

/// Write a response frame echoing the request's trace id.
fn respond(stream: &mut TcpStream, trace_id: u64, resp: Response) -> Result<()> {
    wire::write_frame(
        stream,
        &Frame::Response {
            trace_id,
            secs: 0.0,
            psi_fills: 0,
            resp: Box::new(resp),
        },
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(addr: &str, version: u64) -> (String, u64) {
        (addr.to_string(), version)
    }

    #[test]
    fn pick_prefers_least_in_flight_and_skips_ineligible() {
        let registry = obs::Registry::new();
        let pool = Pool::new(&registry);
        pool.set_members(&[entry("a:1", 1), entry("b:1", 1), entry("c:1", 1)]);
        let members = pool.snapshot();
        members[0].in_flight.store(3, Ordering::Release);
        members[1].in_flight.store(1, Ordering::Release);
        members[2].in_flight.store(2, Ordering::Release);
        let picked = pool.pick(None).expect("pool non-empty");
        assert_eq!(picked.addr, "b:1");

        // the least-loaded backend is excluded after a failure
        let picked = pool.pick(Some("b:1")).expect("siblings remain");
        assert_eq!(picked.addr, "c:1");

        // draining and unhealthy members never route
        members[1].draining.store(true, Ordering::Release);
        members[2].healthy.store(false, Ordering::Release);
        let picked = pool.pick(None).expect("a:1 remains");
        assert_eq!(picked.addr, "a:1");
        assert!(pool.pick(Some("a:1")).is_none());
    }

    #[test]
    fn pick_round_robins_among_ties() {
        let registry = obs::Registry::new();
        let pool = Pool::new(&registry);
        pool.set_members(&[entry("a:1", 1), entry("b:1", 1)]);
        let first = pool.pick(None).expect("pool non-empty").addr.clone();
        let second = pool.pick(None).expect("pool non-empty").addr.clone();
        assert_ne!(first, second, "equal in-flight counts must alternate");
    }

    #[test]
    fn set_members_preserves_entries_and_tracks_skew() {
        let registry = obs::Registry::new();
        let pool = Pool::new(&registry);
        pool.set_members(&[entry("a:1", 1), entry("b:1", 1)]);
        let a = pool.snapshot()[0].clone();
        a.in_flight.store(7, Ordering::Release);
        a.healthy.store(false, Ordering::Release);

        // refresh: a kept (in-flight survives, health restored by the
        // upstream vouching for it), b dropped, c joins with a newer
        // version -> skew gauge trips
        pool.set_members(&[entry("a:1", 1), entry("c:1", 2)]);
        let members = pool.snapshot();
        assert_eq!(members.len(), 2);
        assert_eq!(members[0].addr, "a:1");
        assert_eq!(members[0].in_flight.load(Ordering::Acquire), 7);
        assert!(members[0].healthy.load(Ordering::Acquire));
        assert_eq!(members[1].addr, "c:1");
        assert_eq!(registry.gauge("lb.version_skew").get(), 1);
        assert_eq!(registry.gauge("lb.backends").get(), 2);

        // converged versions clear the skew gauge
        pool.set_members(&[entry("a:1", 2), entry("c:1", 2)]);
        assert_eq!(registry.gauge("lb.version_skew").get(), 0);
    }
}
