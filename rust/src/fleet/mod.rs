//! The replicated serve fleet (DESIGN.md §12): many `gparml serve`
//! replicas behaving as ONE service.
//!
//! Three pieces, all speaking the existing framed transport
//! (`cluster/wire.rs`, v8):
//!
//! * [`control`] — the control plane (`gparml control`): a registry
//!   process serve replicas register with (`Register` /
//!   `ReplicaHeartbeat` / `Deregister` frames), with
//!   heartbeat-staleness eviction and live `obs::metrics` gauges. It
//!   holds no model and forwards nothing; it only answers "who is in
//!   the fleet right now" (`FleetInfo`).
//! * [`lb`] — the front door (`gparml lb`): accepts the same client
//!   frames a single replica would (`ServePredict` / `ServeProject` /
//!   `ModelInfo` / `Reload` / `ServeStats`) and routes compute across
//!   healthy replicas (round-robin + least-in-flight), retrying a
//!   failed replica once on a sibling, surfacing version skew via the
//!   `ModelInfo` model version, and driving fleet-wide `Reload` as a
//!   rolling swap.
//! * [`client`] — the replica side: [`client::ControlClient`] (typed
//!   verbs over a [`crate::model::serve::ServeClient`]) and the
//!   registration loop `gparml serve --control` runs next to its
//!   accept loop.
//!
//! The serving contract is unchanged: every f64 crosses each hop
//! bit-for-bit, so a predict answered through the lb equals a direct
//! predict against any replica of the same model exactly (tested in
//! `tests/fleet.rs`).

pub mod client;
pub mod control;
pub mod lb;

pub use client::ControlClient;
pub use control::{run_control, ControlOptions, FleetRegistry};
pub use lb::{run_lb, LbOptions, LbStats, Upstream};
