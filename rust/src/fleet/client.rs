//! The replica side of the fleet protocol: a typed control-plane
//! client plus the registration loop `gparml serve --control` runs
//! beside its accept loop (DESIGN.md §12).

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::cluster::wire::{ReplicaInfo, Request, Response};
use crate::model::serve::{ConnectOpts, ServeClient, ServeState};

/// Typed verbs of the v8 control protocol over a [`ServeClient`] —
/// the same one-connection/deadline/retry machinery the serve verbs
/// use, pointed at a `gparml control` process.
pub struct ControlClient {
    client: ServeClient,
}

impl ControlClient {
    /// Dial a control plane with the default policy.
    pub fn connect(addr: &str) -> Result<ControlClient> {
        ControlClient::with_opts(addr, ConnectOpts::default())
    }

    /// Dial a control plane with an explicit policy.
    pub fn with_opts(addr: &str, opts: ConnectOpts) -> Result<ControlClient> {
        Ok(ControlClient {
            client: ServeClient::with_opts(addr, opts)?,
        })
    }

    /// The control-plane address this client dials.
    pub fn addr(&self) -> &str {
        self.client.addr()
    }

    fn expect_ok(resp: Response) -> Result<()> {
        match resp {
            Response::Ok => Ok(()),
            Response::Err(e) => bail!("control plane: {e}"),
            other => bail!("unexpected control reply {other:?}"),
        }
    }

    /// Join the fleet as `addr` (the serve address the replica
    /// advertises), reporting its current model version. Idempotent:
    /// re-registering upserts.
    pub fn register(&mut self, addr: &str, model_version: u64) -> Result<()> {
        let req = Request::Register {
            addr: addr.to_string(),
            model_version,
        };
        ControlClient::expect_ok(self.client.request(&req)?.0)
    }

    /// Liveness + current model version. A heartbeat for an address
    /// the control plane forgot is an implicit re-register.
    pub fn heartbeat(&mut self, addr: &str, model_version: u64) -> Result<()> {
        let req = Request::ReplicaHeartbeat {
            addr: addr.to_string(),
            model_version,
        };
        ControlClient::expect_ok(self.client.request(&req)?.0)
    }

    /// Leave the fleet cleanly (idempotent).
    pub fn deregister(&mut self, addr: &str) -> Result<()> {
        let req = Request::Deregister {
            addr: addr.to_string(),
        };
        ControlClient::expect_ok(self.client.request(&req)?.0)
    }

    /// The live replica set (the control plane evicts stale members
    /// before answering).
    pub fn fleet_info(&mut self) -> Result<Vec<ReplicaInfo>> {
        match self.client.request(&Request::FleetInfo)?.0 {
            Response::FleetInfo { replicas } => Ok(replicas),
            Response::Err(e) => bail!("control plane: {e}"),
            other => bail!("unexpected FleetInfo reply {other:?}"),
        }
    }
}

/// Run the replica registration protocol until `stop` is set:
/// register, heartbeat every `interval` (reading the live model
/// version from `state`, so a hot reload is advertised on the next
/// beat), reconnect-and-re-register after control-plane outages, and
/// deregister cleanly on the way out.
///
/// `gparml serve --control` runs this on a scoped thread beside the
/// accept loop and sets `stop` when `serve()` returns.
pub fn registration_loop(
    control_addr: &str,
    advertise: &str,
    state: &ServeState,
    interval: Duration,
    stop: &AtomicBool,
) {
    let mut client: Option<ControlClient> = None;
    let mut control_down = false;
    let mut next_beat = Instant::now(); // first beat immediately
    while !stop.load(Ordering::Acquire) {
        let now = Instant::now();
        if now >= next_beat {
            next_beat = now + interval;
            let version = state.current().version;
            match beat(&mut client, control_addr, advertise, version) {
                Ok(()) => {
                    if control_down {
                        eprintln!(
                            "[gparml-serve] control plane at {control_addr} is back; re-registered"
                        );
                        control_down = false;
                    }
                }
                Err(e) => {
                    client = None;
                    if !control_down {
                        eprintln!(
                            "[gparml-serve] control plane at {control_addr} unreachable \
                             (serving continues; will keep retrying): {e:#}"
                        );
                        control_down = true;
                    }
                }
            }
        }
        // short naps so `stop` stays responsive between beats
        std::thread::sleep(Duration::from_millis(25));
    }
    if let Some(mut c) = client {
        let _ = c.deregister(advertise);
    }
}

/// One beat: (re)dial + register on a fresh connection, heartbeat on
/// an established one. Failover to the sibling makes no sense here —
/// there is one control plane — so internal retries are disabled and
/// the loop's cadence is the retry policy.
fn beat(
    client: &mut Option<ControlClient>,
    control_addr: &str,
    advertise: &str,
    version: u64,
) -> Result<()> {
    match client {
        None => {
            let mut fresh =
                ControlClient::with_opts(control_addr, ConnectOpts::default().no_retry())?;
            fresh.register(advertise, version)?;
            *client = Some(fresh);
            Ok(())
        }
        Some(c) => c.heartbeat(advertise, version),
    }
}
