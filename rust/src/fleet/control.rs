//! The fleet control plane (`gparml control`): a registry process
//! serve replicas register with over the v8 wire frames
//! (DESIGN.md §12).
//!
//! The control plane is deliberately tiny and holds no model: its only
//! job is membership. Replicas `Register` once per connection and then
//! `ReplicaHeartbeat` on an interval; the lb polls `FleetInfo` and
//! routes to whatever the reply names. Liveness is decided two ways,
//! both conservative:
//!
//! * **connection drop** — a replica's registration is tied to the
//!   connection it arrived on; when that connection dies (EOF, error,
//!   `Shutdown`), every member registered through it is removed at
//!   once (implicit deregister). A replica that reconnects re-registers
//!   on its next heartbeat (a heartbeat for an unknown address is an
//!   implicit `Register` — v8 contract).
//! * **heartbeat staleness** — members not heard from within
//!   [`ControlOptions::stale_ms`] are evicted by a background sweep
//!   and (belt-and-braces) on every `FleetInfo` answer, so a wedged
//!   replica whose TCP connection stays open still leaves the fleet.
//!
//! Membership changes feed `obs::metrics` (`fleet.replicas` gauge,
//! register/deregister/heartbeat/eviction counters); `gparml stats
//! --connect <control>` scrapes them over the same `ServeStats` frame
//! every other server answers.

use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::cluster::wire::{self, Frame, ReplicaInfo, Request, Response};
use crate::obs;

/// How the control plane behaves.
#[derive(Debug, Clone)]
pub struct ControlOptions {
    /// Heartbeat-staleness window: a member not heard from for this
    /// long is evicted.
    pub stale_ms: u64,
    /// Background eviction sweep cadence.
    pub sweep_ms: u64,
}

impl Default for ControlOptions {
    fn default() -> ControlOptions {
        ControlOptions {
            stale_ms: 5_000,
            sweep_ms: 500,
        }
    }
}

struct Member {
    model_version: u64,
    last_seen: Instant,
    /// The control connection this registration is tied to; when it
    /// drops, the member goes with it.
    conn_id: u64,
}

/// The fleet membership state machine, separated from the accept loop
/// so it can be unit-tested with explicit clocks (`now` is always a
/// parameter, never sampled inside).
pub struct FleetRegistry {
    registry: obs::Registry,
    inner: Mutex<BTreeMap<String, Member>>,
    replicas: Arc<obs::Gauge>,
    registers: Arc<obs::Counter>,
    deregisters: Arc<obs::Counter>,
    heartbeats: Arc<obs::Counter>,
    evictions: Arc<obs::Counter>,
}

impl Default for FleetRegistry {
    fn default() -> FleetRegistry {
        FleetRegistry::new()
    }
}

impl FleetRegistry {
    pub fn new() -> FleetRegistry {
        let registry = obs::Registry::new();
        FleetRegistry {
            replicas: registry.gauge("fleet.replicas"),
            registers: registry.counter("fleet.registers"),
            deregisters: registry.counter("fleet.deregisters"),
            heartbeats: registry.counter("fleet.heartbeats"),
            evictions: registry.counter("fleet.evictions"),
            inner: Mutex::new(BTreeMap::new()),
            registry,
        }
    }

    /// The metrics registry membership feeds — the accept loop hangs
    /// its request counters off the same one, so a single `ServeStats`
    /// snapshot shows both.
    pub fn obs(&self) -> &obs::Registry {
        &self.registry
    }

    /// Explicit join (or upsert) of `addr`, tied to control connection
    /// `conn_id`.
    pub fn register(&self, addr: &str, model_version: u64, conn_id: u64, now: Instant) {
        let mut g = self
            .inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let prior = g.insert(
            addr.to_string(),
            Member {
                model_version,
                last_seen: now,
                conn_id,
            },
        );
        if prior.is_none() {
            self.registers.inc();
            eprintln!("[gparml-control] replica {addr} joined (model version {model_version})");
        }
        self.replicas.set(g.len() as u64);
    }

    /// Liveness + model-version refresh. A heartbeat for an unknown
    /// address is an implicit re-register (v8 contract), so replicas
    /// that reconnect after a control restart or connection drop
    /// rejoin without special-casing.
    pub fn heartbeat(&self, addr: &str, model_version: u64, conn_id: u64, now: Instant) {
        let mut g = self
            .inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        match g.get_mut(addr) {
            Some(member) => {
                member.model_version = model_version;
                member.last_seen = now;
                member.conn_id = conn_id;
            }
            None => {
                g.insert(
                    addr.to_string(),
                    Member {
                        model_version,
                        last_seen: now,
                        conn_id,
                    },
                );
                self.registers.inc();
                eprintln!(
                    "[gparml-control] replica {addr} re-joined via heartbeat \
                     (model version {model_version})"
                );
            }
        }
        self.heartbeats.inc();
        self.replicas.set(g.len() as u64);
    }

    /// Clean leave; unknown addresses are ignored (idempotent).
    pub fn deregister(&self, addr: &str) {
        let mut g = self
            .inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        if g.remove(addr).is_some() {
            self.deregisters.inc();
            eprintln!("[gparml-control] replica {addr} left");
        }
        self.replicas.set(g.len() as u64);
    }

    /// A control connection died: drop every member registered through
    /// it (implicit deregister).
    pub fn drop_conn(&self, conn_id: u64) {
        let mut g = self
            .inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let doomed: Vec<String> = g
            .iter()
            .filter(|(_, m)| m.conn_id == conn_id)
            .map(|(a, _)| a.clone())
            .collect();
        for addr in doomed {
            g.remove(&addr);
            self.deregisters.inc();
            eprintln!("[gparml-control] replica {addr} dropped (control connection closed)");
        }
        self.replicas.set(g.len() as u64);
    }

    /// Evict members not heard from within `window`; returns the
    /// evicted addresses (logged by callers).
    pub fn evict_stale(&self, now: Instant, window: Duration) -> Vec<String> {
        let mut g = self
            .inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let doomed: Vec<String> = g
            .iter()
            .filter(|(_, m)| now.saturating_duration_since(m.last_seen) > window)
            .map(|(a, _)| a.clone())
            .collect();
        for addr in &doomed {
            g.remove(addr);
            self.evictions.inc();
            eprintln!("[gparml-control] replica {addr} evicted (heartbeat stale)");
        }
        self.replicas.set(g.len() as u64);
        doomed
    }

    /// The live member set, sorted by address (BTreeMap order — equal
    /// registries produce equal snapshots).
    pub fn snapshot(&self, now: Instant) -> Vec<ReplicaInfo> {
        let g = self
            .inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        g.iter()
            .map(|(addr, m)| ReplicaInfo {
                addr: addr.clone(),
                model_version: m.model_version,
                age_ms: now.saturating_duration_since(m.last_seen).as_millis() as u64,
            })
            .collect()
    }
}

/// Run the control plane on `listener` forever (the process is ended
/// by its operator; there is no client-count exit — a fleet outlives
/// any one member).
pub fn run_control(listener: &TcpListener, opts: &ControlOptions) -> Result<()> {
    let reg = FleetRegistry::new();
    // pre-create the request counters so a stats scrape of an idle
    // control plane still shows them (at zero)
    reg.obs().counter("fleet.requests.info");
    reg.obs().counter("fleet.requests.stats");
    reg.obs().counter("fleet.requests.rejected");
    let conns = reg.obs().counter("fleet.connections");
    let stale = Duration::from_millis(opts.stale_ms.max(1));
    let mut next_conn = 0u64;

    std::thread::scope(|s| -> Result<()> {
        // background staleness sweep: a wedged replica whose TCP
        // connection stays open must still leave the fleet
        {
            let reg = &reg;
            s.spawn(move || loop {
                std::thread::sleep(Duration::from_millis(opts.sweep_ms.max(10)));
                reg.evict_stale(Instant::now(), stale);
            });
        }
        loop {
            match listener.accept() {
                Ok((stream, peer)) => {
                    conns.inc();
                    let conn_id = next_conn;
                    next_conn += 1;
                    let reg = &reg;
                    s.spawn(move || {
                        let served = control_client(stream, conn_id, reg, stale);
                        // implicit deregister: the registration dies
                        // with the connection that carried it
                        reg.drop_conn(conn_id);
                        match served {
                            Ok(n) => {
                                eprintln!("[gparml-control] connection {peer}: {n} request(s)")
                            }
                            Err(e) => {
                                eprintln!("[gparml-control] connection {peer} failed: {e:#}")
                            }
                        }
                    });
                }
                // transient under load: log, back off, keep going
                Err(e) => {
                    eprintln!("[gparml-control] accept failed (retrying): {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    })
}

/// Serve one control connection until `Shutdown`, EOF or an error.
fn control_client(
    mut stream: TcpStream,
    conn_id: u64,
    reg: &FleetRegistry,
    stale: Duration,
) -> Result<u64> {
    stream.set_nodelay(true).ok();
    let mut served = 0u64;
    loop {
        let (trace_id, req) = match wire::read_frame(&mut stream)? {
            None | Some((Frame::Shutdown, _)) => return Ok(served),
            Some((Frame::Ping, _)) => {
                wire::write_frame(&mut stream, &Frame::Pong)?;
                served += 1;
                continue;
            }
            Some((Frame::Request { trace_id, req }, _)) => (trace_id, req),
            Some((f, _)) => bail!("unexpected frame {f:?} from control client"),
        };
        let resp = match *req {
            Request::Register {
                ref addr,
                model_version,
            } => {
                reg.register(addr, model_version, conn_id, Instant::now());
                Response::Ok
            }
            Request::ReplicaHeartbeat {
                ref addr,
                model_version,
            } => {
                reg.heartbeat(addr, model_version, conn_id, Instant::now());
                Response::Ok
            }
            Request::Deregister { ref addr } => {
                reg.deregister(addr);
                Response::Ok
            }
            Request::FleetInfo => {
                reg.obs().counter("fleet.requests.info").inc();
                let now = Instant::now();
                reg.evict_stale(now, stale);
                Response::FleetInfo {
                    replicas: reg.snapshot(now),
                }
            }
            Request::ServeStats => {
                reg.obs().counter("fleet.requests.stats").inc();
                Response::StatsJson(reg.obs().snapshot_json().to_string())
            }
            ref other => {
                reg.obs().counter("fleet.requests.rejected").inc();
                Response::Err(format!(
                    "control plane only answers Register/Deregister/ReplicaHeartbeat/\
                     FleetInfo/ServeStats, got {other:?}"
                ))
            }
        };
        wire::write_frame(
            &mut stream,
            &Frame::Response {
                trace_id,
                secs: 0.0,
                psi_fills: 0,
                resp: Box::new(resp),
            },
        )?;
        served += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const WINDOW: Duration = Duration::from_millis(1_000);

    #[test]
    fn register_heartbeat_snapshot_lifecycle() {
        let reg = FleetRegistry::new();
        let t0 = Instant::now();
        reg.register("10.0.0.1:7000", 1, 0, t0);
        reg.register("10.0.0.2:7000", 1, 1, t0);
        let snap = reg.snapshot(t0);
        assert_eq!(snap.len(), 2);
        // sorted by address, ages relative to `now`
        assert_eq!(snap[0].addr, "10.0.0.1:7000");
        assert_eq!(snap[1].addr, "10.0.0.2:7000");
        assert_eq!(snap[0].age_ms, 0);

        // heartbeat refreshes liveness and carries the reload counter
        let t1 = t0 + Duration::from_millis(300);
        reg.heartbeat("10.0.0.1:7000", 5, 0, t1);
        let snap = reg.snapshot(t1);
        assert_eq!(snap[0].model_version, 5);
        assert_eq!(snap[0].age_ms, 0);
        assert_eq!(snap[1].age_ms, 300);

        // clean leave is idempotent
        reg.deregister("10.0.0.2:7000");
        reg.deregister("10.0.0.2:7000");
        assert_eq!(reg.snapshot(t1).len(), 1);
    }

    #[test]
    fn stale_members_are_evicted_fresh_ones_kept() {
        let reg = FleetRegistry::new();
        let t0 = Instant::now();
        reg.register("a:1", 1, 0, t0);
        reg.register("b:1", 1, 1, t0);
        let t1 = t0 + Duration::from_millis(800);
        reg.heartbeat("b:1", 1, 1, t1);
        // a:1 is now 1200ms stale, b:1 only 400ms
        let t2 = t0 + Duration::from_millis(1_200);
        let evicted = reg.evict_stale(t2, WINDOW);
        assert_eq!(evicted, vec!["a:1".to_string()]);
        let snap = reg.snapshot(t2);
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].addr, "b:1");
        // exactly at the window boundary is NOT stale (> window evicts)
        let t3 = t1 + WINDOW;
        assert!(reg.evict_stale(t3, WINDOW).is_empty());
        assert_eq!(reg.snapshot(t3).len(), 1);
    }

    #[test]
    fn heartbeat_for_unknown_addr_is_implicit_register() {
        let reg = FleetRegistry::new();
        let t0 = Instant::now();
        reg.heartbeat("c:9", 3, 7, t0);
        let snap = reg.snapshot(t0);
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].addr, "c:9");
        assert_eq!(snap[0].model_version, 3);
    }

    #[test]
    fn conn_drop_removes_only_that_connections_members() {
        let reg = FleetRegistry::new();
        let t0 = Instant::now();
        reg.register("a:1", 1, 0, t0);
        reg.register("b:1", 1, 1, t0);
        reg.drop_conn(0);
        let snap = reg.snapshot(t0);
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].addr, "b:1");
        // a reconnecting replica re-registers under its new conn id
        reg.heartbeat("a:1", 2, 5, t0);
        assert_eq!(reg.snapshot(t0).len(), 2);
        reg.drop_conn(1);
        reg.drop_conn(5);
        assert!(reg.snapshot(t0).is_empty());
    }
}
