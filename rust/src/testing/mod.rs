//! Property-based testing helpers (proptest is not in the offline crate
//! set — DESIGN.md §5). Deterministic randomised-invariant checking:
//! run a property over many seeded random cases; on failure, report the
//! seed so the case replays exactly.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Run `prop` over `cases` seeded random instances; panics with the
/// failing seed on the first violation.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for seed in 0..cases {
        let mut rng = Rng::new(0x9E3779B9u64 ^ (seed.wrapping_mul(0x2545F4914F6CDD1D)));
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed at seed {seed}: {msg}");
        }
    }
}

/// Random matrix with entries ~ N(0, scale).
pub fn random_matrix(rng: &mut Rng, rows: usize, cols: usize, scale: f64) -> Matrix {
    Matrix::from_fn(rows, cols, |_, _| scale * rng.normal())
}

/// Random SPD matrix with condition control.
pub fn random_spd(rng: &mut Rng, n: usize, diag: f64) -> Matrix {
    let g = random_matrix(rng, n, n + 2, 1.0);
    g.matmul_t(&g).add_diag(diag)
}

/// Random dimension in [lo, hi].
pub fn dim(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Assert two floats agree to a relative tolerance, as a property result.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b}"))
    }
}

/// Assert two matrices agree to an absolute-ish tolerance.
pub fn mat_close(a: &Matrix, b: &Matrix, tol: f64, what: &str) -> Result<(), String> {
    let scale = 1.0 + a.max_abs().max(b.max_abs());
    let diff = a.max_abs_diff(b);
    if diff <= tol * scale {
        Ok(())
    } else {
        Err(format!("{what}: max |diff| = {diff:e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_good_property() {
        check("sum-commutes", 50, |rng| {
            let a = rng.normal();
            let b = rng.normal();
            close(a + b, b + a, 1e-15, "commutativity")
        });
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn check_reports_failing_seed() {
        check("always-false", 3, |_| Err("nope".into()));
    }

    #[test]
    fn random_spd_is_spd() {
        check("spd", 20, |rng| {
            let n = dim(rng, 2, 6);
            let a = random_spd(rng, n, 0.1);
            crate::linalg::Cholesky::new(&a)
                .map(|_| ())
                .map_err(|e| e.to_string())
        });
    }
}
