//! Streaming store packer: rows in, shard files + manifest out, with
//! peak memory bounded by one shard (DESIGN.md §13). `gparml data
//! pack` drives this from a chunked CSV reader or a chunked generator,
//! so CSV → store conversion never materialises the dataset either.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Result};

use super::codec;
use super::manifest::{ShardEntry, StoreManifest};
use crate::linalg::Matrix;

/// Incremental store writer. `append` buffers at most one shard's rows;
/// each full shard is flushed to `shard_NNNNN.gpds` as it completes,
/// and `finish` writes the remainder plus the manifest.
pub struct StoreWriter {
    dir: PathBuf,
    /// columns per row; learned from the first appended chunk so CSV
    /// packing does not need to pre-scan the file
    dims: Option<usize>,
    x_cols: usize,
    shard_rows: usize,
    artifact: Option<String>,
    buf: Vec<f64>,
    buf_rows: usize,
    shards: Vec<ShardEntry>,
    total: usize,
}

impl StoreWriter {
    pub fn create(
        dir: &Path,
        x_cols: usize,
        shard_rows: usize,
        artifact: Option<&str>,
    ) -> Result<StoreWriter> {
        ensure!(shard_rows >= 1, "shard_rows must be >= 1");
        std::fs::create_dir_all(dir)?;
        Ok(StoreWriter {
            dir: dir.to_path_buf(),
            dims: None,
            x_cols,
            shard_rows,
            artifact: artifact.map(str::to_string),
            buf: Vec::new(),
            buf_rows: 0,
            shards: Vec::new(),
            total: 0,
        })
    }

    /// Rows written so far (flushed + buffered).
    pub fn rows(&self) -> usize {
        self.total + self.buf_rows
    }

    pub fn append(&mut self, chunk: &Matrix) -> Result<()> {
        if chunk.rows() == 0 {
            return Ok(());
        }
        let dims = *self.dims.get_or_insert_with(|| chunk.cols());
        ensure!(
            chunk.cols() == dims,
            "chunk has {} columns but the store was started with {dims}",
            chunk.cols()
        );
        ensure!(
            self.x_cols < dims,
            "x_cols ({}) must leave at least one output column (dims {dims})",
            self.x_cols
        );
        let mut offset = 0usize;
        while offset < chunk.rows() {
            let take = (self.shard_rows - self.buf_rows).min(chunk.rows() - offset);
            let lo = offset * dims;
            let hi = (offset + take) * dims;
            self.buf.extend_from_slice(&chunk.data()[lo..hi]);
            self.buf_rows += take;
            offset += take;
            if self.buf_rows == self.shard_rows {
                self.flush_shard()?;
            }
        }
        Ok(())
    }

    fn flush_shard(&mut self) -> Result<()> {
        let dims = self.dims.expect("flush with no rows appended");
        let rows = self.buf_rows;
        let m = Matrix::from_vec(rows, dims, std::mem::take(&mut self.buf));
        let file = format!("shard_{:05}.gpds", self.shards.len());
        let checksum = codec::write_shard(&self.dir.join(&file), &m)?;
        self.shards.push(ShardEntry {
            file,
            start: self.total,
            rows,
            checksum,
        });
        self.total += rows;
        self.buf_rows = 0;
        Ok(())
    }

    /// Flush the final partial shard and write the manifest; returns it.
    pub fn finish(mut self) -> Result<StoreManifest> {
        if self.buf_rows > 0 {
            self.flush_shard()?;
        }
        ensure!(self.total >= 1, "store has no rows");
        let manifest = StoreManifest {
            n: self.total,
            dims: self.dims.expect("rows exist"),
            x_cols: self.x_cols,
            artifact: self.artifact.clone(),
            shards: std::mem::take(&mut self.shards),
        };
        manifest.save(&self.dir)?;
        Ok(manifest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::ShardedDiskSource;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("gpds_writer_{}_{name}", std::process::id()))
    }

    #[test]
    fn packs_across_chunk_and_shard_boundaries() {
        let dir = tmp("pack");
        let data = Matrix::from_fn(23, 4, |i, j| (i * 4 + j) as f64 * 0.25);
        let mut w = StoreWriter::create(&dir, 1, 5, Some("small")).unwrap();
        // append in awkward chunk sizes: 1, 7, 15 rows
        let slice = |lo: usize, hi: usize| {
            Matrix::from_fn(hi - lo, 4, |r, c| data[(lo + r, c)])
        };
        w.append(&slice(0, 1)).unwrap();
        w.append(&slice(1, 8)).unwrap();
        w.append(&slice(8, 23)).unwrap();
        let man = w.finish().unwrap();
        assert_eq!(man.n, 23);
        assert_eq!(man.dims, 4);
        assert_eq!(man.shards.len(), 5); // 5+5+5+5+3
        assert_eq!(man.shards[4].rows, 3);
        assert_eq!(man.artifact.as_deref(), Some("small"));

        let src = ShardedDiskSource::open(&dir).unwrap();
        let all = src.read_all().unwrap();
        for (a, b) in data.data().iter().zip(all.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_column_drift_and_empty_stores() {
        let dir = tmp("drift");
        let mut w = StoreWriter::create(&dir, 0, 4, None).unwrap();
        w.append(&Matrix::zeros(2, 3)).unwrap();
        let msg = format!("{:#}", w.append(&Matrix::zeros(2, 2)).unwrap_err());
        assert!(msg.contains("columns"), "{msg}");

        let w = StoreWriter::create(&dir, 0, 4, None).unwrap();
        let msg = format!("{:#}", w.finish().unwrap_err());
        assert!(msg.contains("no rows"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
