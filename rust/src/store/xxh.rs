//! Streaming XXH64 — the shard checksum of the on-disk dataset store
//! (DESIGN.md §13). Self-contained (the `xxhash` crates are not in the
//! offline set) and incremental, so a shard file can be verified while
//! it is read chunk-by-chunk without ever holding the whole payload.
//!
//! This is the reference XXH64 algorithm with seed 0; the one-shot and
//! streaming paths are bit-identical by construction (and by test).

const P1: u64 = 0x9E3779B185EBCA87;
const P2: u64 = 0xC2B2AE3D27D4EB4F;
const P3: u64 = 0x165667B19E3779F9;
const P4: u64 = 0x85EBCA77C2B2AE63;
const P5: u64 = 0x27D4EB2F165667C5;

#[inline]
fn round(acc: u64, lane: u64) -> u64 {
    acc.wrapping_add(lane.wrapping_mul(P2))
        .rotate_left(31)
        .wrapping_mul(P1)
}

#[inline]
fn merge(acc: u64, val: u64) -> u64 {
    (acc ^ round(0, val)).wrapping_mul(P1).wrapping_add(P4)
}

/// Incremental XXH64 state (seed 0).
pub struct Xxh64 {
    acc: [u64; 4],
    /// partial 32-byte stripe carried between `update` calls
    buf: [u8; 32],
    buf_len: usize,
    total: u64,
}

impl Default for Xxh64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Xxh64 {
    pub fn new() -> Xxh64 {
        Xxh64 {
            acc: [P1.wrapping_add(P2), P2, 0, 0u64.wrapping_sub(P1)],
            buf: [0; 32],
            buf_len: 0,
            total: 0,
        }
    }

    fn stripe(&mut self, s: &[u8]) {
        debug_assert_eq!(s.len(), 32);
        for i in 0..4 {
            let lane = u64::from_le_bytes(s[i * 8..i * 8 + 8].try_into().unwrap());
            self.acc[i] = round(self.acc[i], lane);
        }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (32 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 32 {
                let full = self.buf;
                self.stripe(&full);
                self.buf_len = 0;
            }
        }
        while data.len() >= 32 {
            let (s, rest) = data.split_at(32);
            self.stripe(s);
            data = rest;
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finish(&self) -> u64 {
        let mut h = if self.total >= 32 {
            let [a1, a2, a3, a4] = self.acc;
            let mut h = a1
                .rotate_left(1)
                .wrapping_add(a2.rotate_left(7))
                .wrapping_add(a3.rotate_left(12))
                .wrapping_add(a4.rotate_left(18));
            h = merge(h, a1);
            h = merge(h, a2);
            h = merge(h, a3);
            merge(h, a4)
        } else {
            P5 // seed 0 + PRIME64_5
        };
        h = h.wrapping_add(self.total);
        let mut rem = &self.buf[..self.buf_len];
        while rem.len() >= 8 {
            let lane = u64::from_le_bytes(rem[..8].try_into().unwrap());
            h = (h ^ round(0, lane))
                .rotate_left(27)
                .wrapping_mul(P1)
                .wrapping_add(P4);
            rem = &rem[8..];
        }
        if rem.len() >= 4 {
            let lane = u64::from(u32::from_le_bytes(rem[..4].try_into().unwrap()));
            h = (h ^ lane.wrapping_mul(P1))
                .rotate_left(23)
                .wrapping_mul(P2)
                .wrapping_add(P3);
            rem = &rem[4..];
        }
        for &b in rem {
            h = (h ^ u64::from(b).wrapping_mul(P5))
                .rotate_left(11)
                .wrapping_mul(P1);
        }
        h ^= h >> 33;
        h = h.wrapping_mul(P2);
        h ^= h >> 29;
        h = h.wrapping_mul(P3);
        h ^ (h >> 32)
    }
}

/// One-shot XXH64 (seed 0) of `data`.
pub fn xxh64(data: &[u8]) -> u64 {
    let mut h = Xxh64::new();
    h.update(data);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // reference values from the canonical xxHash test suite (seed 0)
        assert_eq!(xxh64(b""), 0xEF46_DB37_51D8_E999);
        assert_eq!(xxh64(b"abc"), 0x44BC_2CF5_AD77_0999);
    }

    #[test]
    fn streaming_matches_oneshot_at_every_split() {
        // spans all tail paths: <32, exactly 32, >32, 8/4/1-byte remainders
        let data: Vec<u8> = (0..157u32).map(|i| (i.wrapping_mul(97) % 251) as u8).collect();
        let want = xxh64(&data);
        for split in 0..=data.len() {
            let mut h = Xxh64::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finish(), want, "split at {split}");
        }
        // three-way splits across the stripe boundary
        for a in [1usize, 31, 32, 33, 63, 64, 65] {
            for b in [a + 1, a + 32, (a + 40).min(data.len())] {
                if b > data.len() {
                    continue;
                }
                let mut h = Xxh64::new();
                h.update(&data[..a]);
                h.update(&data[a..b]);
                h.update(&data[b..]);
                assert_eq!(h.finish(), want, "splits at {a},{b}");
            }
        }
    }

    #[test]
    fn distinct_inputs_distinct_hashes() {
        let a = xxh64(&[0u8; 64]);
        let mut bytes = [0u8; 64];
        bytes[63] = 1;
        assert_ne!(a, xxh64(&bytes));
    }
}
