//! On-disk shard codec for the dataset store (DESIGN.md §13).
//!
//! One shard file holds a contiguous block of dataset rows as f64
//! row-major little-endian payload behind a fixed header, with a
//! trailing XXH64 checksum over everything before it:
//!
//! ```text
//! offset  size          field
//! 0       4             magic "GPDS"
//! 4       2             format version (u16 LE, currently 1)
//! 6       4             rows (u32 LE, >= 1)
//! 10      4             cols (u32 LE, >= 1)
//! 14      rows*cols*8   payload, f64 LE row-major
//! end-8   8             XXH64 of bytes [0, end-8) (u64 LE)
//! ```
//!
//! Same discipline as the `TrainedModel` artifact codec: decode
//! validates in a fixed order (length → magic → version → implied
//! length → checksum), every rejection is a named error, and writes
//! are atomic (temp file + rename). The streaming reader hashes the
//! file as it goes, so chunked reads are verified without ever
//! materialising the shard — but note that chunks are delivered to the
//! callback *before* the trailing checksum is reached; on mismatch the
//! stream errors and the caller must treat everything delivered as
//! poisoned (bring-up does: the constructor fails loudly).

use std::fs;
use std::io::{BufReader, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::xxh::{xxh64, Xxh64};
use crate::linalg::Matrix;

pub const MAGIC: [u8; 4] = *b"GPDS";
pub const FORMAT_VERSION: u16 = 1;
pub const HEADER_LEN: usize = 4 + 2 + 4 + 4;
pub const CHECKSUM_LEN: usize = 8;

/// Encode `m` as a shard file image. Rejects empty matrices and
/// non-finite values (a dataset cell that is NaN/Inf would poison the
/// bound silently thousands of rows later).
pub fn encode_shard(m: &Matrix) -> Result<Vec<u8>> {
    ensure!(m.rows() >= 1 && m.cols() >= 1, "refusing to pack an empty shard");
    ensure!(
        m.rows() <= u32::MAX as usize && m.cols() <= u32::MAX as usize,
        "shard shape {}x{} does not fit the u32 header",
        m.rows(),
        m.cols()
    );
    for (i, v) in m.data().iter().enumerate() {
        ensure!(
            v.is_finite(),
            "non-finite value at row {} col {} — refusing to pack",
            i / m.cols(),
            i % m.cols()
        );
    }
    let mut out = Vec::with_capacity(HEADER_LEN + m.data().len() * 8 + CHECKSUM_LEN);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&(m.rows() as u32).to_le_bytes());
    out.extend_from_slice(&(m.cols() as u32).to_le_bytes());
    for v in m.data() {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let sum = xxh64(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    Ok(out)
}

/// Parse and fully validate a shard header (shared by the in-memory
/// and streaming decoders): returns (rows, cols).
fn decode_header(header: &[u8; HEADER_LEN], what: &str) -> Result<(usize, usize)> {
    ensure!(header[0..4] == MAGIC, "bad shard magic in {what}");
    let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
    ensure!(
        version == FORMAT_VERSION,
        "shard format version mismatch: {what} has v{version}, this build reads v{FORMAT_VERSION}"
    );
    let rows = u32::from_le_bytes(header[6..10].try_into().unwrap()) as usize;
    let cols = u32::from_le_bytes(header[10..14].try_into().unwrap()) as usize;
    ensure!(rows >= 1 && cols >= 1, "empty shard ({rows}x{cols}) in {what}");
    Ok((rows, cols))
}

/// Decode a full shard image: returns the matrix and its checksum.
pub fn decode_shard(bytes: &[u8]) -> Result<(Matrix, u64)> {
    ensure!(
        bytes.len() >= HEADER_LEN + CHECKSUM_LEN,
        "truncated shard file ({} bytes)",
        bytes.len()
    );
    let header: &[u8; HEADER_LEN] = bytes[..HEADER_LEN].try_into().unwrap();
    let (rows, cols) = decode_header(header, "shard file")?;
    let expect = (HEADER_LEN + CHECKSUM_LEN) as u64 + (rows as u64) * (cols as u64) * 8;
    ensure!(
        bytes.len() as u64 == expect,
        "truncated or oversized shard file: {} bytes, header implies {expect}",
        bytes.len()
    );
    let body = &bytes[..bytes.len() - CHECKSUM_LEN];
    let stored = u64::from_le_bytes(bytes[bytes.len() - CHECKSUM_LEN..].try_into().unwrap());
    let actual = xxh64(body);
    ensure!(
        stored == actual,
        "shard checksum mismatch: stored {stored:#018x}, computed {actual:#018x}"
    );
    let payload = &body[HEADER_LEN..];
    let data: Vec<f64> = payload
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((Matrix::from_vec(rows, cols, data), actual))
}

/// Load and verify a whole shard file (worker-local `shard_ref` loads,
/// `data inspect`, tests). Use [`stream_shard`] to avoid materialising.
pub fn read_shard(path: &Path) -> Result<(Matrix, u64)> {
    let bytes = fs::read(path).with_context(|| format!("reading shard {}", path.display()))?;
    decode_shard(&bytes).with_context(|| format!("decoding shard {}", path.display()))
}

/// Read only a shard file's header: (rows, cols). Cheap (14 bytes) —
/// used to cross-check the manifest before any payload is streamed.
pub fn read_header(path: &Path) -> Result<(usize, usize)> {
    let file =
        fs::File::open(path).with_context(|| format!("opening shard {}", path.display()))?;
    let mut header = [0u8; HEADER_LEN];
    let mut r = BufReader::new(file);
    r.read_exact(&mut header)
        .map_err(|_| anyhow::anyhow!("truncated shard file {}", path.display()))?;
    decode_header(&header, &path.display().to_string())
}

/// Stream a shard file in chunks of at most `chunk_rows` rows without
/// materialising it. `f` receives `(first_row_within_shard, chunk)`.
/// The whole file is hashed while it is read; the trailing checksum
/// (and exact file length) are verified after the last chunk, and the
/// computed checksum is returned alongside the decoded shape.
pub fn stream_shard(
    path: &Path,
    chunk_rows: usize,
    f: &mut dyn FnMut(usize, &Matrix) -> Result<()>,
) -> Result<(usize, usize, u64)> {
    ensure!(chunk_rows >= 1, "chunk_rows must be >= 1");
    let file =
        fs::File::open(path).with_context(|| format!("opening shard {}", path.display()))?;
    let mut r = BufReader::new(file);
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header)
        .map_err(|_| anyhow::anyhow!("truncated shard file {}", path.display()))?;
    let (rows, cols) = decode_header(&header, &path.display().to_string())?;
    let mut hash = Xxh64::new();
    hash.update(&header);
    let row_bytes = cols * 8;
    let mut buf = vec![0u8; chunk_rows.min(rows) * row_bytes];
    let mut done = 0usize;
    while done < rows {
        let take = chunk_rows.min(rows - done);
        let bytes = &mut buf[..take * row_bytes];
        r.read_exact(bytes)
            .map_err(|_| anyhow::anyhow!("truncated shard file {}", path.display()))?;
        hash.update(bytes);
        let data: Vec<f64> = bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let chunk = Matrix::from_vec(take, cols, data);
        f(done, &chunk)?;
        done += take;
    }
    let mut tail = [0u8; CHECKSUM_LEN];
    r.read_exact(&mut tail)
        .map_err(|_| anyhow::anyhow!("truncated shard file {}", path.display()))?;
    let mut probe = [0u8; 1];
    if r.read(&mut probe)? != 0 {
        bail!("truncated or oversized shard file: trailing bytes after the checksum in {}",
            path.display());
    }
    let stored = u64::from_le_bytes(tail);
    let actual = hash.finish();
    ensure!(
        stored == actual,
        "shard checksum mismatch in {}: stored {stored:#018x}, computed {actual:#018x}",
        path.display()
    );
    Ok((rows, cols, actual))
}

/// Write a shard file atomically (temp file + rename, the artifact
/// codec's discipline); returns the shard's checksum.
pub fn write_shard(path: &Path, m: &Matrix) -> Result<u64> {
    let bytes = encode_shard(m)?;
    let sum = u64::from_le_bytes(bytes[bytes.len() - CHECKSUM_LEN..].try_into().unwrap());
    write_atomic(path, &bytes)?;
    Ok(sum)
}

/// Atomic byte write: temp file in the target directory, then rename.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            fs::create_dir_all(dir)
                .with_context(|| format!("creating directory {}", dir.display()))?;
        }
    }
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |i, j| (i as f64 + 1.0) * 0.5 - (j as f64) * 1.25)
    }

    #[test]
    fn roundtrips_bit_for_bit() {
        let m = sample(5, 3);
        let bytes = encode_shard(&m).unwrap();
        let (back, sum) = decode_shard(&bytes).unwrap();
        assert_eq!(back.rows(), 5);
        assert_eq!(back.cols(), 3);
        for (a, b) in m.data().iter().zip(back.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(sum, xxh64(&bytes[..bytes.len() - CHECKSUM_LEN]));
    }

    #[test]
    fn rejects_empty_and_nonfinite() {
        let msg = format!("{:#}", encode_shard(&Matrix::zeros(0, 3)).unwrap_err());
        assert!(msg.contains("empty shard"), "{msg}");
        let mut m = sample(2, 2);
        m.data_mut()[3] = f64::NAN;
        let msg = format!("{:#}", encode_shard(&m).unwrap_err());
        assert!(msg.contains("non-finite"), "{msg}");
    }

    #[test]
    fn every_single_byte_corruption_is_rejected() {
        let bytes = encode_shard(&sample(3, 2)).unwrap();
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            let err = decode_shard(&bad).expect_err(&format!("byte {i} corruption accepted"));
            let msg = format!("{err:#}");
            assert!(
                msg.contains("magic")
                    || msg.contains("version mismatch")
                    || msg.contains("truncated or oversized")
                    || msg.contains("empty shard")
                    || msg.contains("checksum mismatch"),
                "byte {i}: unexpected error {msg}"
            );
        }
    }

    #[test]
    fn every_truncation_is_rejected() {
        let bytes = encode_shard(&sample(3, 2)).unwrap();
        for cut in 0..bytes.len() {
            let err = decode_shard(&bytes[..cut]).expect_err(&format!("cut at {cut} accepted"));
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated"),
                "cut {cut}: unexpected error {msg}"
            );
        }
    }

    #[test]
    fn version_mismatch_is_named() {
        let mut bytes = encode_shard(&sample(2, 2)).unwrap();
        bytes[4..6].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
        let msg = format!("{:#}", decode_shard(&bytes).unwrap_err());
        assert!(msg.contains("shard format version mismatch"), "{msg}");
    }

    #[test]
    fn stream_matches_full_decode_at_every_chunk_size() {
        let m = sample(11, 4);
        let dir = std::env::temp_dir().join(format!("gpds_codec_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.gpds");
        let want_sum = write_shard(&path, &m).unwrap();
        for chunk_rows in [1usize, 2, 3, 5, 11, 64] {
            let mut got = Matrix::zeros(11, 4);
            let (rows, cols, sum) = stream_shard(&path, chunk_rows, &mut |row0, chunk| {
                for i in 0..chunk.rows() {
                    got.row_mut(row0 + i).copy_from_slice(chunk.row(i));
                }
                Ok(())
            })
            .unwrap();
            assert_eq!((rows, cols, sum), (11, 4, want_sum));
            for (a, b) in m.data().iter().zip(got.data()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stream_rejects_corruption_and_trailing_bytes() {
        let dir = std::env::temp_dir().join(format!("gpds_codec_bad_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.gpds");
        let mut bytes = encode_shard(&sample(4, 3)).unwrap();
        // flip one payload byte: the stream must fail at checksum time
        bytes[HEADER_LEN + 5] ^= 0x01;
        fs::write(&path, &bytes).unwrap();
        let msg = format!(
            "{:#}",
            stream_shard(&path, 2, &mut |_, _| Ok(())).unwrap_err()
        );
        assert!(msg.contains("checksum mismatch"), "{msg}");
        // trailing garbage after the checksum
        let mut bytes = encode_shard(&sample(4, 3)).unwrap();
        bytes.push(0xAB);
        fs::write(&path, &bytes).unwrap();
        let msg = format!(
            "{:#}",
            stream_shard(&path, 2, &mut |_, _| Ok(())).unwrap_err()
        );
        assert!(msg.contains("trailing bytes"), "{msg}");
        fs::remove_dir_all(&dir).ok();
    }
}
