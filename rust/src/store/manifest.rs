//! The store manifest: one `manifest.json` per store directory naming
//! every shard file, its contiguous row range, and its checksum
//! (DESIGN.md §13). The manifest is the unit of trust — every streamed
//! shard is verified against the checksum recorded here, so a shard
//! file swapped or corrupted after packing is rejected even when the
//! file's own trailing checksum is internally consistent.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Context, Result};

use crate::util::json::{self, Json};

pub const MANIFEST_FILE: &str = "manifest.json";

/// One shard file's entry: contiguous rows `[start, start + rows)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardEntry {
    pub file: String,
    pub start: usize,
    pub rows: usize,
    pub checksum: u64,
}

/// The dataset store's schema and shard map.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreManifest {
    /// total dataset rows
    pub n: usize,
    /// columns per row (inputs then outputs)
    pub dims: usize,
    /// leading input columns (0 for an outputs-only / LVM store)
    pub x_cols: usize,
    /// suggested `ArtifactConfig` name for training (packer hint)
    pub artifact: Option<String>,
    pub shards: Vec<ShardEntry>,
}

impl StoreManifest {
    /// Output columns per row.
    pub fn y_cols(&self) -> usize {
        self.dims - self.x_cols
    }

    pub fn shard_path(&self, dir: &Path, i: usize) -> PathBuf {
        dir.join(&self.shards[i].file)
    }

    /// Structural invariants: at least one shard, every shard non-empty,
    /// ranges contiguous from 0, totals matching `n`, `x_cols` leaving
    /// at least one output column.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.dims >= 1, "store manifest: dims must be >= 1");
        ensure!(
            self.x_cols < self.dims,
            "store manifest: x_cols ({}) must leave at least one output column (dims {})",
            self.x_cols,
            self.dims
        );
        ensure!(!self.shards.is_empty(), "store manifest: no shards");
        let mut next = 0usize;
        for (i, s) in self.shards.iter().enumerate() {
            ensure!(s.rows >= 1, "store manifest: shard {i} is empty");
            ensure!(
                s.start == next,
                "store manifest: shard {i} starts at row {} but the previous shard ends at {next}",
                s.start
            );
            ensure!(!s.file.is_empty(), "store manifest: shard {i} has no file name");
            next += s.rows;
        }
        ensure!(
            next == self.n,
            "store manifest: shards cover {next} rows but n is {}",
            self.n
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let shards: Vec<Json> = self
            .shards
            .iter()
            .map(|s| {
                json::obj(vec![
                    ("file", Json::Str(s.file.clone())),
                    ("start", Json::Num(s.start as f64)),
                    ("rows", Json::Num(s.rows as f64)),
                    ("checksum", Json::Str(format!("{:#018x}", s.checksum))),
                ])
            })
            .collect();
        let mut pairs = vec![
            ("format", Json::Str("gpds".into())),
            ("version", Json::Num(1.0)),
            ("n", Json::Num(self.n as f64)),
            ("dims", Json::Num(self.dims as f64)),
            ("x_cols", Json::Num(self.x_cols as f64)),
            ("shards", Json::Arr(shards)),
        ];
        if let Some(a) = &self.artifact {
            pairs.push(("artifact", Json::Str(a.clone())));
        }
        json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<StoreManifest> {
        let format = j.get("format")?.as_str()?;
        ensure!(format == "gpds", "store manifest: unknown format {format:?}");
        let version = j.get("version")?.as_usize()?;
        ensure!(
            version == 1,
            "store manifest version mismatch: file has v{version}, this build reads v1"
        );
        let shards = j
            .get("shards")?
            .as_arr()?
            .iter()
            .enumerate()
            .map(|(i, s)| {
                Ok(ShardEntry {
                    file: s.get("file")?.as_str()?.to_string(),
                    start: s.get("start")?.as_usize()?,
                    rows: s.get("rows")?.as_usize()?,
                    checksum: parse_checksum(s.get("checksum")?.as_str()?)
                        .with_context(|| format!("store manifest: shard {i}"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let m = StoreManifest {
            n: j.get("n")?.as_usize()?,
            dims: j.get("dims")?.as_usize()?,
            x_cols: j.get("x_cols")?.as_usize()?,
            artifact: match j.opt("artifact") {
                Some(a) => Some(a.as_str()?.to_string()),
                None => None,
            },
            shards,
        };
        m.validate()?;
        Ok(m)
    }

    /// Write `dir/manifest.json` atomically.
    pub fn save(&self, dir: &Path) -> Result<()> {
        self.validate()?;
        super::codec::write_atomic(&dir.join(MANIFEST_FILE), self.to_json().to_string().as_bytes())
    }

    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<StoreManifest> {
        let path = dir.join(MANIFEST_FILE);
        let j = Json::from_file(&path)
            .with_context(|| format!("reading store manifest {}", path.display()))?;
        Self::from_json(&j).with_context(|| format!("parsing store manifest {}", path.display()))
    }
}

/// Checksums are stored as `0x`-prefixed hex strings (a u64 does not
/// round-trip through a JSON number).
fn parse_checksum(s: &str) -> Result<u64> {
    let hex = s
        .strip_prefix("0x")
        .ok_or_else(|| anyhow!("bad checksum {s:?} (expected 0x-prefixed hex)"))?;
    u64::from_str_radix(hex, 16).map_err(|_| anyhow!("bad checksum {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StoreManifest {
        StoreManifest {
            n: 7,
            dims: 3,
            x_cols: 2,
            artifact: Some("small".into()),
            shards: vec![
                ShardEntry { file: "shard_00000.gpds".into(), start: 0, rows: 4, checksum: 0xDEAD_BEEF },
                ShardEntry { file: "shard_00001.gpds".into(), start: 4, rows: 3, checksum: u64::MAX },
            ],
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let m = sample();
        let back = StoreManifest::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn validation_names_each_failure() {
        let mut m = sample();
        m.shards[1].start = 5;
        let msg = format!("{:#}", m.validate().unwrap_err());
        assert!(msg.contains("previous shard ends"), "{msg}");

        let mut m = sample();
        m.n = 9;
        let msg = format!("{:#}", m.validate().unwrap_err());
        assert!(msg.contains("cover 7 rows but n is 9"), "{msg}");

        let mut m = sample();
        m.x_cols = 3;
        let msg = format!("{:#}", m.validate().unwrap_err());
        assert!(msg.contains("at least one output column"), "{msg}");

        let mut m = sample();
        m.shards.clear();
        let msg = format!("{:#}", m.validate().unwrap_err());
        assert!(msg.contains("no shards"), "{msg}");
    }

    #[test]
    fn bad_checksum_strings_are_rejected() {
        assert!(parse_checksum("deadbeef").is_err());
        assert!(parse_checksum("0xzz").is_err());
        assert_eq!(parse_checksum("0x00000000deadbeef").unwrap(), 0xDEAD_BEEF);
    }

    #[test]
    fn manifest_version_mismatch_is_named() {
        let mut j = sample().to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::Num(2.0));
        }
        let msg = format!("{:#}", StoreManifest::from_json(&j).unwrap_err());
        assert!(msg.contains("store manifest version mismatch"), "{msg}");
    }
}
