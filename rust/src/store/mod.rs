//! Out-of-core sharded dataset store (DESIGN.md §13).
//!
//! The paper's headline regime — 700k flight records, MNIST-scale
//! GPLVMs — needs n bounded by disk, not leader RAM. This layer stores
//! a dataset as a directory of checksummed binary shard files
//! ([`codec`]: `GPDS` magic, versioned header, f64 row-major payload,
//! trailing XXH64) plus a JSON manifest ([`manifest`]: row ranges and
//! per-shard checksums), written by a streaming packer ([`writer`])
//! and read back through the [`DataSource`] trait:
//!
//! - [`InMemorySource`] wraps today's in-memory matrices (the
//!   bit-identical reference);
//! - [`ShardedDiskSource`] streams shard files chunk-by-chunk and
//!   never materialises the dataset; every streamed shard is verified
//!   against both its own trailing checksum and the manifest's record.
//!
//! Trainer bring-up consumes a source through a [`RowMapper`], which
//! turns raw store rows into worker-shard content (split input/output
//! columns for regression; a latent projector for LVM stores).

pub mod codec;
pub mod manifest;
pub mod writer;
pub mod xxh;

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::linalg::Matrix;

pub use manifest::{ShardEntry, StoreManifest};
pub use writer::StoreWriter;

/// A dataset that can be read as ordered row chunks. `stream_range`
/// visits rows `[start, end)` in order, in chunks of at most
/// `chunk_rows` rows, calling `f(global_row_of_first_chunk_row, chunk)`.
pub trait DataSource {
    fn rows(&self) -> usize;
    fn dims(&self) -> usize;
    fn stream_range(
        &self,
        start: usize,
        end: usize,
        chunk_rows: usize,
        f: &mut dyn FnMut(usize, &Matrix) -> Result<()>,
    ) -> Result<()>;
}

fn check_range(rows: usize, start: usize, end: usize, chunk_rows: usize) -> Result<()> {
    ensure!(chunk_rows >= 1, "chunk_rows must be >= 1");
    ensure!(
        start <= end && end <= rows,
        "row range [{start}, {end}) out of bounds for {rows} rows"
    );
    Ok(())
}

/// The trivial source: a dataset already materialised as a matrix.
/// This is the bit-identical reference the disk source is tested
/// against — chunking must never change what a consumer sees.
pub struct InMemorySource {
    data: Matrix,
}

impl InMemorySource {
    pub fn new(data: Matrix) -> InMemorySource {
        InMemorySource { data }
    }

    pub fn data(&self) -> &Matrix {
        &self.data
    }
}

impl DataSource for InMemorySource {
    fn rows(&self) -> usize {
        self.data.rows()
    }

    fn dims(&self) -> usize {
        self.data.cols()
    }

    fn stream_range(
        &self,
        start: usize,
        end: usize,
        chunk_rows: usize,
        f: &mut dyn FnMut(usize, &Matrix) -> Result<()>,
    ) -> Result<()> {
        check_range(self.data.rows(), start, end, chunk_rows)?;
        let mut lo = start;
        while lo < end {
            let hi = (lo + chunk_rows).min(end);
            let chunk = Matrix::from_fn(hi - lo, self.data.cols(), |r, c| self.data[(lo + r, c)]);
            f(lo, &chunk)?;
            lo = hi;
        }
        Ok(())
    }
}

/// A store directory opened for streaming reads. Opening cross-checks
/// every shard file's header (14 bytes each) against the manifest, so
/// a swapped or reshaped shard fails before any payload is streamed;
/// payload checksums are verified during each streamed read.
pub struct ShardedDiskSource {
    dir: PathBuf,
    manifest: StoreManifest,
}

impl ShardedDiskSource {
    pub fn open(dir: &Path) -> Result<ShardedDiskSource> {
        let manifest = StoreManifest::load(dir)?;
        for (i, e) in manifest.shards.iter().enumerate() {
            let path = manifest.shard_path(dir, i);
            let (rows, cols) = codec::read_header(&path)?;
            ensure!(
                rows == e.rows,
                "store shard {i} row count mismatch: manifest says {}, {} has {rows}",
                e.rows,
                path.display()
            );
            ensure!(
                cols == manifest.dims,
                "store shard {i} column count mismatch: manifest says {}, {} has {cols}",
                manifest.dims,
                path.display()
            );
        }
        Ok(ShardedDiskSource {
            dir: dir.to_path_buf(),
            manifest,
        })
    }

    pub fn manifest(&self) -> &StoreManifest {
        &self.manifest
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn shard_path(&self, i: usize) -> PathBuf {
        self.manifest.shard_path(&self.dir, i)
    }

    /// Deep verification: stream every shard, checking each file's own
    /// checksum AND the manifest's record of it. Returns bytes read.
    pub fn verify(&self) -> Result<u64> {
        let mut bytes = 0u64;
        for i in 0..self.manifest.shards.len() {
            let e = &self.manifest.shards[i];
            let path = self.shard_path(i);
            let (rows, cols, sum) = codec::stream_shard(&path, 4096, &mut |_, _| Ok(()))
                .with_context(|| format!("verifying store shard {i}"))?;
            ensure!(
                sum == e.checksum,
                "store checksum mismatch for shard {i}: manifest records {:#018x}, {} has {sum:#018x}",
                e.checksum,
                path.display()
            );
            bytes += (codec::HEADER_LEN + codec::CHECKSUM_LEN) as u64
                + (rows as u64) * (cols as u64) * 8;
        }
        Ok(bytes)
    }

    /// Materialise the whole store (inspect/tests/small stores only —
    /// this is exactly what the streaming paths exist to avoid).
    pub fn read_all(&self) -> Result<Matrix> {
        let mut out = Matrix::zeros(self.manifest.n, self.manifest.dims);
        self.stream_range(0, self.manifest.n, 4096, &mut |row0, chunk| {
            for i in 0..chunk.rows() {
                out.row_mut(row0 + i).copy_from_slice(chunk.row(i));
            }
            Ok(())
        })?;
        Ok(out)
    }
}

impl DataSource for ShardedDiskSource {
    fn rows(&self) -> usize {
        self.manifest.n
    }

    fn dims(&self) -> usize {
        self.manifest.dims
    }

    fn stream_range(
        &self,
        start: usize,
        end: usize,
        chunk_rows: usize,
        f: &mut dyn FnMut(usize, &Matrix) -> Result<()>,
    ) -> Result<()> {
        check_range(self.manifest.n, start, end, chunk_rows)?;
        for (i, e) in self.manifest.shards.iter().enumerate() {
            let s_lo = e.start;
            let s_hi = e.start + e.rows;
            if s_hi <= start || s_lo >= end {
                continue;
            }
            // the WHOLE overlapping shard file is streamed (and hashed)
            // even when the range clips it: integrity is per shard, and
            // sequential IO of the tail costs less than losing the
            // checksum. Rows outside [start, end) are clipped out of
            // each chunk before delivery.
            let path = self.shard_path(i);
            let (_, _, sum) = codec::stream_shard(&path, chunk_rows, &mut |row0, chunk| {
                let g_lo = s_lo + row0;
                let g_hi = g_lo + chunk.rows();
                let lo = g_lo.max(start);
                let hi = g_hi.min(end);
                if lo >= hi {
                    return Ok(());
                }
                if lo == g_lo && hi == g_hi {
                    return f(g_lo, chunk);
                }
                let clipped = Matrix::from_fn(hi - lo, chunk.cols(), |r, c| {
                    chunk[(lo - g_lo + r, c)]
                });
                f(lo, &clipped)
            })
            .with_context(|| format!("streaming store shard {i}"))?;
            ensure!(
                sum == e.checksum,
                "store checksum mismatch for shard {i}: manifest records {:#018x}, {} has {sum:#018x}",
                e.checksum,
                path.display()
            );
        }
        Ok(())
    }
}

/// Maps a chunk of raw store rows onto worker-shard content
/// `(xmu, xvar, y)`. `row0` is the global dataset row of the chunk's
/// first row, so mappers may key per-row state off absolute position.
pub trait RowMapper {
    /// `(q, d)` this mapper produces from a store of `dims` columns.
    fn shapes(&self, dims: usize) -> Result<(usize, usize)>;
    fn map(&self, row0: usize, chunk: &Matrix) -> Result<(Matrix, Matrix, Matrix)>;
}

/// Regression stores: the first `x_cols` columns are the inputs
/// (observed, so `q(X)` is a delta: Xvar = 0), the rest the outputs.
pub struct SplitColumns {
    pub x_cols: usize,
}

/// LVM stores (`x_cols = 0`): every store column is an output. The
/// latent initialisation is a FIXED linear map — subtract `mean`,
/// project onto `components`, whiten by `scale` — applied per row, so
/// any chunking of the store produces bit-identical worker shards.
/// Built from a PCA fit of a bounded sample of rows via
/// [`PcaProject::from_pca`] (paper §4.1 initialisation, out-of-core:
/// the sample bounds leader memory, not n).
pub struct PcaProject {
    /// d x q orthonormal projection axes (the sample's PCA components).
    pub components: Matrix,
    /// Column means subtracted before projecting (length d).
    pub mean: Vec<f64>,
    /// Per-latent whitening factor `1/sigma_c` (length q).
    pub scale: Vec<f64>,
    /// Initial q(X) variance for every latent coordinate.
    pub xvar0: f64,
}

impl PcaProject {
    pub fn from_pca(p: &crate::data::pca::Pca, xvar0: f64) -> PcaProject {
        PcaProject {
            components: p.components.clone(),
            mean: p.mean.clone(),
            scale: p
                .eigenvalues
                .iter()
                .map(|e| 1.0 / e.sqrt().max(1e-12))
                .collect(),
            xvar0,
        }
    }
}

impl RowMapper for PcaProject {
    fn shapes(&self, dims: usize) -> Result<(usize, usize)> {
        ensure!(
            self.components.rows() == dims,
            "PCA projector was fit on {}-column rows but the store has {dims}",
            self.components.rows()
        );
        Ok((self.components.cols(), dims))
    }

    fn map(&self, _row0: usize, chunk: &Matrix) -> Result<(Matrix, Matrix, Matrix)> {
        let (q, d) = self.shapes(chunk.cols())?;
        let xmu = Matrix::from_fn(chunk.rows(), q, |r, c| {
            let mut s = 0.0;
            for j in 0..d {
                s += (chunk[(r, j)] - self.mean[j]) * self.components[(j, c)];
            }
            s * self.scale[c]
        });
        let xvar = Matrix::from_fn(chunk.rows(), q, |_, _| self.xvar0);
        Ok((xmu, xvar, chunk.clone()))
    }
}

impl RowMapper for SplitColumns {
    fn shapes(&self, dims: usize) -> Result<(usize, usize)> {
        ensure!(
            self.x_cols >= 1 && self.x_cols < dims,
            "x_cols ({}) must be in [1, dims) for a regression store (dims {dims})",
            self.x_cols
        );
        Ok((self.x_cols, dims - self.x_cols))
    }

    fn map(&self, _row0: usize, chunk: &Matrix) -> Result<(Matrix, Matrix, Matrix)> {
        let (q, d) = self.shapes(chunk.cols())?;
        let xmu = Matrix::from_fn(chunk.rows(), q, |r, c| chunk[(r, c)]);
        let xvar = Matrix::zeros(chunk.rows(), q);
        let y = Matrix::from_fn(chunk.rows(), d, |r, c| chunk[(r, q + c)]);
        Ok((xmu, xvar, y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_fixture(name: &str, n: usize, dims: usize, shard_rows: usize) -> (PathBuf, Matrix) {
        let dir = std::env::temp_dir().join(format!("gpds_src_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let data = Matrix::from_fn(n, dims, |i, j| ((i * dims + j) as f64).sin());
        let mut w = StoreWriter::create(&dir, 0, shard_rows, None).unwrap();
        w.append(&data).unwrap();
        w.finish().unwrap();
        (dir, data)
    }

    fn collect_range(src: &dyn DataSource, start: usize, end: usize, chunk: usize) -> Matrix {
        let mut out = Matrix::zeros(end - start, src.dims());
        src.stream_range(start, end, chunk, &mut |row0, c| {
            for i in 0..c.rows() {
                out.row_mut(row0 - start + i).copy_from_slice(c.row(i));
            }
            Ok(())
        })
        .unwrap();
        out
    }

    #[test]
    fn disk_source_matches_in_memory_on_every_range_and_chunking() {
        let (dir, data) = store_fixture("ranges", 29, 3, 7);
        let disk = ShardedDiskSource::open(&dir).unwrap();
        let mem = InMemorySource::new(data);
        for (start, end) in [(0, 29), (0, 5), (5, 9), (6, 23), (28, 29), (7, 7)] {
            for chunk in [1usize, 2, 5, 7, 8, 64] {
                let a = collect_range(&mem, start, end, chunk);
                let b = collect_range(&disk, start, end, chunk);
                assert_eq!(a.data().len(), b.data().len());
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "range [{start},{end}) chunk {chunk}");
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_checksum_disagreement_is_rejected() {
        let (dir, _) = store_fixture("disagree", 12, 2, 4);
        // rewrite shard 1 with different content: its own trailing
        // checksum is valid, but the manifest still records the old one
        let path = dir.join("shard_00001.gpds");
        codec::write_shard(&path, &Matrix::from_fn(4, 2, |i, j| (i + j) as f64)).unwrap();
        let src = ShardedDiskSource::open(&dir).unwrap();
        let msg = format!("{:#}", src.verify().unwrap_err());
        assert!(msg.contains("store checksum mismatch for shard 1"), "{msg}");
        let msg = format!(
            "{:#}",
            src.stream_range(0, 12, 4, &mut |_, _| Ok(())).unwrap_err()
        );
        assert!(msg.contains("store checksum mismatch for shard 1"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reshaped_shard_is_rejected_at_open() {
        let (dir, _) = store_fixture("reshape", 12, 2, 4);
        // swap shard 2 for a valid file with the wrong shape: the cheap
        // header cross-check at open() must catch it, pre-payload
        codec::write_shard(
            &dir.join("shard_00002.gpds"),
            &Matrix::from_fn(3, 2, |i, j| (i + j) as f64),
        )
        .unwrap();
        let msg = format!("{:#}", ShardedDiskSource::open(&dir).unwrap_err());
        assert!(msg.contains("row count mismatch"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_shard_fails_streaming_with_named_error() {
        let (dir, _) = store_fixture("corrupt", 10, 2, 5);
        let path = dir.join("shard_00000.gpds");
        let mut bytes = std::fs::read(&path).unwrap();
        let k = codec::HEADER_LEN + 3;
        bytes[k] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let src = ShardedDiskSource::open(&dir).unwrap();
        let msg = format!(
            "{:#}",
            src.stream_range(0, 10, 5, &mut |_, _| Ok(())).unwrap_err()
        );
        assert!(msg.contains("checksum mismatch"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pca_projector_matches_whitened_scores_and_is_chunk_invariant() {
        let mut rng = crate::util::rng::Rng::new(3);
        let y = Matrix::from_fn(40, 6, |_, _| rng.normal());
        let p = crate::data::pca::pca(&y, 2, 50, 7);
        let want = crate::data::pca::whitened_scores(&p);
        let m = PcaProject::from_pca(&p, 0.5);
        assert_eq!(m.shapes(6).unwrap(), (2, 6));
        assert!(m.shapes(5).is_err(), "dims mismatch must be rejected");

        // on the fit sample, the projector reproduces the whitened scores
        let (xmu, xvar, back) = m.map(0, &y).unwrap();
        assert_eq!((xmu.rows(), xmu.cols()), (40, 2));
        for (a, b) in want.data().iter().zip(xmu.data()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        assert!(xvar.data().iter().all(|v| *v == 0.5));
        assert_eq!(back.max_abs_diff(&y), 0.0, "y must pass through untouched");

        // per-row map: chunking never changes the produced latents
        let top = Matrix::from_fn(15, 6, |r, c| y[(r, c)]);
        let rest = Matrix::from_fn(25, 6, |r, c| y[(15 + r, c)]);
        let (a, _, _) = m.map(0, &top).unwrap();
        let (b, _, _) = m.map(15, &rest).unwrap();
        for (i, v) in a.data().iter().chain(b.data()).enumerate() {
            assert_eq!(v.to_bits(), xmu.data()[i].to_bits(), "row-major index {i}");
        }
    }

    #[test]
    fn split_columns_mapper_splits_and_checks() {
        let chunk = Matrix::from_fn(4, 5, |i, j| (i * 5 + j) as f64);
        let m = SplitColumns { x_cols: 2 };
        assert_eq!(m.shapes(5).unwrap(), (2, 3));
        let (xmu, xvar, y) = m.map(0, &chunk).unwrap();
        assert_eq!((xmu.rows(), xmu.cols()), (4, 2));
        assert_eq!((y.rows(), y.cols()), (4, 3));
        assert_eq!(xmu[(1, 1)], 6.0);
        assert_eq!(y[(1, 0)], 7.0);
        assert_eq!(xvar.max_abs(), 0.0);
        assert!(SplitColumns { x_cols: 0 }.shapes(5).is_err());
        assert!(SplitColumns { x_cols: 5 }.shapes(5).is_err());
    }
}
