//! Oil-flow-like dataset (substitute for the 3-phase oil flow data used
//! in the paper's Fig. 4 / Fig. 7 experiments — the original is not
//! redistributable).
//!
//! Structure preserved (DESIGN.md §5): 12-dimensional observations
//! generated from a low-dimensional latent space with three distinct
//! flow-regime clusters, so that (a) a GPLVM with an ARD kernel should
//! discover a low intrinsic dimensionality, and (b) the classes separate
//! in the learned latent space.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

pub struct OilFlow {
    /// Observations, n x 12.
    pub y: Matrix,
    /// Class label (flow regime) per point, values 0..3.
    pub labels: Vec<usize>,
    /// Ground-truth 2D latent coordinates.
    pub latent: Matrix,
}

/// Generate `n` points, roughly balanced across the three regimes.
pub fn generate(n: usize, seed: u64) -> OilFlow {
    let mut rng = Rng::new(seed);
    let dim = 12;
    // class centres in the 2D latent space, well separated
    let centres = [(-2.0, 0.0), (1.2, 1.8), (1.2, -1.8)];
    // one smooth nonlinear map shared by all classes: 12 random
    // sinusoidal features of the latent position
    let mut prng = Rng::new(seed ^ 0xABCD);
    let w1: Vec<f64> = (0..dim).map(|_| prng.range(-1.0, 1.0)).collect();
    let w2: Vec<f64> = (0..dim).map(|_| prng.range(-1.0, 1.0)).collect();
    let ph: Vec<f64> = (0..dim).map(|_| prng.range(0.0, 6.28)).collect();
    let amp: Vec<f64> = (0..dim).map(|_| prng.range(0.5, 1.5)).collect();

    let mut y = Matrix::zeros(n, dim);
    let mut labels = Vec::with_capacity(n);
    let mut latent = Matrix::zeros(n, 2);
    for i in 0..n {
        let cls = i % 3;
        labels.push(cls);
        let (cx, cy) = centres[cls];
        let lx = cx + 0.45 * rng.normal();
        let ly = cy + 0.45 * rng.normal();
        latent[(i, 0)] = lx;
        latent[(i, 1)] = ly;
        for j in 0..dim {
            let u = w1[j] * lx + w2[j] * ly;
            y[(i, j)] = amp[j] * (u + ph[j]).sin() + 0.4 * u + 0.05 * rng.normal();
        }
    }
    OilFlow { y, labels, latent }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_classes_and_shapes() {
        let d = generate(300, 0);
        assert_eq!(d.y.rows(), 300);
        assert_eq!(d.y.cols(), 12);
        for c in 0..3 {
            let count = d.labels.iter().filter(|&&l| l == c).count();
            assert_eq!(count, 100);
        }
    }

    #[test]
    fn classes_are_separated_in_observation_space() {
        let d = generate(300, 1);
        // mean vectors per class should be pairwise distinct
        let mut means = vec![vec![0.0; 12]; 3];
        let mut counts = [0usize; 3];
        for i in 0..300 {
            let c = d.labels[i];
            counts[c] += 1;
            for j in 0..12 {
                means[c][j] += d.y[(i, j)];
            }
        }
        for c in 0..3 {
            for j in 0..12 {
                means[c][j] /= counts[c] as f64;
            }
        }
        for a in 0..3 {
            for b in (a + 1)..3 {
                let dist: f64 = (0..12)
                    .map(|j| (means[a][j] - means[b][j]).powi(2))
                    .sum::<f64>()
                    .sqrt();
                assert!(dist > 0.5, "classes {a} and {b} overlap (dist {dist})");
            }
        }
    }
}
