//! PCA via orthogonal power iteration — used to initialise the GPLVM
//! latent space (paper §4.1) and as the linear baseline in Fig. 1.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Result of a PCA projection.
pub struct Pca {
    /// Scores: n x k projection of the (centred) data.
    pub scores: Matrix,
    /// Principal axes, d x k (orthonormal columns).
    pub components: Matrix,
    /// Eigenvalues (variance along each axis), length k.
    pub eigenvalues: Vec<f64>,
    /// Column means of the input.
    pub mean: Vec<f64>,
}

/// Top-`k` PCA of `y` (n x d) by blocked power iteration on the
/// covariance (never forms the n x n Gram matrix).
pub fn pca(y: &Matrix, k: usize, iters: usize, seed: u64) -> Pca {
    let (n, d) = (y.rows(), y.cols());
    assert!(k <= d, "k must be <= feature dimension");
    let mean: Vec<f64> = (0..d)
        .map(|j| (0..n).map(|i| y[(i, j)]).sum::<f64>() / n as f64)
        .collect();
    let centred = Matrix::from_fn(n, d, |i, j| y[(i, j)] - mean[j]);

    let mut rng = Rng::new(seed);
    let mut q = Matrix::from_fn(d, k, |_, _| rng.normal());
    orthonormalise(&mut q);
    for _ in 0..iters {
        // q <- orth( Y^T (Y q) / n )
        let yq = centred.matmul(&q); // n x k
        q = centred.t_matmul(&yq).scale(1.0 / n as f64); // d x k
        orthonormalise(&mut q);
    }
    let scores = centred.matmul(&q);
    let eigenvalues: Vec<f64> = (0..k)
        .map(|c| (0..n).map(|i| scores[(i, c)] * scores[(i, c)]).sum::<f64>() / n as f64)
        .collect();
    Pca {
        scores,
        components: q,
        eigenvalues,
        mean,
    }
}

/// Gram-Schmidt on the columns.
fn orthonormalise(q: &mut Matrix) {
    let (d, k) = (q.rows(), q.cols());
    for c in 0..k {
        for prev in 0..c {
            let dot: f64 = (0..d).map(|i| q[(i, c)] * q[(i, prev)]).sum();
            for i in 0..d {
                q[(i, c)] -= dot * q[(i, prev)];
            }
        }
        let norm: f64 = (0..d).map(|i| q[(i, c)] * q[(i, c)]).sum::<f64>().sqrt();
        if norm > 1e-12 {
            for i in 0..d {
                q[(i, c)] /= norm;
            }
        }
    }
}

/// Standardise scores to unit variance per column (the usual GPLVM
/// latent initialisation).
pub fn whitened_scores(p: &Pca) -> Matrix {
    let (n, k) = (p.scores.rows(), p.scores.cols());
    Matrix::from_fn(n, k, |i, c| {
        let sd = p.eigenvalues[c].sqrt().max(1e-12);
        p.scores[(i, c)] / sd
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_dominant_direction() {
        // rank-1 data along a known direction + small noise
        let mut rng = Rng::new(0);
        let dir = [0.6, 0.8];
        let y = Matrix::from_fn(500, 2, |_, j| {
            // same t per row: regenerate deterministically per row
            0.0 * j as f64
        });
        // build properly: t_i * dir + eps
        let mut y = y;
        for i in 0..500 {
            let t = rng.range(-2.0, 2.0);
            for j in 0..2 {
                y[(i, j)] = t * dir[j] + 0.01 * rng.normal();
            }
        }
        let p = pca(&y, 1, 50, 1);
        let c = [p.components[(0, 0)], p.components[(1, 0)]];
        let align = (c[0] * dir[0] + c[1] * dir[1]).abs();
        assert!(align > 0.999, "alignment {align}");
    }

    #[test]
    fn components_are_orthonormal() {
        let mut rng = Rng::new(2);
        let y = Matrix::from_fn(200, 5, |_, _| rng.normal());
        let p = pca(&y, 3, 50, 3);
        for a in 0..3 {
            for b in 0..3 {
                let dot: f64 = (0..5)
                    .map(|i| p.components[(i, a)] * p.components[(i, b)])
                    .sum();
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn eigenvalues_sorted_descending_in_practice() {
        let mut rng = Rng::new(4);
        // anisotropic data: var 9 along dim0, 1 along dim1, 0.25 dim2
        let y = Matrix::from_fn(400, 3, |_, j| {
            let s = [3.0, 1.0, 0.5][j];
            s * rng.normal()
        });
        let p = pca(&y, 3, 100, 5);
        assert!(p.eigenvalues[0] > p.eigenvalues[1]);
        assert!(p.eigenvalues[1] > p.eigenvalues[2]);
    }

    #[test]
    fn whitened_scores_have_unit_variance() {
        let mut rng = Rng::new(6);
        let y = Matrix::from_fn(300, 4, |_, _| 2.5 * rng.normal());
        let p = pca(&y, 2, 60, 7);
        let w = whitened_scores(&p);
        for c in 0..2 {
            let var: f64 = (0..300).map(|i| w[(i, c)] * w[(i, c)]).sum::<f64>() / 300.0;
            assert!((var - 1.0).abs() < 0.05, "col {c} var {var}");
        }
    }
}
