//! Synthetic flight-delay-style regression generator for the
//! paper-scale scenario (`gparml experiment flights`). The paper's
//! flight-delay benchmark regresses arrival delay on 8 covariates
//! (month, day of month, day of week, plane age, air time, distance,
//! departure and arrival times) over 700k training records; the real
//! table is not redistributable, so this generates a structurally
//! equivalent task: 8 standardised covariates, a smooth nonlinear
//! delay surface with interactions, and heteroscedastic noise
//! (delays get noisier on long congested routes — the property that
//! makes the benchmark non-trivial for a stationary kernel).
//!
//! Rows are seeded **per row** (splitmix-style mix of `seed` and the
//! absolute row index), so generation is chunk-invariant: any chunking
//! of `[0, n)` produces bit-identical rows, and the packer can stream
//! a 700k-row store with O(chunk) memory. Row indices past n are valid
//! too — held-out test rows are just `chunk(seed, n, n_test)`.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Covariate count (paper's 8 flight-record columns).
pub const INPUT_COLS: usize = 8;
/// Store row layout: 8 inputs then the delay.
pub const DIMS: usize = INPUT_COLS + 1;

/// Generate rows `[start, start + rows)` as a `rows x 9` matrix
/// (inputs then delay), bit-identical under any chunking.
pub fn chunk(seed: u64, start: usize, rows: usize) -> Matrix {
    let mut out = Matrix::zeros(rows, DIMS);
    for i in 0..rows {
        row_into(seed, start + i, out.row_mut(i));
    }
    out
}

/// Fill one dataset row: deterministic in `(seed, index)` only.
fn row_into(seed: u64, index: usize, out: &mut [f64]) {
    // decorrelate the per-row stream from the seed with an odd-constant
    // multiply (the Rng constructor's splitmix expansion does the rest)
    let mut rng = Rng::new(seed ^ (index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let month = rng.range(-1.7, 1.7); // standardised calendar covariates
    let day = rng.range(-1.7, 1.7);
    let weekday = rng.range(-1.7, 1.7);
    let plane_age = rng.normal() * 0.8;
    let distance = rng.normal().abs().min(3.0) - 1.0; // right-skewed, standardised
    let air_time = 0.9 * distance + 0.2 * rng.normal();
    let dep_time = rng.range(-1.7, 1.7);
    let arr_time = (dep_time + 0.3 * distance + 0.1 * rng.normal()).clamp(-2.5, 2.5);
    // smooth delay surface: rush-hour ridge, long-route interaction,
    // weekend dip, old-plane penalty
    let f = 0.9 * (1.8 * dep_time).sin()
        + 0.6 * distance * (0.7 * month).cos()
        + 0.4 * (plane_age * plane_age - 0.64)
        + 0.3 * weekday
        + 0.25 * air_time * dep_time
        - 0.2 * day * weekday;
    // heteroscedastic noise: long congested routes are noisier
    let sigma = 0.15 + 0.1 * (distance + 1.0).max(0.0);
    let delay = f + sigma * rng.normal();
    out[0] = month;
    out[1] = day;
    out[2] = weekday;
    out[3] = plane_age;
    out[4] = air_time;
    out[5] = distance;
    out[6] = dep_time;
    out[7] = arr_time;
    out[8] = delay;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunking_is_invariant() {
        let whole = chunk(7, 0, 50);
        let mut parts = chunk(7, 0, 13);
        parts = parts.vstack(&chunk(7, 13, 17));
        parts = parts.vstack(&chunk(7, 30, 20));
        for (a, b) in whole.data().iter().zip(parts.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn rows_are_finite_and_seed_sensitive() {
        let a = chunk(1, 0, 100);
        let b = chunk(2, 0, 100);
        assert!(a.data().iter().all(|v| v.is_finite()));
        assert!(a.data().iter().zip(b.data()).any(|(x, y)| x != y));
        // delay correlates with the surface, not pure noise: its
        // variance must be well above the noise floor
        let mean = a.data().iter().skip(8).step_by(9).sum::<f64>() / 100.0;
        let var = a
            .data()
            .iter()
            .skip(8)
            .step_by(9)
            .map(|v| (v - mean) * (v - mean))
            .sum::<f64>()
            / 100.0;
        assert!(var > 0.2, "delay variance {var} too small");
    }
}
