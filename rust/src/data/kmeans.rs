//! Lloyd's k-means — the paper initialises the inducing-point locations
//! with "k-means with added noise" (§4.1).

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// k-means centres of `x` (n x q), k centres, `iters` Lloyd steps.
pub fn kmeans(x: &Matrix, k: usize, iters: usize, rng: &mut Rng) -> Matrix {
    let (n, q) = (x.rows(), x.cols());
    assert!(k <= n, "more centres than points");
    // k-means++ seeding: first centre uniform, then proportional to the
    // squared distance to the closest chosen centre
    let mut chosen: Vec<usize> = vec![rng.below(n)];
    let mut d2 = vec![f64::INFINITY; n];
    while chosen.len() < k {
        let last = *chosen.last().unwrap();
        for i in 0..n {
            let dist: f64 = (0..q).map(|j| (x[(i, j)] - x[(last, j)]).powi(2)).sum();
            if dist < d2[i] {
                d2[i] = dist;
            }
        }
        let total: f64 = d2.iter().sum();
        let mut target = rng.uniform() * total;
        let mut pick = n - 1;
        for i in 0..n {
            target -= d2[i];
            if target <= 0.0 {
                pick = i;
                break;
            }
        }
        chosen.push(pick);
    }
    let mut centres = Matrix::from_fn(k, q, |c, j| x[(chosen[c], j)]);

    let mut assign = vec![0usize; n];
    for _ in 0..iters {
        // assignment step
        for i in 0..n {
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..k {
                let d: f64 = (0..q)
                    .map(|j| (x[(i, j)] - centres[(c, j)]).powi(2))
                    .sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            assign[i] = best.1;
        }
        // update step
        let mut sums = Matrix::zeros(k, q);
        let mut counts = vec![0usize; k];
        for i in 0..n {
            counts[assign[i]] += 1;
            for j in 0..q {
                sums[(assign[i], j)] += x[(i, j)];
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed an empty cluster at a random point
                let r = rng.below(n);
                for j in 0..q {
                    centres[(c, j)] = x[(r, j)];
                }
            } else {
                for j in 0..q {
                    centres[(c, j)] = sums[(c, j)] / counts[c] as f64;
                }
            }
        }
    }
    centres
}

/// The paper's inducing-point initialisation: k-means centres plus a
/// little noise (breaks exact symmetries between Z and data points).
pub fn inducing_init(x: &Matrix, k: usize, noise: f64, rng: &mut Rng) -> Matrix {
    let mut z = kmeans(x, k, 20, rng);
    for v in z.data_mut() {
        *v += noise * rng.normal();
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_separated_clusters() {
        let mut rng = Rng::new(0);
        let n = 300;
        let x = Matrix::from_fn(n, 2, |i, j| {
            let c = i % 3;
            let centre = [[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]][c][j];
            centre + 0.3 * rng.normal()
        });
        let centres = kmeans(&x, 3, 30, &mut rng);
        // each true centre has a kmeans centre within 0.5
        for truth in [[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]] {
            let closest = (0..3)
                .map(|c| {
                    ((centres[(c, 0)] - truth[0]).powi(2)
                        + (centres[(c, 1)] - truth[1]).powi(2))
                    .sqrt()
                })
                .fold(f64::INFINITY, f64::min);
            assert!(closest < 0.5, "no centre near {truth:?} ({closest})");
        }
    }

    #[test]
    fn inducing_init_shape_and_jitter() {
        let mut rng = Rng::new(1);
        let x = Matrix::from_fn(50, 3, |_, _| rng.normal());
        let z = inducing_init(&x, 8, 0.05, &mut rng);
        assert_eq!((z.rows(), z.cols()), (8, 3));
    }

    #[test]
    fn handles_k_equals_n() {
        let mut rng = Rng::new(2);
        let x = Matrix::from_fn(5, 2, |i, j| (i * 2 + j) as f64);
        let z = kmeans(&x, 5, 10, &mut rng);
        assert_eq!(z.rows(), 5);
    }
}
