//! Dataset generators and initialisation utilities.
//!
//! The paper's real datasets (oil-flow, USPS) are not redistributable
//! here; `oilflow` and `digits` generate structurally equivalent
//! synthetic versions (DESIGN.md §5 documents why each substitution
//! preserves the behaviour being measured). `synthetic` is the paper's
//! own synthetic benchmark (Figs. 1-3).

pub mod digits;
pub mod flights;
pub mod kmeans;
pub mod oilflow;
pub mod pca;
pub mod synthetic;
