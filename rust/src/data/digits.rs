//! USPS-like synthetic digit images (substitute for the USPS dataset of
//! §4.5/Fig. 6 — not redistributable here).
//!
//! 16x16 grayscale digits rendered from hand-coded stroke templates with
//! random affine jitter (shift, scale) and pixel noise, so the GPLVM
//! faces the same task shape: a density model over 256-dimensional
//! images with ~10 modes, evaluated by reconstructing missing pixels.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

pub const SIDE: usize = 16;
pub const PIXELS: usize = SIDE * SIDE;

/// 8x12 coarse glyph templates for digits 0-9 ('#' = ink).
const GLYPHS: [[&str; 12]; 10] = [
    [
        " ###### ", "##    ##", "##    ##", "##    ##", "##    ##", "##    ##", "##    ##",
        "##    ##", "##    ##", "##    ##", "##    ##", " ###### ",
    ],
    [
        "   ##   ", "  ###   ", " ####   ", "   ##   ", "   ##   ", "   ##   ", "   ##   ",
        "   ##   ", "   ##   ", "   ##   ", "   ##   ", " ###### ",
    ],
    [
        " ###### ", "##    ##", "      ##", "      ##", "     ## ", "    ##  ", "   ##   ",
        "  ##    ", " ##     ", "##      ", "##      ", "########",
    ],
    [
        " ###### ", "##    ##", "      ##", "      ##", "  ##### ", "  ##### ", "      ##",
        "      ##", "      ##", "      ##", "##    ##", " ###### ",
    ],
    [
        "##   ## ", "##   ## ", "##   ## ", "##   ## ", "##   ## ", "########", "########",
        "     ## ", "     ## ", "     ## ", "     ## ", "     ## ",
    ],
    [
        "########", "##      ", "##      ", "##      ", "####### ", "      ##", "      ##",
        "      ##", "      ##", "      ##", "##    ##", " ###### ",
    ],
    [
        " ###### ", "##    ##", "##      ", "##      ", "####### ", "##    ##", "##    ##",
        "##    ##", "##    ##", "##    ##", "##    ##", " ###### ",
    ],
    [
        "########", "      ##", "      ##", "     ## ", "     ## ", "    ##  ", "    ##  ",
        "   ##   ", "   ##   ", "  ##    ", "  ##    ", "  ##    ",
    ],
    [
        " ###### ", "##    ##", "##    ##", "##    ##", " ###### ", " ###### ", "##    ##",
        "##    ##", "##    ##", "##    ##", "##    ##", " ###### ",
    ],
    [
        " ###### ", "##    ##", "##    ##", "##    ##", "##    ##", " #######", "      ##",
        "      ##", "      ##", "      ##", "##    ##", " ###### ",
    ],
];

/// Bilinear sample of the template for digit `d` at continuous
/// coordinates (u, v) in template space.
fn template_at(d: usize, u: f64, v: f64) -> f64 {
    let (w, h) = (8.0, 12.0);
    if u < 0.0 || v < 0.0 || u >= w - 1.0 || v >= h - 1.0 {
        return 0.0;
    }
    let (x0, y0) = (u.floor() as usize, v.floor() as usize);
    let (fx, fy) = (u - u.floor(), v - v.floor());
    let ink = |x: usize, y: usize| -> f64 {
        if GLYPHS[d][y].as_bytes()[x] == b'#' {
            1.0
        } else {
            0.0
        }
    };
    ink(x0, y0) * (1.0 - fx) * (1.0 - fy)
        + ink(x0 + 1, y0) * fx * (1.0 - fy)
        + ink(x0, y0 + 1) * (1.0 - fx) * fy
        + ink(x0 + 1, y0 + 1) * fx * fy
}

pub struct Digits {
    /// Flattened images, n x 256, values in [0, 1] plus noise.
    pub y: Matrix,
    /// Digit label per image.
    pub labels: Vec<usize>,
}

/// Render `n` digits cycling through 0-9 with random affine jitter.
pub fn generate(n: usize, noise: f64, seed: u64) -> Digits {
    let mut rng = Rng::new(seed);
    let mut y = Matrix::zeros(n, PIXELS);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let d = i % 10;
        labels.push(d);
        let scale = rng.range(0.85, 1.15);
        let dx = rng.range(-1.5, 1.5);
        let dy = rng.range(-1.5, 1.5);
        for py in 0..SIDE {
            for px in 0..SIDE {
                // map the 16x16 canvas into 8x12 template coordinates
                let u = ((px as f64 - dx) / SIDE as f64 - 0.5) / scale * 8.0 + 3.5;
                let v = ((py as f64 - dy) / SIDE as f64 - 0.5) / scale * 12.0 + 5.5;
                let val = template_at(d, u, v) + noise * rng.normal();
                y[(i, py * SIDE + px)] = val.clamp(-0.25, 1.25);
            }
        }
    }
    Digits { y, labels }
}

/// Knock out a random fraction of pixels (returns the mask: true = kept).
pub fn drop_pixels(image: &[f64], frac: f64, rng: &mut Rng) -> (Vec<f64>, Vec<bool>) {
    let mut out = image.to_vec();
    let mut kept = vec![true; image.len()];
    for i in 0..image.len() {
        if rng.flip(frac) {
            out[i] = 0.0;
            kept[i] = false;
        }
    }
    (out, kept)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_digits() {
        let d = generate(20, 0.0, 3);
        assert_eq!(d.y.rows(), 20);
        assert_eq!(d.y.cols(), 256);
        // each image has a sensible amount of ink
        for i in 0..20 {
            let ink: f64 = d.y.row(i).iter().sum();
            assert!(ink > 10.0 && ink < 200.0, "image {i} ink {ink}");
        }
    }

    #[test]
    fn same_digit_images_are_more_similar_than_different() {
        let d = generate(40, 0.02, 5);
        let dist = |a: usize, b: usize| -> f64 {
            d.y.row(a)
                .iter()
                .zip(d.y.row(b))
                .map(|(x, y)| (x - y) * (x - y))
                .sum()
        };
        // 0 vs 10 are both '0's; 0 vs 1 differ
        let same = dist(0, 10) + dist(1, 11) + dist(2, 12);
        let diff = dist(0, 1) + dist(1, 2) + dist(2, 3);
        assert!(same < diff, "same {same} diff {diff}");
    }

    #[test]
    fn drop_pixels_masks_requested_fraction() {
        let mut rng = Rng::new(0);
        let img = vec![1.0; 1000];
        let (out, kept) = drop_pixels(&img, 0.34, &mut rng);
        let dropped = kept.iter().filter(|k| !**k).count();
        assert!((dropped as f64 / 1000.0 - 0.34).abs() < 0.06);
        for (i, k) in kept.iter().enumerate() {
            assert_eq!(out[i], if *k { 1.0 } else { 0.0 });
        }
    }
}
