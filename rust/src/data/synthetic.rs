//! The paper's synthetic benchmark (§4.2, Fig. 1): a 1D latent space
//! mapped into 3D observations "through linear functions with sines
//! superimposed", at any size — the dataset used for the 100K-point
//! scaling experiments (Figs. 2-3).

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// A generated dataset with the ground-truth latent coordinates.
pub struct Synthetic {
    /// Observations, n x 3.
    pub y: Matrix,
    /// Ground-truth 1D latent coordinate (for embedding-recovery checks).
    pub latent: Vec<f64>,
}

/// Generate `n` points: t ~ U(-3, 3);
/// y_j = a_j t + b_j sin(c_j t + phi_j) + eps.
pub fn generate(n: usize, noise: f64, seed: u64) -> Synthetic {
    let mut rng = Rng::new(seed);
    // fixed map parameters (drawn once so every size uses the same family)
    let mut prng = Rng::new(seed ^ 0x5EED);
    let a: Vec<f64> = (0..3).map(|_| prng.range(0.5, 1.5)).collect();
    let b: Vec<f64> = (0..3).map(|_| prng.range(0.3, 0.9)).collect();
    let c: Vec<f64> = (0..3).map(|_| prng.range(1.0, 2.5)).collect();
    let phi: Vec<f64> = (0..3).map(|_| prng.range(0.0, std::f64::consts::PI)).collect();

    let latent: Vec<f64> = (0..n).map(|_| rng.range(-3.0, 3.0)).collect();
    let y = Matrix::from_fn(n, 3, |i, j| {
        let t = latent[i];
        a[j] * t + b[j] * (c[j] * t + phi[j]).sin() + noise * rng.normal()
    });
    Synthetic { y, latent }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn shapes_and_determinism() {
        let d1 = generate(100, 0.05, 7);
        let d2 = generate(100, 0.05, 7);
        assert_eq!(d1.y.rows(), 100);
        assert_eq!(d1.y.cols(), 3);
        assert_eq!(d1.y.data(), d2.y.data());
        assert_ne!(d1.y.data(), generate(100, 0.05, 8).y.data());
    }

    #[test]
    fn observations_track_latent() {
        // the linear component dominates, so each output dim should
        // correlate strongly with the latent coordinate
        let d = generate(2000, 0.01, 1);
        for j in 0..3 {
            let col: Vec<f64> = (0..2000).map(|i| d.y[(i, j)]).collect();
            let r = stats::pearson(&d.latent, &col).abs();
            assert!(r > 0.8, "dim {j} correlation {r}");
        }
    }
}
