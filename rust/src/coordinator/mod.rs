//! Layer-3 coordinator: the paper's distributed inference.
//!
//! A leader drives a cluster of worker nodes through the
//! [`crate::cluster::Backend`] trait — OS threads in-process
//! ([`crate::cluster::PoolBackend`], the default) or real processes
//! over TCP ([`crate::cluster::TcpBackend`]). Each worker owns a data
//! shard and its own compiled executor. One outer iteration implements
//! the paper's §3.2 protocol:
//!
//! 1. broadcast the global parameters G = (Z, kernel hypers, beta);
//! 2. map: each worker computes its partial statistics
//!    (a, psi0, C, D, KL); reduce: sum (constant-size messages,
//!    m x m and m x d);
//! 3. central: assemble the collapsed bound F and adjoint matrices
//!    dF/d{psi0, C, D, KL, Kmm, log beta} (O(m^3), `gp::bound`);
//!    broadcast the adjoints;
//! 4. map: workers chain-rule to partial global gradients and update
//!    their local q(X) parameters; reduce: sum global gradients; the
//!    central node takes a scaled-conjugate-gradient step on G.
//!
//! Node failure (paper §5.2): a failed node's partial terms are dropped
//! from both reduces for that iteration, yielding a noisy gradient
//! rather than a stall. Transient failures (injection, Fig. 7) come
//! back next iteration; a lost TCP connection is permanent.

mod trainer;

pub use trainer::{
    make_inits, partition, GlobalOpt, ModelKind, StreamConfig, TrainConfig, Trainer,
};
