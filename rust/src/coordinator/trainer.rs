//! The distributed trainer: leader state machine + worker node state.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::gp::params::{GlobalGrads, GlobalParams};
use crate::gp::{self, kernel, Stats};
use crate::linalg::Matrix;
use crate::mapreduce::Pool;
use crate::optim::{Adam, Scg};
use crate::runtime::{Manifest, ShardData, ShardExecutor};
use crate::telemetry::{IterationLog, RoundTiming, RunLog};
use crate::util::rng::Rng;

/// Which of the paper's two models is being fit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// Sparse GP regression (Titsias 2009): inputs observed, q(X) a delta.
    Regression,
    /// Bayesian GPLVM (Titsias & Lawrence 2010): latent inputs, local
    /// variational parameters (mu_i, s_i) optimised on the workers.
    Lvm,
}

/// Optimiser for the global parameters.
#[derive(Debug, Clone, Copy)]
pub enum GlobalOpt {
    /// Scaled conjugate gradients (the paper's optimiser).
    Scg,
    /// Adam ablation (DESIGN.md ablation index).
    Adam { lr: f64 },
}

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Artifact config name in `artifacts/manifest.json`.
    pub artifact: String,
    /// Artifacts directory.
    pub artifacts_dir: PathBuf,
    /// Number of worker nodes (threads).
    pub workers: usize,
    pub model: ModelKind,
    pub global_opt: GlobalOpt,
    /// Adam learning rate for the workers' local q(X) updates.
    pub local_lr: f64,
    /// Kmm jitter.
    pub jitter: f64,
    /// Per-iteration, per-node failure probability (paper Fig. 7).
    pub failure_rate: f64,
    /// Floor on the local variances (keeps log s finite).
    pub min_xvar: f64,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifact: "small".into(),
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            workers: 4,
            model: ModelKind::Regression,
            global_opt: GlobalOpt::Scg,
            local_lr: 0.05,
            jitter: 1e-6,
            failure_rate: 0.0,
            min_xvar: 1e-6,
            seed: 0,
        }
    }
}

/// Per-node state living on its own thread: compiled executables, the
/// data shard, and local optimiser state.
struct WorkerState {
    exec: ShardExecutor,
    shard: ShardData,
    adam_mu: Adam,
    adam_ls: Adam, // over log s
    min_xvar: f64,
    lvm: bool,
}

impl WorkerState {
    /// Apply one local ascent step on (mu, log s) from raw-space grads.
    fn local_update(&mut self, d_xmu: &Matrix, d_xvar: &Matrix) {
        if !self.lvm || self.shard.len() == 0 {
            return;
        }
        let (b, q) = (self.shard.xmu.rows(), self.shard.xmu.cols());
        // minimise -F: negate the ascent gradients
        let g_mu: Vec<f64> = d_xmu.data().iter().map(|g| -g).collect();
        // chain rule d/dlog s = s * d/ds
        let g_ls: Vec<f64> = d_xvar
            .data()
            .iter()
            .zip(self.shard.xvar.data())
            .map(|(g, s)| -g * s)
            .collect();
        self.adam_mu.step(self.shard.xmu.data_mut(), &g_mu);
        let mut log_s: Vec<f64> = self
            .shard
            .xvar
            .data()
            .iter()
            .map(|s| s.max(self.min_xvar).ln())
            .collect();
        self.adam_ls.step(&mut log_s, &g_ls);
        for (s, l) in self.shard.xvar.data_mut().iter_mut().zip(&log_s) {
            *s = l.exp().max(self.min_xvar);
        }
        debug_assert_eq!(b * q, g_mu.len());
    }
}

/// The distributed trainer (leader).
pub struct Trainer {
    pool: Pool<WorkerState>,
    pub params: GlobalParams,
    cfg: TrainConfig,
    dout: usize,
    pub log: RunLog,
    rng: Rng,
    scg: Option<Scg>,
    adam: Option<Adam>,
    /// workers alive this iteration
    alive: Vec<bool>,
    /// permanently decommissioned workers (elastic recovery)
    dead: Vec<bool>,
    /// scratch: rounds recorded during the current iteration
    rounds: Vec<RoundTiming>,
    central_secs: f64,
    /// apply local updates on the next gradient round
    update_locals_next: bool,
    last_f: f64,
    /// the objective changed since SCG last anchored (locals moved or a
    /// node failed) — a refresh evaluation is needed before stepping
    objective_dirty: bool,
}

impl Trainer {
    /// Spawn the cluster. `shards[k]` becomes worker k's slice; local
    /// parameters (Xmu, Xvar) live only on the workers from here on.
    pub fn new(cfg: TrainConfig, params: GlobalParams, shards: Vec<ShardData>) -> Result<Trainer> {
        ensure!(
            shards.len() == cfg.workers,
            "need exactly one shard per worker ({} vs {})",
            shards.len(),
            cfg.workers
        );
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let art = manifest.config(&cfg.artifact)?;
        ensure!(
            art.m == params.m() && art.q == params.q(),
            "params shape (m={}, q={}) does not match artifact {} (m={}, q={})",
            params.m(),
            params.q(),
            cfg.artifact,
            art.m,
            art.q
        );
        let dout = art.d;
        let lvm = cfg.model == ModelKind::Lvm;
        let local_lr = cfg.local_lr;
        let min_xvar = cfg.min_xvar;
        let artifact = cfg.artifact.clone();
        let shards = Arc::new(shards);
        let manifest = Arc::new(manifest);
        let t0 = Instant::now();
        let pool = Pool::new(cfg.workers, move |k| {
            let exec = ShardExecutor::new(&manifest, &artifact)
                .with_context(|| format!("worker {k}: compiling artifacts"))?;
            let shard = shards[k].clone();
            let dof = shard.xmu.rows() * shard.xmu.cols();
            Ok(WorkerState {
                exec,
                shard,
                adam_mu: Adam::new(dof, local_lr),
                adam_ls: Adam::new(dof, local_lr),
                min_xvar,
                lvm,
            })
        })?;
        let startup_secs = t0.elapsed().as_secs_f64();
        let alive = vec![true; cfg.workers];
        let dead = vec![false; cfg.workers];
        let rng = Rng::new(cfg.seed ^ 0xC0FFEE);
        let mut log = RunLog::default();
        log.startup_secs = startup_secs;
        Ok(Trainer {
            pool,
            params,
            cfg,
            dout,
            log,
            rng,
            scg: None,
            adam: None,
            alive,
            dead,
            rounds: Vec::new(),
            central_secs: 0.0,
            update_locals_next: false,
            last_f: f64::NAN,
            objective_dirty: false,
        })
    }

    pub fn dout(&self) -> usize {
        self.dout
    }

    pub fn workers(&self) -> usize {
        self.cfg.workers
    }

    /// Adjust the per-iteration node failure probability (Fig. 7 sweeps).
    pub fn set_failure_rate(&mut self, rate: f64) {
        self.cfg.failure_rate = rate;
    }

    /// Permanently decommission worker `k`, re-sharding its data across
    /// the survivors — the paper's §5.2 *alternative* recovery strategy
    /// ("load the data to a different node and restart the calculation").
    /// In-process we fetch the shard back from the dying worker, which
    /// stands in for re-reading it from replicated storage; the survivors'
    /// local optimiser state is rebuilt at the new shapes.
    pub fn decommission(&mut self, k: usize) -> Result<()> {
        ensure!(k < self.cfg.workers, "no such worker {k}");
        ensure!(!self.dead[k], "worker {k} already decommissioned");
        let survivors: Vec<usize> = (0..self.cfg.workers)
            .filter(|i| *i != k && !self.dead[*i])
            .collect();
        ensure!(!survivors.is_empty(), "cannot decommission the last worker");

        // fetch the doomed shard (replica read)
        let orphan = self
            .pool
            .map_one(k, |_, w: &mut WorkerState| {
                let s = w.shard.clone();
                // drop the local data so the dead node holds nothing
                w.shard = ShardData {
                    xmu: Matrix::zeros(0, s.xmu.cols()),
                    xvar: Matrix::zeros(0, s.xvar.cols()),
                    y: Matrix::zeros(0, s.y.cols()),
                    kl_weight: s.kl_weight,
                };
                s
            })
            .ok_or_else(|| anyhow::anyhow!("worker {k} unreachable"))?
            .value;

        // split the orphan shard across the survivors
        let parts = partition(
            &orphan.xmu,
            &orphan.xvar,
            &orphan.y,
            orphan.kl_weight,
            survivors.len(),
        );
        let local_lr = self.cfg.local_lr;
        for (s, part) in survivors.iter().zip(parts) {
            self.pool
                .map_one(*s, move |_, w: &mut WorkerState| {
                    w.shard.xmu = w.shard.xmu.vstack(&part.xmu);
                    w.shard.xvar = w.shard.xvar.vstack(&part.xvar);
                    w.shard.y = w.shard.y.vstack(&part.y);
                    // optimiser state is shape-bound: rebuild (documented
                    // trade-off of the reassign strategy)
                    let dof = w.shard.xmu.rows() * w.shard.xmu.cols();
                    w.adam_mu = Adam::new(dof, local_lr);
                    w.adam_ls = Adam::new(dof, local_lr);
                })
                .ok_or_else(|| anyhow::anyhow!("survivor {s} unreachable"))?;
        }
        self.dead[k] = true;
        self.objective_dirty = true;
        Ok(())
    }

    /// Workers currently decommissioned.
    pub fn dead_workers(&self) -> Vec<usize> {
        (0..self.cfg.workers).filter(|k| self.dead[*k]).collect()
    }

    fn record_round<R>(&mut self, results: &[crate::mapreduce::MapResult<R>], wall: f64) {
        let mut worker_secs = vec![0.0; self.cfg.workers];
        for r in results {
            worker_secs[r.worker] = r.secs;
        }
        self.rounds.push(RoundTiming {
            worker_secs,
            wall_secs: wall,
        });
    }

    /// Rounds 1+2 at global parameters `theta`: distributed bound value
    /// and gradient. Applies local worker updates when the one-shot
    /// `update_locals_next` flag is set (paper step 4's "at the same
    /// time the end-point nodes optimise L_k").
    fn eval_globals(&mut self, theta: &[f64]) -> Result<(f64, Vec<f64>)> {
        let params = self.params.unflatten(theta);
        let alive = self.alive.clone();

        // ---- round 1: partial statistics --------------------------------
        let p1 = params.clone();
        let t0 = Instant::now();
        let results = self
            .pool
            .map_subset(&alive, move |_, w: &mut WorkerState| {
                w.exec.shard_stats(&p1, &w.shard)
            });
        let wall = t0.elapsed().as_secs_f64();
        self.record_round(&results, wall);
        let m = params.m();
        let mut stats = Stats::zeros(m, self.dout);
        for r in &results {
            let s = r.value.as_ref().map_err(|e| anyhow::anyhow!("{e}"))?;
            stats.accumulate(s);
        }

        // ---- central: bound + adjoints -----------------------------------
        let tc = Instant::now();
        let kmm = kernel::kmm(&params, self.cfg.jitter);
        let (bv, adj) = gp::assemble_bound(&stats, &kmm, params.log_beta, self.dout)?;
        self.central_secs += tc.elapsed().as_secs_f64();

        // ---- round 2: chain-rule gradients (+ local updates) -------------
        let p2 = params.clone();
        let adj2 = Arc::new(adj);
        let adj_for_round = Arc::clone(&adj2);
        let do_locals = self.update_locals_next;
        self.update_locals_next = false;
        let t1 = Instant::now();
        let gresults = self
            .pool
            .map_subset(&alive, move |_, w: &mut WorkerState| -> Result<GlobalGrads> {
                let (g, local) = w.exec.shard_grads(&p2, &w.shard, &adj_for_round)?;
                if do_locals {
                    w.local_update(&local.d_xmu, &local.d_xvar);
                }
                Ok(g)
            });
        let wall1 = t1.elapsed().as_secs_f64();
        self.record_round(&gresults, wall1);

        let tc2 = Instant::now();
        let mut total = GlobalGrads::zeros(m, params.q());
        for r in &gresults {
            let g = r.value.as_ref().map_err(|e| anyhow::anyhow!("{e}"))?;
            total.accumulate(g);
        }
        // central direct term (native pullback of dF/dKmm through Kmm(Z))
        total.accumulate(&kernel::kmm_vjp(&params, &adj2.d_kmm));
        total.d_log_beta = adj2.d_log_beta;
        self.central_secs += tc2.elapsed().as_secs_f64();

        self.last_f = bv.f;
        // minimise -F
        Ok((-bv.f, total.flatten().iter().map(|g| -g).collect()))
    }

    /// One outer iteration of the §3.2 protocol. Returns the bound F at
    /// the iteration's accepted point.
    pub fn step(&mut self) -> Result<f64> {
        let iter = self.log.iterations.len();
        self.rounds.clear();
        self.central_secs = 0.0;

        // node-failure injection for this iteration (paper Fig. 7);
        // permanently decommissioned nodes stay down
        let mut failed = Vec::new();
        for k in 0..self.cfg.workers {
            if self.dead[k] {
                self.alive[k] = false;
                continue;
            }
            let down = self.cfg.failure_rate > 0.0 && self.rng.flip(self.cfg.failure_rate);
            self.alive[k] = !down;
            if down {
                failed.push(k);
            }
        }
        if !self.alive.iter().any(|a| *a) {
            // never drop the whole cluster; revive the first live node
            let k = (0..self.cfg.workers).find(|k| !self.dead[*k]).unwrap();
            self.alive[k] = true;
            failed.retain(|f| *f != k);
        }

        let mut accepted_f = f64::NAN;
        match self.cfg.global_opt {
            GlobalOpt::Scg => {
                // take SCG out of self to avoid a double borrow in the
                // objective closure
                let mut scg = self.scg.take();
                let theta0 = self.params.flatten();
                // the first eval of the iteration happens at the current
                // accepted point and carries the workers' local updates
                // ("at the same time the end-point nodes optimise L_k");
                // SCG's probe/candidate evals do not.
                let lvm = self.cfg.model == ModelKind::Lvm;
                self.update_locals_next = lvm;
                // re-anchoring is only needed when the objective moved under
                // SCG's feet: local updates (LVM) or dropped nodes. Pure
                // regression with no failures skips the refresh eval —
                // a 1/3 round saving per iteration (EXPERIMENTS.md §Perf).
                let dirty = self.objective_dirty || lvm || !failed.is_empty();
                self.objective_dirty = !failed.is_empty();
                let result = (|| -> Result<()> {
                    let mut err: Option<anyhow::Error> = None;
                    {
                        let mut obj = |x: &[f64]| match self.eval_globals(x) {
                            Ok(v) => v,
                            Err(e) => {
                                err = Some(e);
                                (f64::INFINITY, vec![0.0; x.len()])
                            }
                        };
                        match scg.as_mut() {
                            None => {
                                scg = Some(Scg::new(theta0, &mut obj));
                            }
                            Some(s) => {
                                if dirty {
                                    s.refresh(&mut obj);
                                }
                            }
                        }
                        scg.as_mut().unwrap().step(&mut obj);
                    }
                    if let Some(e) = err {
                        return Err(e);
                    }
                    Ok(())
                })();
                let scg = scg.expect("scg initialised above");
                self.params = self.params.unflatten(scg.x());
                // report the bound at the ACCEPTED point (scg minimises -F),
                // not at whatever probe/candidate ran last
                accepted_f = -scg.f();
                self.scg = Some(scg);
                result?;
            }
            GlobalOpt::Adam { lr } => {
                let mut theta = self.params.flatten();
                self.update_locals_next = self.cfg.model == ModelKind::Lvm;
                let (_, grad) = self.eval_globals(&theta)?;
                if self.adam.is_none() {
                    self.adam = Some(Adam::new(theta.len(), lr));
                }
                self.adam.as_mut().unwrap().step(&mut theta, &grad);
                self.params = self.params.unflatten(&theta);
                accepted_f = self.last_f;
            }
        }

        let f = accepted_f;
        self.log.iterations.push(IterationLog {
            iter,
            f,
            rounds: std::mem::take(&mut self.rounds),
            central_secs: self.central_secs,
            failed_workers: failed,
        });
        Ok(f)
    }

    /// Run `iters` outer iterations; returns the final bound.
    pub fn train(&mut self, iters: usize) -> Result<f64> {
        let mut f = f64::NAN;
        for _ in 0..iters {
            f = self.step()?;
        }
        Ok(f)
    }

    /// Evaluate the bound at the current parameters without stepping
    /// (all nodes, no failure injection).
    pub fn evaluate(&mut self) -> Result<f64> {
        let saved = self.alive.clone();
        self.alive = (0..self.cfg.workers).map(|k| !self.dead[k]).collect();
        let theta = self.params.flatten();
        let (neg_f, _) = self.eval_globals(&theta)?;
        self.alive = saved;
        Ok(-neg_f)
    }

    /// Accumulated statistics at the current parameters (for posterior
    /// weights / prediction).
    pub fn current_stats(&mut self) -> Result<Stats> {
        let params = self.params.clone();
        let m = params.m();
        let results = self.pool.map(move |_, w: &mut WorkerState| {
            w.exec.shard_stats(&params, &w.shard)
        });
        let mut stats = Stats::zeros(m, self.dout);
        for r in &results {
            stats.accumulate(r.value.as_ref().map_err(|e| anyhow::anyhow!("{e}"))?);
        }
        Ok(stats)
    }

    /// Posterior weights at the current parameters.
    pub fn posterior(&mut self) -> Result<gp::PosteriorWeights> {
        let stats = self.current_stats()?;
        let kmm = kernel::kmm(&self.params, self.cfg.jitter);
        gp::bound::posterior_weights(&stats, &kmm, self.params.log_beta)
    }

    /// Fetch the workers' current local parameters (gather; used by the
    /// LVM experiments to inspect the learned embedding).
    pub fn gather_locals(&self) -> Vec<(Matrix, Matrix)> {
        self.pool
            .map(|_, w: &mut WorkerState| (w.shard.xmu.clone(), w.shard.xvar.clone()))
            .into_iter()
            .map(|r| r.value)
            .collect()
    }

    /// Predict through the first live worker's executor (any node serves).
    pub fn predict(
        &mut self,
        xt_mu: &Matrix,
        xt_var: &Matrix,
    ) -> Result<(Matrix, Vec<f64>)> {
        let w = self.posterior()?;
        let params = self.params.clone();
        let xt_mu = xt_mu.clone();
        let xt_var = xt_var.clone();
        let k = (0..self.cfg.workers)
            .find(|k| !self.dead[*k])
            .ok_or_else(|| anyhow::anyhow!("no live workers"))?;
        self.pool
            .map_one(k, move |_, ws: &mut WorkerState| {
                ws.exec.predict(&params, &xt_mu, &xt_var, &w.w1, &w.wv)
            })
            .expect("live worker reachable")
            .value
    }
}

/// Partition a dataset into `k` contiguous shards of near-equal size
/// (the paper distributes points evenly across nodes).
pub fn partition(
    xmu: &Matrix,
    xvar: &Matrix,
    y: &Matrix,
    kl_weight: f64,
    k: usize,
) -> Vec<ShardData> {
    let n = xmu.rows();
    let mut out = Vec::with_capacity(k);
    let base = n / k;
    let extra = n % k;
    let mut lo = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        let hi = lo + len;
        let take = |src: &Matrix| {
            Matrix::from_fn(hi - lo, src.cols(), |r, c| src[(lo + r, c)])
        };
        out.push(ShardData {
            xmu: take(xmu),
            xvar: take(xvar),
            y: take(y),
            kl_weight,
        });
        lo = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_all_points_once() {
        let n = 23;
        let xmu = Matrix::from_fn(n, 2, |i, j| (i * 2 + j) as f64);
        let xvar = Matrix::zeros(n, 2);
        let y = Matrix::from_fn(n, 3, |i, _| i as f64);
        let shards = partition(&xmu, &xvar, &y, 0.0, 5);
        assert_eq!(shards.len(), 5);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, n);
        // sizes differ by at most 1
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        // first row of shard 1 follows last row of shard 0
        assert_eq!(shards[1].y[(0, 0)], shards[0].len() as f64);
    }
}
